"""Distributed greedy balancing (paper §4, Balancing).

shard_map port of the host balancer in ``core/balance.py`` over the
``GraphShards`` layout. The two round kernels are shared with the host
path — each PE runs ``core.balance.balance_gains`` over its own arc
shard and pools its ``top_m`` candidates; the pools are combined with
``collectives.all_gather_1d`` (direct or two-level grid routing — the
array analogue of the paper's binary-tree reduction), and
``core.balance.greedy_select`` then applies the ranked pool redundantly
on every PE, so all PEs agree on the accepted moves without a root /
broadcast step.

Per round, each PE therefore exchanges O(P · top_m) candidate records
plus one halo refresh — never the O(m) arc gather the host balancer
pays (``core.balance.rebalance`` builds a single-chunk arc slab of the
whole graph). Block weight tables come in the same two layouts as
``dist_lp``:

  * ``"replicated"`` — every PE carries the dense (k+1,) table across
    rounds. Selection is deterministic and redundant, so no psum is
    needed to keep the copies identical.
  * ``"owner"`` — each PE persistently holds its (ceil((k+1)/P),) shard
    and requests the dense view via ``all_gather_1d`` at the top of
    each round; after selection it keeps only its slice (the commit is
    a slice, not a reduce-scatter, exactly because every PE computed
    the identical updated table).

Both layouts apply identical integer arithmetic in the same order and
produce bit-identical labels; at P=1 the whole balancer is bit-identical
to ``core.balance.rebalance``.

``dist_enforce_cluster_weights`` is the coarsening-side half of paper
§4's balancing: the exact eject-to-singleton sweep of
``core.coarsening.enforce_cluster_weights``, run owner-side. Member
records (cluster, weight, vertex) are routed to the cluster's owner PE
through one all-to-all, the owner applies the shared
keep-heaviest-first-prefix rule (``core.coarsening.ejection_candidates``
semantics) over the members it alone sees in full, and the eject flags
ride the reverse all-to-all back. Ejected vertices move to cluster id
``n + vertex_gid`` — guaranteed unused since LP labels are vertex ids
< n — so decisions match the host sweep exactly and the resulting
clustering is identical up to a relabeling of the fresh singletons
(contraction renumbers labels anyway).

Transients: the gathered pool is O(P · top_m) and the enforcement slab
O(n_loc · P) per PE — the same transient class as the dense weight
views of ``dist_lp``; persistent state stays O(n/P + k).
"""
from __future__ import annotations

import functools
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as PS

from ..core.balance import balance_gains, greedy_select
from ..core.lp import I32_MAX
from ..graphs.distribute import GraphShards
from ..kernels import dispatch
from ..kernels.bal_round import ops as bal_ops
from ..kernels.bal_round.bal_round import greedy_pick
from .collectives import all_gather_1d, all_to_all, halo_exchange
from .compat import shard_map
from .dist_lp import (_check_int32_weights, _check_weights_mode,
                      _resolve_mesh, owner_table_width)

# bytes per pooled candidate record: 4 int32 fields + 1 f32 gain
_POOL_RECORD_BYTES = 20


# ---------------------------------------------------------------------------
# distributed balancing rounds
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=32)
def _build_balance_round_fn(mesh, P, k, n, n_loc, n_ghost, top_m, use_grid,
                            owner, fused=False, interpret=True):
    kk = k + 1                    # sentinel block k
    S_k = owner_table_width(kk, P)

    def per_pe(*args):
        if fused:
            (lab_loc, lab_ghost, bw_state, ell_idx, ell_w, vw_loc, lgid,
             send_idx, recv_slot, offsets, l_max, salt) = args
            ell_idx, ell_w = ell_idx[0], ell_w[0]
        else:
            (lab_loc, lab_ghost, bw_state, src, dst, w, vw_loc, lgid,
             send_idx, recv_slot, offsets, l_max, salt) = args
            src, dst, w = src[0], dst[0], w[0]
        lab_loc, lab_ghost, bw_state = lab_loc[0], lab_ghost[0], bw_state[0]
        vw_loc, lgid = vw_loc[0], lgid[0]
        send_idx, recv_slot = send_idx[0], recv_slot[0]

        # dense block-weight view for this round (owner mode: request)
        bw = all_gather_1d(bw_state, "pe", P, use_grid=use_grid) if owner \
            else bw_state
        vw_pad = jnp.concatenate([vw_loc, jnp.zeros((1,), jnp.int32)])
        gid_pad = jnp.concatenate([lgid, jnp.full((1,), n, jnp.int32)])
        tab = jnp.concatenate(
            [lab_loc, lab_ghost, jnp.full((1,), k, jnp.int32)])
        lab_src_tab = jnp.concatenate(
            [lab_loc, jnp.full((1,), k, jnp.int32)])

        # per-shard gains: shared host kernel (composed) or Pallas pair
        if fused:
            rel, tgt = bal_ops.fused_round_scores(
                tab, lab_src_tab, bw, l_max, None, ell_idx, ell_w,
                vw_pad, gid_pad < n, salt, restricted=False,
                interpret=interpret)
        else:
            lab_dst = tab[dst]
            s_src, s_lab, s_w = lax.sort((src, lab_dst, w), num_keys=2)
            rel, tgt = balance_gains(lab_src_tab, s_src, s_lab, s_w, bw,
                                     l_max, None, vw_pad, salt, n_loc,
                                     valid=gid_pad < n, restricted=False)

        # local top-m pool -> gathered (P*top_m,) pool on every PE
        vals, vidx = lax.top_k(rel, top_m)
        pool = jnp.stack([gid_pad[vidx], tgt[vidx], lab_src_tab[vidx],
                          vw_pad[vidx]], axis=1)            # (top_m, 4)
        pool = all_gather_1d(pool, "pe", P, use_grid=use_grid)
        pvals = all_gather_1d(vals, "pe", P, use_grid=use_grid)

        # deterministic ranking: descending gain, ties by vertex id
        # (matches lax.top_k's lower-index-first tie-break at P=1)
        o_neg, o_gid, o_tgt, o_blk, o_w = lax.sort(
            (-pvals, pool[:, 0], pool[:, 1], pool[:, 2], pool[:, 3]),
            num_keys=2)
        if fused:
            accept, bw = greedy_pick(-o_neg, o_tgt, o_blk, o_w, bw, l_max,
                                     interpret=interpret)
        else:
            accept, bw = greedy_select(-o_neg, o_tgt, o_blk, o_w, bw,
                                       l_max)

        # apply accepted moves to the locally-owned vertices
        pid = lax.axis_index("pe")
        v0, v1 = offsets[pid], offsets[pid + 1]
        mine = accept & (o_gid >= v0) & (o_gid < v1)
        idx = jnp.where(mine, o_gid - v0, jnp.int32(n_loc))
        lab_loc = lab_loc.at[idx].set(o_tgt, mode="drop")
        lab_ghost = halo_exchange(lab_loc, send_idx, recv_slot, n_ghost,
                                  "pe", P, use_grid=use_grid)

        overloaded = jnp.any(bw[:k] > l_max[:k])
        if owner:   # commit: keep only this PE's authoritative slice
            bw_state = lax.dynamic_slice(bw, (pid * S_k,), (S_k,))
        else:
            bw_state = bw
        return (lab_loc[None], lab_ghost[None], bw_state[None],
                overloaded[None])

    pe = PS("pe")
    rep = PS()
    n_pe = 9 if fused else 10
    fn = shard_map(per_pe, mesh=mesh,
                   in_specs=(pe,) * n_pe + (rep, rep, rep),
                   out_specs=(pe, pe, pe, pe), check_rep=not fused)
    return jax.jit(fn)


def dist_rebalance(shards: GraphShards,
                   part: np.ndarray,
                   l_max_vec: np.ndarray,
                   top_m: int = 128,
                   max_rounds: int = 200,
                   seed: int = 0,
                   use_grid: bool = True,
                   mesh=None,
                   weights: str = "replicated",
                   kernel: str = "auto",
                   stats: Optional[Dict] = None) -> np.ndarray:
    """Distributed exact balancer: rounds of pooled greedy moves until
    every block fits its budget.

    Bit-identical to ``core.balance.rebalance(g, part, l_max_vec)`` at
    P=1 (same gains, same pool ordering, same salt schedule, same
    early-return); at P>1 each PE contributes its own ``top_m``
    candidates per round, so a round can apply up to ``P * top_m``
    moves. ``weights`` picks the block-table layout (module docstring);
    both produce bit-identical labels, as does ``kernel="fused"`` (the
    ``kernels.bal_round`` Pallas pair; falls back to composed when the
    per-PE ELL slab exceeds the VMEM budget). ``stats``, when given,
    receives ``rounds`` / ``pool_bytes`` / ``halo_bytes`` / ``time_s``.
    """
    P, n = shards.P, shards.n
    owner = _check_weights_mode(weights)
    k = int(l_max_vec.shape[0])
    part = np.asarray(part, dtype=np.int64)
    l_max_vec = np.asarray(l_max_vec, dtype=np.int64)
    t_start = time.perf_counter()

    valid = shards.local_gid < n
    vw_glob = np.zeros(n, dtype=np.int64)
    vw_glob[shards.local_gid[valid]] = shards.vweights[valid]
    bw0 = np.zeros(k, dtype=np.int64)
    np.add.at(bw0, part, vw_glob)
    if not bool(np.any(bw0 > l_max_vec)):   # already feasible: no device work
        if stats is not None:
            stats.update(rounds=0, pool_bytes=0, halo_bytes=0,
                         time_s=time.perf_counter() - t_start)
        return part.copy()

    _check_int32_weights(shards)
    mesh = _resolve_mesh(mesh, P)
    kk = k + 1
    S_k = owner_table_width(kk, P)
    L = P * S_k if owner else kk
    # sentinel / pad blocks: maximal weight and budget — never overloaded,
    # never a fitting target, never the argmin fallback (same fix as
    # core.refinement.pad_blocks)
    bw_dense = np.full(L, I32_MAX, dtype=np.int32)
    bw_dense[:k] = bw0
    lmax_dense = np.full(L, I32_MAX, dtype=np.int32)
    lmax_dense[:k] = np.minimum(l_max_vec, int(I32_MAX))
    bw_state = bw_dense.reshape(P, S_k) if owner \
        else np.broadcast_to(bw_dense, (P, kk)).copy()

    top_m_loc = min(top_m, shards.n_loc + 1)
    part_pad = np.concatenate([part, [k]])   # sentinel gid n -> block k
    lab_loc = part_pad[np.minimum(shards.local_gid, n)].astype(np.int32)
    lab_ghost = part_pad[np.minimum(shards.ghost_gid, n)].astype(np.int32)

    fused = dispatch.resolve_kernel_mode(kernel) == "fused"
    if fused:
        ell_idx, ell_w = bal_ops.build_balance_ell_dist(shards)
        if not bal_ops.balance_ell_fits(ell_idx.shape[1],
                                        ell_idx.shape[2]):
            dispatch.report_fallback(
                "bal_round",
                bal_ops.bal_scores_vmem_bytes(
                    ell_idx.shape[1], ell_idx.shape[2],
                    bal_ops.ROW_TILE),
                detail="dist_rebalance")
            fused = False
    fn = _build_balance_round_fn(mesh, P, k, n, shards.n_loc,
                                 shards.n_ghost, top_m_loc, use_grid,
                                 owner, fused=fused,
                                 interpret=dispatch.kernel_interpret())
    lab_loc = jnp.asarray(lab_loc)
    lab_ghost = jnp.asarray(lab_ghost)
    bw_state = jnp.asarray(bw_state)
    slab_args = (jnp.asarray(ell_idx), jnp.asarray(ell_w)) if fused else \
        (jnp.asarray(shards.arc_src),
         jnp.asarray(shards.arc_dst_idx),
         jnp.asarray(shards.arc_w))
    graph_args = slab_args + (jnp.asarray(shards.vweights),
                  jnp.asarray(shards.local_gid),
                  jnp.asarray(shards.send_idx),
                  jnp.asarray(shards.recv_slot),
                  jnp.asarray(shards.offsets.astype(np.int32)),
                  jnp.asarray(lmax_dense))
    rounds = 0
    for r in range(max_rounds):
        lab_loc, lab_ghost, bw_state, overloaded = fn(
            lab_loc, lab_ghost, bw_state, *graph_args,
            jnp.uint32((seed * 7919 + r) % (2**32)))
        rounds = r + 1
        if not bool(np.any(np.asarray(overloaded))):
            break

    lab = np.asarray(lab_loc)
    out = np.empty(n, dtype=np.int64)
    out[shards.local_gid[valid]] = lab[valid]
    if stats is not None:
        stats.update(
            rounds=rounds,
            # per-PE gathered pool volume + ghost refresh, per run
            pool_bytes=rounds * P * top_m_loc * _POOL_RECORD_BYTES,
            halo_bytes=rounds * shards.comm_bytes_per_halo(),
            time_s=time.perf_counter() - t_start)
    return out


# ---------------------------------------------------------------------------
# sharded exact cluster-weight enforcement (coarsening-side balancing)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=32)
def _build_enforce_fn(mesh, P, n, n_loc, use_grid):
    S_w = owner_table_width(n + 1, P)   # cluster id c is owned by c // S_w
    R = P * n_loc                       # owner-side member rows

    def per_pe(lab_loc, vw_loc, lgid, W):
        lab_loc, vw_loc, lgid = lab_loc[0], vw_loc[0], lgid[0]
        iota = jnp.arange(n_loc, dtype=jnp.int32)
        valid = lgid < n
        dest = jnp.where(valid, lab_loc // S_w, P)   # P == drop

        # pack member records into per-owner segments of the send slab
        o_dest, _, o_lab, o_vw, o_gid, o_idx = lax.sort(
            (dest, lgid, lab_loc, vw_loc, lgid, iota), num_keys=2)
        runs = jnp.concatenate(
            [jnp.ones((1,), jnp.bool_), o_dest[1:] != o_dest[:-1]])
        rid = jnp.cumsum(runs.astype(jnp.int32)) - 1
        run0 = jax.ops.segment_min(jnp.where(runs, iota, I32_MAX), rid,
                                   num_segments=n_loc)
        pos = iota - run0[rid]
        fidx = jnp.where(o_dest < P, o_dest * n_loc + pos, R)
        slab = jnp.stack([
            jnp.full((R,), n, jnp.int32).at[fidx].set(o_lab, mode="drop"),
            jnp.zeros((R,), jnp.int32).at[fidx].set(o_vw, mode="drop"),
            jnp.full((R,), n, jnp.int32).at[fidx].set(o_gid, mode="drop"),
        ], axis=-1).reshape(P, n_loc, 3)

        # owners see every member of their clusters
        recv = all_to_all(slab, "pe", P, use_grid=use_grid)
        r_lab = recv[:, :, 0].reshape(R)
        r_vw = recv[:, :, 1].reshape(R)
        r_gid = recv[:, :, 2].reshape(R)

        # shared decision rule: sort by (cluster, -weight, id), eject when
        # the cumulative kept weight exceeds W — never the first member
        riota = jnp.arange(R, dtype=jnp.int32)
        s_lab, s_nvw, s_gid, s_j = lax.sort(
            (r_lab, -r_vw, r_gid, riota), num_keys=3)
        s_vw = jnp.where(s_lab < n, -s_nvw, 0)
        starts = jnp.concatenate(
            [jnp.ones((1,), jnp.bool_), s_lab[1:] != s_lab[:-1]])
        grp = jnp.cumsum(starts.astype(jnp.int32)) - 1
        csum = jnp.cumsum(s_vw)
        base = jax.ops.segment_min(
            jnp.where(starts, csum - s_vw, I32_MAX), grp, num_segments=R)
        within = csum - base[grp]
        eject = (s_lab < n) & (within > W) & ~starts

        # eject flags ride the reverse exchange back to the member's PE
        flags = jnp.zeros((R,), jnp.bool_).at[s_j].set(eject)
        back = all_to_all(flags.reshape(P, n_loc), "pe", P,
                          use_grid=use_grid).reshape(R)
        fl = jnp.where(o_dest < P, back[jnp.minimum(fidx, R - 1)], False)
        ej_loc = jnp.zeros((n_loc,), jnp.bool_).at[o_idx].set(fl)

        # fresh singleton id n + gid: unused, since LP labels are ids < n
        lab_out = jnp.where(ej_loc & valid, n + lgid, lab_loc)
        return lab_out[None], jnp.sum(ej_loc)[None]

    pe = PS("pe")
    fn = shard_map(per_pe, mesh=mesh, in_specs=(pe, pe, pe, PS()),
                   out_specs=(pe, pe), check_rep=True)
    return jax.jit(fn)


def dist_enforce_cluster_weights(shards: GraphShards,
                                 labels: np.ndarray,
                                 max_weight: int,
                                 use_grid: bool = True,
                                 mesh=None,
                                 stats: Optional[Dict] = None
                                 ) -> np.ndarray:
    """Sharded exact max-cluster-weight enforcement.

    Ejects the identical vertex set as the host sweep
    (``core.coarsening.enforce_cluster_weights`` /
    ``ejection_candidates``) — owners apply the same deterministic
    (cluster, -weight, id) prefix rule over all members of their
    clusters — but assigns ejected vertices the fresh singleton id
    ``n + vertex_gid`` instead of recycling host-side free ids, so the
    result matches the host sweep up to a relabeling of the fresh
    singletons. ``labels`` must be LP cluster labels (values are vertex
    ids < n).
    """
    P, n = shards.P, shards.n
    if n >= 2**30:
        raise ValueError(
            f"dist_enforce_cluster_weights: n = {n} >= 2^30 would "
            "overflow the int32 fresh-singleton id space (n + gid)")
    labels = np.asarray(labels, dtype=np.int64)
    if labels.shape != (n,) or (n and labels.max() >= n):
        raise ValueError(
            "dist_enforce_cluster_weights expects (n,) LP labels with "
            f"values < n, got shape {labels.shape}")
    _check_int32_weights(shards)   # the owner-side cumsum is int32
    mesh = _resolve_mesh(mesh, P)
    t0 = time.perf_counter()
    lab_pad = np.concatenate([labels, [n]])
    lab_loc = lab_pad[np.minimum(shards.local_gid, n)].astype(np.int32)
    fn = _build_enforce_fn(mesh, P, n, shards.n_loc, use_grid)
    out_loc, ejected = fn(
        jnp.asarray(lab_loc), jnp.asarray(shards.vweights),
        jnp.asarray(shards.local_gid),
        jnp.int32(max(1, min(int(max_weight), int(I32_MAX)))))
    out_loc = np.asarray(out_loc)
    valid = shards.local_gid < n
    out = np.empty(n, dtype=np.int64)
    out[shards.local_gid[valid]] = out_loc[valid]
    if stats is not None:
        stats.update(ejected=int(np.asarray(ejected).sum()),
                     slab_bytes_per_pe=int(P * shards.n_loc * 12),
                     time_s=time.perf_counter() - t0)
    return out
