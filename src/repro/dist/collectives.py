"""Sparse all-to-all collectives (paper §3, Communication).

Both primitives transpose a per-PE message slab: each PE holds a local
array ``slab`` of shape (P, ...) where ``slab[q]`` is the message destined
for PE q; after the exchange PE p holds ``out[q] == slab_of_q[p]``.

``direct_all_to_all`` issues the single P-way collective. For large P the
paper routes the same payload through a two-level a x b grid
(``grid_all_to_all``): messages first travel within grid rows (grouped by
destination column), then within columns — 2·(a+b) partners per PE instead
of P, at the cost of forwarding each payload twice. Non-square P uses the
largest divisor a <= sqrt(P) (6 PEs -> 2x3); prime P degenerates to the
direct exchange.

On top of the raw transposition sit three protocol primitives:
``halo_exchange`` (ghost-vertex refresh over a static schedule),
``exchange_segments`` (segmented payload exchange for the distributed
contraction's edge shuffle, §5), and the owner-sharded weight-table pair
``all_gather_1d`` / ``psum_scatter_1d`` (read / commit halves of the
distributed cluster- and block-weight tables). Each routes either
directly or through the grid with identical results.

All functions are jit-side and must run inside ``shard_map`` over the 1D
'pe' mesh axis.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
from jax import lax


def direct_all_to_all(slab: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """One-phase transposition: out[q] = slab_of_q[p]."""
    return lax.all_to_all(slab, axis_name, 0, 0, tiled=True)


def grid_factors(P: int) -> Tuple[int, int]:
    """(a, b) with a*b == P, a <= b, a the largest divisor <= sqrt(P)."""
    a = 1
    d = 1
    while d * d <= P:
        if P % d == 0:
            a = d
        d += 1
    return a, P // a


def grid_all_to_all(slab: jnp.ndarray, axis_name: str, P: int) -> jnp.ndarray:
    """Two-level all-to-all through an a x b PE grid (PE p = (p//b, p%b)).

    Phase 1 transposes within grid rows over the destination-column axis;
    phase 2 within grid columns over the destination-row axis. The result
    is bit-identical to ``direct_all_to_all``.
    """
    a, b = grid_factors(P)
    if a == 1:  # prime P: no nontrivial grid, route directly
        return direct_all_to_all(slab, axis_name)
    row_groups = [[r * b + c for c in range(b)] for r in range(a)]
    col_groups = [[r * b + c for r in range(a)] for c in range(b)]
    tail = slab.shape[1:]
    m = slab.reshape((a, b) + tail)            # [dst_row, dst_col]
    m = lax.all_to_all(m, axis_name, 1, 1, axis_index_groups=row_groups,
                       tiled=True)             # [dst_row, src_col]
    m = lax.all_to_all(m, axis_name, 0, 0, axis_index_groups=col_groups,
                       tiled=True)             # [src_row, src_col]
    return m.reshape((P,) + tail)


def all_to_all(slab: jnp.ndarray, axis_name: str, P: int,
               use_grid: bool = False) -> jnp.ndarray:
    return grid_all_to_all(slab, axis_name, P) if use_grid \
        else direct_all_to_all(slab, axis_name)


def all_gather_1d(shard: jnp.ndarray, axis_name: str, P: int,
                  use_grid: bool = False) -> jnp.ndarray:
    """Concatenate the (S, ...) owner shards of all P PEs along the
    leading axis into the dense (P*S, ...) table (every PE receives the
    same array).

    The read half of the owner-sharded weight protocol, and the pool
    combiner of the distributed balancer (each PE contributes its
    (top_m, fields) candidate records). Persistent state stays O(S) per
    PE; the dense view exists only transiently inside the chunk/round
    body. Grid routing gathers within grid rows, then columns —
    bit-identical to the direct gather.
    """
    if not use_grid:
        return lax.all_gather(shard, axis_name, tiled=True)
    a, b = grid_factors(P)
    if a == 1:
        return lax.all_gather(shard, axis_name, tiled=True)
    row_groups = [[r * b + c for c in range(b)] for r in range(a)]
    col_groups = [[r * b + c for r in range(a)] for c in range(b)]
    m = lax.all_gather(shard, axis_name, axis_index_groups=row_groups)
    m = lax.all_gather(m, axis_name, axis_index_groups=col_groups)
    return m.reshape((P * shard.shape[0],) + shard.shape[1:])


def psum_scatter_1d(dense: jnp.ndarray, axis_name: str, P: int,
                    use_grid: bool = False) -> jnp.ndarray:
    """Reduce-scatter a dense (P*S,) delta table to owner shards: PE p
    receives sum_q dense_of_q[p*S:(p+1)*S].

    The commit half of the owner-sharded weight protocol (movers scatter
    weight deltas, owners hold the authoritative sum). Integer payloads
    make grid and direct routing bit-identical.
    """
    S = dense.shape[0] // P
    if not use_grid:
        return lax.psum_scatter(dense, axis_name, scatter_dimension=0,
                                tiled=True)
    a, b = grid_factors(P)
    if a == 1:
        return lax.psum_scatter(dense, axis_name, scatter_dimension=0,
                                tiled=True)
    row_groups = [[r * b + c for c in range(b)] for r in range(a)]
    col_groups = [[r * b + c for r in range(a)] for c in range(b)]
    # phase 1: sum within grid columns, each PE keeping its dst-row block
    m = lax.psum_scatter(dense.reshape(a, b * S), axis_name,
                         scatter_dimension=0, axis_index_groups=col_groups,
                         tiled=True)
    # phase 2: sum within grid rows, each PE keeping its dst-column block
    m = lax.psum_scatter(m.reshape(b, S), axis_name, scatter_dimension=0,
                         axis_index_groups=row_groups, tiled=True)
    return m.reshape(S)


def exchange_segments(slab: jnp.ndarray, counts: jnp.ndarray,
                      axis_name: str, P: int,
                      use_grid: bool = False
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Segmented all-to-all: transpose a (P, S, ...) payload slab together
    with its per-destination segment lengths (P,).

    After the exchange PE p holds ``recv[q] = slab_of_q[p]`` with
    ``recv_counts[q]`` valid rows — the edge-exchange primitive of the
    distributed contraction (paper §5): segment q→p carries the coarse
    arcs PE q pre-contracted whose coarse tail is owned by PE p.
    """
    recv = all_to_all(slab, axis_name, P, use_grid=use_grid)
    rcounts = all_to_all(counts.reshape(P, 1), axis_name, P,
                         use_grid=use_grid).reshape(P)
    return recv, rcounts


def halo_exchange(vals: jnp.ndarray,
                  send_idx: jnp.ndarray,
                  recv_slot: jnp.ndarray,
                  n_ghost: int,
                  axis_name: str,
                  P: int,
                  use_grid: bool = False) -> jnp.ndarray:
    """Ghost-vertex value exchange over a ``GraphShards`` halo plan.

    ``vals``: (n_loc,) per-PE values of owned vertices.
    ``send_idx``/``recv_slot``: this PE's (P, S) rows of the static halo
    schedule (sentinels n_loc / n_ghost mark padding).
    Returns the (n_ghost,) ghost values; padded ghost slots read 0.
    """
    pad = jnp.concatenate([vals, jnp.zeros((1,), vals.dtype)])
    msg = pad[send_idx]                                   # (P, S)
    rcv = all_to_all(msg, axis_name, P, use_grid=use_grid)
    out = jnp.zeros((n_ghost + 1,), vals.dtype)
    out = out.at[recv_slot.reshape(-1)].set(rcv.reshape(-1), mode="drop")
    return out[:n_ghost]
