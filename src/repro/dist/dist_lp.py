"""Distributed size-constrained label propagation (paper §4).

shard_map port of the chunked LP kernels in ``core/lp.py`` over the
``GraphShards`` layout of ``graphs/distribute.py``. Each PE owns a
contiguous vertex range; labels are *global* ids, and ghost labels are
refreshed through the static halo schedule after every chunk.

Cluster/block weight tables come in two layouts, selected by the
``weights`` argument:

  * ``"replicated"`` — every PE carries the full (n+1,)/(k+1,) table,
    synchronized by psum after each chunk. Simple and fast at test
    scale, but O(n) persistent state per PE.
  * ``"owner"`` — each PE persistently holds only its ~(n/P,) shard of
    the table (uniform block distribution of the label space). Movers
    *request* current weights via ``all_gather_1d`` at the top of each
    chunk and *commit* their deltas via ``psum_scatter_1d``; the
    overweight check runs on the owner's authoritative shard before the
    flags are gathered back for the bounce. Persistent per-PE state
    drops to O(n/P + k); the dense view exists only transiently inside
    the chunk body (XLA's static shapes rule out sparse messages).

Both layouts apply identical integer arithmetic in the same order, so
they produce bit-identical labels.

Weight constraint handling follows the paper's two tiers:

  * intra-PE races within a chunk use the exact hash-ordered revert of
    ``core.lp._cluster_chunk`` against the PE's local view;
  * cross-PE races are only detected after the commit — overweight
    clusters then *bounce* this chunk's incoming moves back (approximate
    revert, §4 Coarsening). Exact enforcement happens on the host before
    contraction (``core.coarsening.enforce_cluster_weights``).

The bounce decision depends only on reduction results, never on message
routing, so grid and direct runs produce identical labels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as PS

from ..core.lp import (I32_MAX, _argmax_target, _group_conns, _hash32,
                       _own_connection)
from ..graphs.distribute import GraphShards, chunk_local_arcs
from ..kernels import dispatch
from ..kernels.lp_move import ops as move_ops
from ..kernels.lp_move.lp_move import lp_move_chunk, lp_move_vmem_bytes
from .collectives import all_gather_1d, halo_exchange, psum_scatter_1d
from .compat import shard_map

_BIG = np.int32(2**30)

WEIGHT_MODES = ("replicated", "owner")


def _check_weights_mode(weights: str) -> bool:
    if weights not in WEIGHT_MODES:
        raise ValueError(f"unknown weights mode {weights!r}; expected one "
                         f"of {WEIGHT_MODES}")
    return weights == "owner"


def owner_table_width(num_labels: int, P: int) -> int:
    """Per-PE owner-shard width: uniform block distribution of the label
    space, padded so P shards tile the dense table exactly."""
    return -(-num_labels // P)


def _check_int32_weights(shards: GraphShards) -> None:
    """Same guard as core.lp.build_chunks: the replicated int32 weight
    tables (psum-accumulated) must never wrap. A real error, not an
    assert — asserts vanish under ``python -O``."""
    tot_v = int(shards.vweights.astype(np.int64).sum())
    tot_e = int(shards.arc_w.astype(np.int64).sum())
    if tot_v >= 2**31 or tot_e >= 2**31:
        raise ValueError(
            f"dist_lp: total vertex/edge weight ({tot_v}/{tot_e}) must "
            "be < 2^31 for the int32 jit path")


def make_mesh_1d(P: int) -> Mesh:
    """1D 'pe' mesh over the first P devices."""
    devs = jax.devices()
    assert len(devs) >= P, (len(devs), P)
    return Mesh(np.array(devs[:P]), ("pe",))


def _resolve_mesh(mesh, P: int) -> Mesh:
    """Accept a caller-provided 1D 'pe' mesh (serving sessions build one
    and reuse it across requests) or build a fresh one."""
    if mesh is None:
        return make_mesh_1d(P)
    assert mesh.axis_names == ("pe",) and mesh.devices.size == P, \
        (mesh.axis_names, mesh.devices.size, P)
    return mesh


# ---------------------------------------------------------------------------
# per-PE chunk step (jit-side)
# ---------------------------------------------------------------------------

def _local_moves(lab_src_tab, tab, cw_like, budget_like, vw_pad,
                 c_src, c_dst, c_w, salt, n_loc, cluster_mode):
    """Shared gain/argmax stage. Returns (move, target, lab_cur) over the
    (n_loc+1,) src space. ``cw_like``/``budget_like`` are indexed by label
    value; in cluster mode budget is the scalar W broadcast."""
    lab_dst = tab[c_dst]
    s_src, s_lab, s_w = lax.sort((c_src, lab_dst, c_w), num_keys=2)
    conn = _group_conns(s_src, s_lab, s_w)
    own_lab = lab_src_tab[s_src]
    staying = s_lab == own_lab
    # ``w <= budget - c`` form: exact at the int32 boundary (w + c wraps)
    fits = cw_like[s_lab] <= budget_like[s_lab] - vw_pad[s_src]
    if cluster_mode:
        fits = fits | staying
    else:
        fits = fits & ~staying
    score = jnp.where(fits, conn, -1)
    best, target = _argmax_target(s_src, s_lab, score, cw_like[s_lab],
                                  salt, n_loc)
    own_conn = _own_connection(s_src, s_lab, s_w, lab_src_tab, n_loc)
    lab_cur = lab_src_tab
    tgt_safe = jnp.where(target < I32_MAX, target, lab_cur)
    if cluster_mode:
        move = (best > own_conn) & (tgt_safe != lab_cur) & \
            (target < I32_MAX) & (best > 0)
    else:
        gain = best - own_conn
        lighter = cw_like[tgt_safe] < cw_like[lab_cur] - vw_pad
        move = (target < I32_MAX) & (best >= 0) & \
            ((gain > 0) | ((gain == 0) & lighter))
    move = move.at[n_loc].set(False)
    return move, tgt_safe, lab_cur


def _penalized_moves(lab_src_tab, tab, bw_like, budget_like, vw_pad,
                     c_src, c_dst, c_w, salt, pen_num, pen_den, n_loc):
    """Unconstrained (Jet-style) gain/argmax stage: the budget mask of
    ``_local_moves`` is replaced by a penalty-weighted score. A move
    whose target block would exceed its budget pays
    ``(own_conn // pen_den) * pen_num`` off its connection (integer-only,
    ``pen <= own_conn < 2^31``), so round 0 is pure gain-greedy and later
    rounds escalate the bar for overloading moves. No bounce follows —
    feasibility is repaired by the trailing balancer (afterburner). Same
    tie-breaks and move rule as the constrained stage otherwise, so the
    two stages differ only in admission. See docs/REFINEMENT.md."""
    lab_dst = tab[c_dst]
    s_src, s_lab, s_w = lax.sort((c_src, lab_dst, c_w), num_keys=2)
    conn = _group_conns(s_src, s_lab, s_w)
    own_lab = lab_src_tab[s_src]
    staying = s_lab == own_lab
    own_conn = _own_connection(s_src, s_lab, s_w, lab_src_tab, n_loc)
    # ``w > budget - c`` form: exact at the int32 boundary (w + c wraps)
    over_after = bw_like[s_lab] > budget_like[s_lab] - vw_pad[s_src]
    pen = jnp.where(over_after,
                    (own_conn[s_src] // pen_den) * pen_num, 0)
    # clamping to -1 loses nothing: a score < 0 can never pass the move
    # rule (it would need score >= own_conn >= 0)
    score = jnp.where(~staying, jnp.maximum(conn - pen, -1), -1)
    best, target = _argmax_target(s_src, s_lab, score, bw_like[s_lab],
                                  salt, n_loc)
    lab_cur = lab_src_tab
    tgt_safe = jnp.where(target < I32_MAX, target, lab_cur)
    gain = best - own_conn
    lighter = bw_like[tgt_safe] < bw_like[lab_cur] - vw_pad
    move = (target < I32_MAX) & (best >= 0) & \
        ((gain > 0) | ((gain == 0) & lighter))
    move = move.at[n_loc].set(False)
    return move, tgt_safe, lab_cur


def _intra_pe_revert(move, tgt, lab_cur, vw_pad, cw, d_in, d_out,
                     salt, n_loc, num_labels, W):
    """Exact hash-ordered revert of this PE's chunk moves against its local
    weight view (port of core.lp._cluster_chunk's revert block)."""
    new_cw = cw + d_in - d_out
    new_lab = jnp.where(move, tgt, lab_cur)
    over = new_cw > W
    cand = move & over[new_lab]
    num = n_loc + 1
    rk = _hash32(jnp.arange(num, dtype=jnp.int32),
                 salt ^ np.uint32(0x9E3779B9))
    sort_lab = jnp.where(cand, new_lab, jnp.int32(num_labels))
    o_lab, _, o_v = lax.sort(
        (sort_lab, rk, jnp.arange(num, dtype=jnp.int32)), num_keys=2)
    o_vw = jnp.where(o_lab < num_labels, vw_pad[o_v], 0)
    csum = jnp.cumsum(o_vw)
    grp_start = jnp.concatenate([
        jnp.ones((1,), jnp.bool_), o_lab[1:] != o_lab[:-1]])
    gid = jnp.cumsum(grp_start.astype(jnp.int32)) - 1
    base = jax.ops.segment_min(
        jnp.where(grp_start, csum - o_vw, I32_MAX), gid, num_segments=num)
    within = csum - base[gid]
    lab_safe = jnp.where(o_lab < num_labels, o_lab, 0)
    moved_in = jax.ops.segment_sum(o_vw, gid, num_segments=num)[gid]
    allowed = jnp.maximum(W - (new_cw[lab_safe] - moved_in), 0)
    revert = (o_lab < num_labels) & (within > allowed)
    rv = jnp.zeros(num, dtype=jnp.bool_).at[o_v].set(revert, mode="drop")
    return move & ~rv


def _apply_and_sync(move, tgt, lab_cur, vw_pad, cw, num_labels):
    """Scatter move deltas into the replicated label-weight table and psum.
    Returns the updated weight table."""
    vw_m = jnp.where(move, vw_pad, 0)
    d_in = jnp.zeros((num_labels,), jnp.int32).at[tgt].add(vw_m,
                                                           mode="drop")
    d_out = jnp.zeros((num_labels,), jnp.int32).at[lab_cur].add(vw_m,
                                                                mode="drop")
    delta = lax.psum(d_in - d_out, "pe")
    return cw + delta


def _bounce_back(move, tgt, lab_cur, vw_pad, cw, budget_like, num_labels):
    """Approximate cross-PE revert: labels that exceeded their budget after
    the psum bounce this chunk's incoming moves back everywhere."""
    over = cw > budget_like
    bounce = move & over[tgt]
    vw_b = jnp.where(bounce, vw_pad, 0)
    b_in = jnp.zeros((num_labels,), jnp.int32).at[lab_cur].add(vw_b,
                                                               mode="drop")
    b_out = jnp.zeros((num_labels,), jnp.int32).at[tgt].add(vw_b,
                                                            mode="drop")
    cw = cw + lax.psum(b_in - b_out, "pe")
    return move & ~bounce, cw


# --- owner-sharded weight-table protocol (weights="owner") -----------------

def _commit_to_owners(move, tgt, lab_cur, vw_pad, cw_own, L, P, use_grid):
    """Owner-mode apply: scatter this chunk's move deltas into a transient
    dense table and reduce-scatter them onto the owners' authoritative
    shards. Returns the updated (L/P,) owner shard."""
    vw_m = jnp.where(move, vw_pad, 0)
    d_in = jnp.zeros((L,), jnp.int32).at[tgt].add(vw_m, mode="drop")
    d_out = jnp.zeros((L,), jnp.int32).at[lab_cur].add(vw_m, mode="drop")
    return cw_own + psum_scatter_1d(d_in - d_out, "pe", P,
                                    use_grid=use_grid)


def _bounce_back_owner(move, tgt, lab_cur, vw_pad, cw_own, budget_own, L,
                       P, use_grid):
    """Approximate cross-PE revert, owner-authoritative: each owner checks
    *its shard* against its budget slice, the overweight flags are
    gathered back, and bounced moves return their weight via a second
    commit. Same flags as the replicated check, O(L/P) persistent state."""
    over = all_gather_1d(cw_own > budget_own, "pe", P, use_grid=use_grid)
    bounce = move & over[tgt]
    vw_b = jnp.where(bounce, vw_pad, 0)
    b_in = jnp.zeros((L,), jnp.int32).at[lab_cur].add(vw_b, mode="drop")
    b_out = jnp.zeros((L,), jnp.int32).at[tgt].add(vw_b, mode="drop")
    cw_own = cw_own + psum_scatter_1d(b_in - b_out, "pe", P,
                                      use_grid=use_grid)
    return move & ~bounce, cw_own


def _fused_chunk_move(lab_src_tab, tab, cw, bud, vw_pad, c_idx, c_w, v0,
                      salt, n_loc, W, interpret):
    """Fused twin of ``_local_moves`` + ``_intra_pe_revert``: gather the
    chunk's ELL operands from the live tables and run the Pallas move
    kernel (diff-form admission, same salts/hash order — bit-identical).
    Returns ``(move, tgt)`` over the (n_loc+1,) src space."""
    R, _ = c_idx.shape
    rows = v0 + jnp.arange(R, dtype=jnp.int32)
    own = lab_src_tab[rows][:, None]         # clamp-gather: dup rows inert
    vwr = vw_pad[rows][:, None]
    valid = c_idx >= 0
    nlab = jnp.where(valid, tab[jnp.where(valid, c_idx, 0)], -1)
    safe_lab = jnp.where(valid, nlab, 0)
    ncw = jnp.where(valid, cw[safe_lab], I32_MAX)
    nbud = jnp.where(valid, bud[safe_lab], 0)
    scal = jnp.concatenate([
        jnp.reshape(W.astype(jnp.int32), (1, 1)),
        jnp.reshape(v0.astype(jnp.int32), (1, 1))], axis=1)
    moved, tgt = lp_move_chunk(nlab, c_w, ncw, own, vwr, scal,
                               jnp.reshape(salt, (1, 1)), nbud=nbud,
                               fit_sum=False, row_tile=move_ops.ROW_TILE,
                               interpret=interpret)
    move = jnp.zeros((n_loc + 1,), jnp.bool_).at[rows].set(
        moved[:, 0] != 0, mode="drop")
    tgt_full = lab_src_tab.at[rows].set(tgt[:, 0], mode="drop")
    return move, tgt_full


# ---------------------------------------------------------------------------
# distributed clustering
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=32)
def _build_cluster_fn(mesh, P, n, n_loc, n_ghost, B, num_iterations,
                      use_grid, owner=False, fused=False, interpret=True):
    num_labels = n + 1           # label values are global vertex ids
    S_w = owner_table_width(num_labels, P)
    # owner mode pads the dense *transient* view so P shards tile it;
    # only the (S_w,) shard persists across chunks
    L = P * S_w if owner else num_labels

    def per_pe(slab_a, slab_b, slab_c, vw_loc, lgid, ggid, send_idx,
               recv_slot, salts, W):
        # slabs are (src, dst, w) arc chunks, or (idx, w, v0) ELL chunks
        # when the fused Pallas move kernel is active
        slab_a, slab_b, slab_c = slab_a[0], slab_b[0], slab_c[0]
        vw_loc, lgid, ggid = vw_loc[0], lgid[0], ggid[0]
        send_idx, recv_slot = send_idx[0], recv_slot[0]
        vw_pad = jnp.concatenate([vw_loc, jnp.zeros((1,), jnp.int32)])
        # global per-cluster weights: every vertex starts as a singleton
        # so cw == scattered vertex weights
        dense0 = jnp.zeros((L,), jnp.int32).at[lgid].add(vw_loc,
                                                         mode="drop")
        if owner:
            cw_state = psum_scatter_1d(dense0, "pe", P, use_grid=use_grid)
            gidx = lax.axis_index("pe") * S_w + \
                jnp.arange(S_w, dtype=jnp.int32)
            cw_state = jnp.where(gidx == n, _BIG, cw_state)
            budget_own = jnp.where(gidx == n, -_BIG, W).astype(jnp.int32)
        else:
            cw_state = lax.psum(dense0, "pe")
            cw_state = cw_state.at[n].set(_BIG)  # sentinel never a target
            budget = jnp.full((L,), W, jnp.int32).at[n].set(-_BIG)
        lab_loc = lgid.astype(jnp.int32)     # own global id = own cluster
        lab_ghost = ggid.astype(jnp.int32)

        def chunk_body(carry, xs):
            lab_loc, lab_ghost, cw_state = carry
            # owner mode: request current weights from the owners (the
            # dense views live only inside this chunk body)
            if owner:
                cw = all_gather_1d(cw_state, "pe", P, use_grid=use_grid)
                bud = jnp.full((L,), W, jnp.int32).at[n].set(-_BIG)
            else:
                cw, bud = cw_state, budget
            tab = jnp.concatenate(
                [lab_loc, lab_ghost, jnp.full((1,), n, jnp.int32)])
            lab_src_tab = jnp.concatenate(
                [lab_loc, jnp.full((1,), n, jnp.int32)])
            if fused:
                c_idx, c_w, v0, salt = xs
                move, tgt = _fused_chunk_move(
                    lab_src_tab, tab, cw, bud, vw_pad, c_idx, c_w, v0,
                    salt, n_loc, W, interpret)
                lab_cur = lab_src_tab
            else:
                c_src, c_dst, c_w, salt = xs
                move, tgt, lab_cur = _local_moves(
                    lab_src_tab, tab, cw, bud, vw_pad, c_src, c_dst, c_w,
                    salt, n_loc, cluster_mode=True)
                vw_m = jnp.where(move, vw_pad, 0)
                d_in = jnp.zeros((L,), jnp.int32).at[tgt].add(
                    vw_m, mode="drop")
                d_out = jnp.zeros((L,), jnp.int32).at[lab_cur].add(
                    vw_m, mode="drop")
                move = _intra_pe_revert(move, tgt, lab_cur, vw_pad, cw,
                                        d_in, d_out, salt, n_loc, L, W)
            if owner:
                cw_state = _commit_to_owners(move, tgt, lab_cur, vw_pad,
                                             cw_state, L, P, use_grid)
                move, cw_state = _bounce_back_owner(
                    move, tgt, lab_cur, vw_pad, cw_state, budget_own, L,
                    P, use_grid)
            else:
                cw_state = _apply_and_sync(move, tgt, lab_cur, vw_pad,
                                           cw_state, L)
                move, cw_state = _bounce_back(move, tgt, lab_cur, vw_pad,
                                              cw_state, bud, L)
            lab_loc = jnp.where(move[:n_loc], tgt[:n_loc], lab_loc)
            lab_ghost = halo_exchange(lab_loc, send_idx, recv_slot,
                                      n_ghost, "pe", P, use_grid=use_grid)
            return (lab_loc, lab_ghost, cw_state), ()

        for it in range(num_iterations):
            (lab_loc, lab_ghost, cw_state), _ = lax.scan(
                chunk_body, (lab_loc, lab_ghost, cw_state),
                (slab_a, slab_b, slab_c, salts[it]))
        return lab_loc[None]

    pe = PS("pe")
    rep = PS()
    # check_rep: pallas_call has no replication rule under shard_map
    fn = shard_map(per_pe, mesh=mesh,
                   in_specs=(pe, pe, pe, pe, pe, pe, pe, pe, rep, rep),
                   out_specs=pe, check_rep=not fused)
    return jax.jit(fn)


def dist_cluster(shards: GraphShards,
                 max_cluster_weight: int,
                 num_iterations: int = 3,
                 num_chunks: int = 8,
                 seed: int = 0,
                 use_grid: bool = True,
                 mesh: Mesh = None,
                 weights: str = "replicated",
                 kernel: str = "auto") -> np.ndarray:
    """Distributed size-constrained LP clustering over graph shards.

    Returns (n,) int64 global cluster labels (label values are vertex
    ids). Cluster weights respect ``max_cluster_weight`` up to cross-PE
    race tolerance; callers contract only after exact host-side
    enforcement. ``weights`` picks the table layout (module docstring)
    and ``kernel`` the chunk-move implementation (``kernels.dispatch``);
    every combination returns bit-identical labels.
    """
    P, n = shards.P, shards.n
    owner = _check_weights_mode(weights)
    _check_int32_weights(shards)
    mesh = _resolve_mesh(mesh, P)
    fused = dispatch.resolve_kernel_mode(kernel) == "fused"
    if fused:
        idx, ws_ell, v0s = move_ops.build_move_chunks_dist(
            shards, num_chunks)
        _, B, R, D = idx.shape
        est = lp_move_vmem_bytes(R, D, move_ops.ROW_TILE, fit_sum=False)
        if est > dispatch.VMEM_BUDGET_BYTES:
            dispatch.report_fallback("lp_move", est,
                                     detail="dist_cluster")
            fused = False
        else:
            slabs = (jnp.asarray(idx), jnp.asarray(ws_ell),
                     jnp.asarray(v0s))
    if not fused:
        srcs, dsts, ws = chunk_local_arcs(shards, num_chunks)
        B = srcs.shape[1]
        slabs = (jnp.asarray(srcs), jnp.asarray(dsts), jnp.asarray(ws))
    fn = _build_cluster_fn(mesh, P, n, shards.n_loc, shards.n_ghost, B,
                           num_iterations, use_grid, owner, fused=fused,
                           interpret=dispatch.kernel_interpret())
    salts = (np.arange(num_iterations * B, dtype=np.uint64).reshape(
        num_iterations, B) * 0x85EBCA6B + seed * 1000003) % (2**32)
    lab = fn(*slabs,
             jnp.asarray(shards.vweights), jnp.asarray(shards.local_gid),
             jnp.asarray(shards.ghost_gid), jnp.asarray(shards.send_idx),
             jnp.asarray(shards.recv_slot),
             jnp.asarray(salts.astype(np.uint32)),
             jnp.int32(max(1, min(int(max_cluster_weight), int(_BIG)))))
    lab = np.asarray(lab)
    out = np.empty(n, dtype=np.int64)
    valid = shards.local_gid < n
    out[shards.local_gid[valid]] = lab[valid]
    return out


# ---------------------------------------------------------------------------
# distributed k-way refinement
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=32)
def _build_refine_fn(mesh, P, k, n_loc, n_ghost, B, num_iterations,
                     use_grid, owner=False):
    kk = k + 1                   # sentinel block k
    S_k = owner_table_width(kk, P)
    L = P * S_k if owner else kk

    def per_pe(src, dst, w, vw_loc, part_loc, part_ghost, send_idx,
               recv_slot, salts, l_max):
        src, dst, w = src[0], dst[0], w[0]
        vw_loc, part_loc, part_ghost = vw_loc[0], part_loc[0], part_ghost[0]
        send_idx, recv_slot = send_idx[0], recv_slot[0]
        vw_pad = jnp.concatenate([vw_loc, jnp.zeros((1,), jnp.int32)])
        dense0 = jnp.zeros((L,), jnp.int32).at[part_loc].add(vw_loc,
                                                             mode="drop")
        budget = jnp.concatenate([l_max.astype(jnp.int32),
                                  jnp.full((L - k,), -_BIG, jnp.int32)])
        if owner:
            bw_state = psum_scatter_1d(dense0, "pe", P, use_grid=use_grid)
            gidx = lax.axis_index("pe") * S_k + \
                jnp.arange(S_k, dtype=jnp.int32)
            bw_state = jnp.where(gidx == k, _BIG, bw_state)
            budget_own = lax.dynamic_slice(
                budget, (lax.axis_index("pe") * S_k,), (S_k,))
        else:
            bw_state = lax.psum(dense0, "pe")
            bw_state = bw_state.at[k].set(_BIG)

        def chunk_body(carry, xs):
            lab_loc, lab_ghost, bw_state = carry
            c_src, c_dst, c_w, salt = xs
            bw = all_gather_1d(bw_state, "pe", P, use_grid=use_grid) \
                if owner else bw_state
            tab = jnp.concatenate(
                [lab_loc, lab_ghost, jnp.full((1,), k, jnp.int32)])
            lab_src_tab = jnp.concatenate(
                [lab_loc, jnp.full((1,), k, jnp.int32)])
            move, tgt, lab_cur = _local_moves(
                lab_src_tab, tab, bw, budget, vw_pad, c_src, c_dst, c_w,
                salt, n_loc, cluster_mode=False)
            if owner:
                bw_state = _commit_to_owners(move, tgt, lab_cur, vw_pad,
                                             bw_state, L, P, use_grid)
                move, bw_state = _bounce_back_owner(
                    move, tgt, lab_cur, vw_pad, bw_state, budget_own, L,
                    P, use_grid)
            else:
                bw_state = _apply_and_sync(move, tgt, lab_cur, vw_pad,
                                           bw_state, L)
                move, bw_state = _bounce_back(move, tgt, lab_cur, vw_pad,
                                              bw_state, budget, L)
            lab_loc = jnp.where(move[:n_loc], tgt[:n_loc], lab_loc)
            lab_ghost = halo_exchange(lab_loc, send_idx, recv_slot,
                                      n_ghost, "pe", P, use_grid=use_grid)
            return (lab_loc, lab_ghost, bw_state), ()

        lab_loc = part_loc
        lab_ghost = part_ghost
        for it in range(num_iterations):
            (lab_loc, lab_ghost, bw_state), _ = lax.scan(
                chunk_body, (lab_loc, lab_ghost, bw_state),
                (src, dst, w, salts[it]))
        return lab_loc[None]

    pe = PS("pe")
    rep = PS()
    fn = shard_map(per_pe, mesh=mesh,
                   in_specs=(pe, pe, pe, pe, pe, pe, pe, pe, rep, rep),
                   out_specs=pe, check_rep=True)
    return jax.jit(fn)


def dist_lp_refine(shards: GraphShards,
                   part: np.ndarray,
                   l_max_vec: np.ndarray,
                   num_iterations: int = 2,
                   num_chunks: int = 8,
                   seed: int = 0,
                   use_grid: bool = True,
                   mesh: Mesh = None,
                   weights: str = "replicated") -> np.ndarray:
    """Distributed chunked LP refinement of a k-way partition.

    Same move rule as ``core.lp._refine_chunk`` (positive gain, or zero
    gain into the lighter block); block weights either replicated and
    psum-synced per chunk or owner-sharded (``weights``, module
    docstring), overweight blocks bouncing racing moves back either way.
    May leave the partition slightly infeasible; pair with a balancing
    pass.
    """
    P, n = shards.P, shards.n
    owner = _check_weights_mode(weights)
    _check_int32_weights(shards)
    k = int(l_max_vec.shape[0])
    mesh = _resolve_mesh(mesh, P)
    srcs, dsts, ws = chunk_local_arcs(shards, num_chunks)
    B = srcs.shape[1]
    fn = _build_refine_fn(mesh, P, k, shards.n_loc, shards.n_ghost, B,
                          num_iterations, use_grid, owner)
    part_pad = np.concatenate([part.astype(np.int64), [k]])  # sentinel gid=n
    part_loc = part_pad[np.minimum(shards.local_gid, n)].astype(np.int32)
    part_ghost = part_pad[np.minimum(shards.ghost_gid, n)].astype(np.int32)
    salts = (np.arange(num_iterations * B, dtype=np.uint64).reshape(
        num_iterations, B) * 0xC2B2AE35 + seed * 2654435761) % (2**32)
    lmax32 = np.minimum(l_max_vec, int(_BIG)).astype(np.int32)
    lab = fn(jnp.asarray(srcs), jnp.asarray(dsts), jnp.asarray(ws),
             jnp.asarray(shards.vweights), jnp.asarray(part_loc),
             jnp.asarray(part_ghost), jnp.asarray(shards.send_idx),
             jnp.asarray(shards.recv_slot),
             jnp.asarray(salts.astype(np.uint32)), jnp.asarray(lmax32))
    lab = np.asarray(lab)
    out = np.empty(n, dtype=np.int64)
    valid = shards.local_gid < n
    out[shards.local_gid[valid]] = lab[valid]
    return out


# ---------------------------------------------------------------------------
# distributed unconstrained (Jet-style) refinement
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=32)
def _build_urefine_fn(mesh, P, k, n_loc, n_ghost, B, num_iterations,
                      use_grid, owner=False):
    """shard_map program for one unconstrained refinement call: the
    ``_build_refine_fn`` skeleton with the penalized gain stage and *no*
    bounce-back — moves commit even when they overload the target, the
    weight tables track the overloaded truth, and the per-round penalty
    (a Python constant of the unrolled iteration loop) escalates
    ``it / num_iterations``. Both weight layouts stay bit-identical:
    they present the same dense table at the top of each chunk and
    commit the same deltas."""
    kk = k + 1                   # sentinel block k
    S_k = owner_table_width(kk, P)
    L = P * S_k if owner else kk

    def per_pe(src, dst, w, vw_loc, part_loc, part_ghost, send_idx,
               recv_slot, salts, l_max):
        src, dst, w = src[0], dst[0], w[0]
        vw_loc, part_loc, part_ghost = vw_loc[0], part_loc[0], part_ghost[0]
        send_idx, recv_slot = send_idx[0], recv_slot[0]
        vw_pad = jnp.concatenate([vw_loc, jnp.zeros((1,), jnp.int32)])
        dense0 = jnp.zeros((L,), jnp.int32).at[part_loc].add(vw_loc,
                                                             mode="drop")
        budget = jnp.concatenate([l_max.astype(jnp.int32),
                                  jnp.full((L - k,), -_BIG, jnp.int32)])
        if owner:
            bw_state = psum_scatter_1d(dense0, "pe", P, use_grid=use_grid)
            gidx = lax.axis_index("pe") * S_k + \
                jnp.arange(S_k, dtype=jnp.int32)
            bw_state = jnp.where(gidx == k, _BIG, bw_state)
        else:
            bw_state = lax.psum(dense0, "pe")
            bw_state = bw_state.at[k].set(_BIG)
        pen_den = jnp.int32(num_iterations)

        def make_chunk_body(pen_num):
            def chunk_body(carry, xs):
                lab_loc, lab_ghost, bw_state = carry
                c_src, c_dst, c_w, salt = xs
                bw = all_gather_1d(bw_state, "pe", P, use_grid=use_grid) \
                    if owner else bw_state
                tab = jnp.concatenate(
                    [lab_loc, lab_ghost, jnp.full((1,), k, jnp.int32)])
                lab_src_tab = jnp.concatenate(
                    [lab_loc, jnp.full((1,), k, jnp.int32)])
                move, tgt, lab_cur = _penalized_moves(
                    lab_src_tab, tab, bw, budget, vw_pad, c_src, c_dst,
                    c_w, salt, pen_num, pen_den, n_loc)
                if owner:
                    bw_state = _commit_to_owners(move, tgt, lab_cur,
                                                 vw_pad, bw_state, L, P,
                                                 use_grid)
                else:
                    bw_state = _apply_and_sync(move, tgt, lab_cur, vw_pad,
                                               bw_state, L)
                lab_loc = jnp.where(move[:n_loc], tgt[:n_loc], lab_loc)
                lab_ghost = halo_exchange(lab_loc, send_idx, recv_slot,
                                          n_ghost, "pe", P,
                                          use_grid=use_grid)
                return (lab_loc, lab_ghost, bw_state), ()
            return chunk_body

        lab_loc = part_loc
        lab_ghost = part_ghost
        for it in range(num_iterations):
            (lab_loc, lab_ghost, bw_state), _ = lax.scan(
                make_chunk_body(jnp.int32(it)),
                (lab_loc, lab_ghost, bw_state), (src, dst, w, salts[it]))
        return lab_loc[None]

    pe = PS("pe")
    rep = PS()
    fn = shard_map(per_pe, mesh=mesh,
                   in_specs=(pe, pe, pe, pe, pe, pe, pe, pe, rep, rep),
                   out_specs=pe, check_rep=True)
    return jax.jit(fn)


def dist_ulp_refine(shards: GraphShards,
                    part: np.ndarray,
                    l_max_vec: np.ndarray,
                    num_iterations: int = 2,
                    num_chunks: int = 8,
                    seed: int = 0,
                    use_grid: bool = True,
                    mesh: Mesh = None,
                    weights: str = "replicated") -> np.ndarray:
    """Distributed unconstrained (Jet-style) refinement of a k-way
    partition: penalty-weighted gains instead of the budget mask, no
    bounce-back. The result may overload blocks by design — callers MUST
    follow with ``rebalance`` / ``dist_rebalance`` (the afterburner;
    ``dist_partitioner.dist_refine_and_balance`` does). Block weight
    tables replicated or owner-sharded per ``weights``, bit-identical
    either way. Same chunking/salt streams as ``dist_lp_refine``."""
    P, n = shards.P, shards.n
    owner = _check_weights_mode(weights)
    _check_int32_weights(shards)
    k = int(l_max_vec.shape[0])
    mesh = _resolve_mesh(mesh, P)
    srcs, dsts, ws = chunk_local_arcs(shards, num_chunks)
    B = srcs.shape[1]
    fn = _build_urefine_fn(mesh, P, k, shards.n_loc, shards.n_ghost, B,
                           num_iterations, use_grid, owner)
    part_pad = np.concatenate([part.astype(np.int64), [k]])  # sentinel
    part_loc = part_pad[np.minimum(shards.local_gid, n)].astype(np.int32)
    part_ghost = part_pad[np.minimum(shards.ghost_gid, n)].astype(np.int32)
    salts = (np.arange(num_iterations * B, dtype=np.uint64).reshape(
        num_iterations, B) * 0xC2B2AE35 + seed * 2654435761) % (2**32)
    lmax32 = np.minimum(l_max_vec, int(_BIG)).astype(np.int32)
    lab = fn(jnp.asarray(srcs), jnp.asarray(dsts), jnp.asarray(ws),
             jnp.asarray(shards.vweights), jnp.asarray(part_loc),
             jnp.asarray(part_ghost), jnp.asarray(shards.send_idx),
             jnp.asarray(shards.recv_slot),
             jnp.asarray(salts.astype(np.uint32)), jnp.asarray(lmax32))
    lab = np.asarray(lab)
    out = np.empty(n, dtype=np.int64)
    valid = shards.local_gid < n
    out[shards.local_gid[valid]] = lab[valid]
    return out
