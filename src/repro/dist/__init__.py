"""Distributed subsystem (paper §4–5): named-axis sharding rules for the
launch/model layers, sparse all-to-all collectives, distributed LP
clustering and the distributed deep-MGP driver.

Import layering: ``sharding`` is dependency-light (models import it at
module load); the heavy shard_map machinery lives in ``collectives`` /
``dist_lp`` / ``dist_partitioner`` and is imported lazily by callers so
that merely importing a model never touches jax device state.
"""
from .sharding import (DEFAULT_RULES, NULL_CTX, ShardCtx, resolve_axes,
                       spec_shardings)

__all__ = ["DEFAULT_RULES", "NULL_CTX", "ShardCtx", "resolve_axes",
           "spec_shardings"]
