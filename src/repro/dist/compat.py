"""Version-compat shims for jax APIs the distributed subsystem relies on.

The repo targets the baked-in toolchain (jax 0.4.x) but keeps working on
newer releases where ``shard_map`` graduated out of ``jax.experimental``
and ``make_mesh`` grew an ``axis_types`` parameter.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax < 0.5
    from jax.experimental.shard_map import shard_map  # noqa: F401


def make_mesh(axis_shapes, axis_names):
    """jax.make_mesh that tolerates the absence of AxisType (jax 0.4.x)."""
    if hasattr(jax.sharding, "AxisType"):
        try:
            return jax.make_mesh(
                axis_shapes, axis_names,
                axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
        except TypeError:
            pass
    return jax.make_mesh(axis_shapes, axis_names)
