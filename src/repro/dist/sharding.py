"""Named-axis sharding rules: logical parameter/activation axes -> mesh axes.

Every model declares *logical* axes on its ``ParamSpec``s and activation
constraints ('batch', 'embed', 'mlp', ...). This module owns the single
mapping from those names to physical mesh axes ('data', 'model', ...),
with a hard invariant: **the planner never produces an invalid sharding**
— a dim that is not divisible by its mesh axis, or a mesh axis used twice
in one spec, silently falls back to replication for that dim.
"""
from __future__ import annotations

from typing import Any, Dict, Mapping, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

# logical axis -> mesh axis (or tuple of mesh axes). Axes absent from the
# map (or mapped to None) replicate. 'embed' stays replicated on purpose:
# it co-occurs with 'mlp'/'heads'/'vocab' in every matmul param, and those
# carry the model-parallel split.
DEFAULT_RULES: Dict[str, Any] = {
    # data-parallel activation axes
    "batch": "data",
    "nodes": "data",
    "edges": "data",
    # model-parallel (tensor) axes
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    "table": "model",
    # sequence / feature / stacked-layer axes replicate by default
    "seq": None,
    "act_seq": None,
    "feat": None,
    "embed": None,
    "head_dim": None,
    "table_dim": None,
    "stack": None,
}


def _mesh_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def resolve_axes(shape: Sequence[int],
                 axes: Sequence[Optional[str]],
                 mesh: Mesh,
                 rules: Mapping[str, Any] = DEFAULT_RULES) -> PS:
    """Map logical ``axes`` of an array of ``shape`` to a PartitionSpec.

    Falls back to replication per-dim whenever the rule's mesh axis is
    absent from the mesh, already consumed by an earlier dim, trivial
    (size 1), or does not divide the dim.
    """
    sizes = _mesh_sizes(mesh)
    used: set = set()
    spec = []
    for dim, logical in zip(shape, axes):
        target = rules.get(logical) if logical is not None else None
        if target is None:
            spec.append(None)
            continue
        names: Tuple[str, ...] = (target,) if isinstance(target, str) \
            else tuple(target)
        prod = 1
        ok = True
        for nm in names:
            if nm not in sizes or nm in used or sizes[nm] <= 1:
                ok = False
                break
            prod *= sizes[nm]
        if not ok or prod <= 1 or dim % prod != 0:
            spec.append(None)
            continue
        used.update(names)
        spec.append(names[0] if len(names) == 1 else names)
    return PS(*spec)


def spec_shardings(specs, mesh: Mesh,
                   rules: Mapping[str, Any] = DEFAULT_RULES):
    """ParamSpec tree -> NamedSharding tree (same structure)."""
    from ..models.common import is_spec
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, resolve_axes(s.shape, s.axes, mesh,
                                                   rules)),
        specs, is_leaf=is_spec)


class ShardCtx:
    """Sharding context threaded through model forward passes.

    ``constrain(x, *logical_axes)`` annotates intermediate activations so
    GSPMD keeps them distributed; with no mesh (``NULL_CTX``) every call
    is the identity, so models run unmodified on a single device.
    """

    def __init__(self, mesh: Optional[Mesh] = None,
                 rules: Mapping[str, Any] = DEFAULT_RULES):
        self.mesh = mesh
        self.rules = rules

    def constrain(self, x, *axes: Optional[str]):
        if self.mesh is None:
            return x
        spec = resolve_axes(x.shape, axes, self.mesh, self.rules)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def data_groups(self) -> int:
        """Number of shards along the data-parallel axis (>= 1) — the
        group count for group-local MoE dispatch."""
        if self.mesh is None:
            return 1
        target = self.rules.get("batch")
        if target is None:
            return 1
        names = (target,) if isinstance(target, str) else tuple(target)
        sizes = _mesh_sizes(self.mesh)
        g = 1
        for nm in names:
            g *= sizes.get(nm, 1)
        return max(1, g)

    def __repr__(self) -> str:
        return f"ShardCtx(mesh={None if self.mesh is None else self.mesh.axis_names})"


NULL_CTX = ShardCtx(None)
