"""Distributed deep multilevel graph partitioning driver (paper Alg. 1).

Mirrors ``core/deep_mgp.py``: while the graph is large it coarsens with
*distributed* LP clustering over graph shards; once the graph fits one
PE's budget it delegates to the single-process deep-MGP path (the paper's
own base case: after log P contractions the coarse graph is gathered and
partitioned on fewer PEs). Uncoarsening projects through the contraction
maps and runs distributed refinement + balancing per level.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from ..core import metrics
from ..core.balance import rebalance
from ..core.coarsening import enforce_cluster_weights
from ..core.contraction import contract
from ..core.deep_mgp import PartitionerConfig
from ..core.partitioner import partition as sp_partition
from ..graphs.distribute import distribute_graph
from ..graphs.format import Graph
from .dist_lp import dist_cluster, dist_lp_refine


def dist_refine_and_balance(g: Graph,
                            part: np.ndarray,
                            l_max_vec: np.ndarray,
                            P: int,
                            num_iterations: int = 2,
                            num_chunks: int = 8,
                            seed: int = 0,
                            use_grid: bool = True) -> np.ndarray:
    """Distributed BalanceAndRefine: sharded LP refinement (block weights
    psum-synced, races bounced) followed by the exact global balancer so
    the result always satisfies the per-block budgets."""
    part = np.asarray(part, dtype=np.int64)
    l_max_vec = np.asarray(l_max_vec, dtype=np.int64)
    shards = distribute_graph(g, P)
    part = dist_lp_refine(shards, part, l_max_vec,
                          num_iterations=num_iterations,
                          num_chunks=num_chunks, seed=seed,
                          use_grid=use_grid)
    part = rebalance(g, part, l_max_vec, seed=seed + 1)
    return part


def dist_partition(g: Graph,
                   k: int,
                   P: int,
                   cfg: Optional[PartitionerConfig] = None,
                   use_grid: bool = True) -> np.ndarray:
    """Distributed deep multilevel k-way partition over P PEs.

    Returns (n,) int64 block ids satisfying the paper's relaxed balance
    constraint. Matches the single-process reference pipeline except that
    fine levels cluster and refine under shard_map.
    """
    cfg = cfg or PartitionerConfig()
    if k <= 1 or g.n == 0:
        return np.zeros(g.n, dtype=np.int64)
    total_c = g.total_vweight
    l_final = metrics.l_max(total_c, k, cfg.epsilon,
                            int(g.vweights.max()) if g.n else 1)
    C, K = cfg.contraction_limit, cfg.initial_k

    # ---- distributed deep coarsening -----------------------------------
    hierarchy: List[Tuple[Graph, np.ndarray]] = []
    G = g
    level = 0
    while G.n > C * min(k, K) and G.n >= 2 * P and level < cfg.max_levels:
        kprime = max(1, min(k, G.n // max(1, C)))
        W = max(1, int(cfg.epsilon * total_c / kprime))
        shards = distribute_graph(G, P)
        labels = dist_cluster(shards, W,
                              num_iterations=cfg.cluster_iterations,
                              num_chunks=cfg.num_chunks,
                              seed=cfg.seed + level, use_grid=use_grid)
        labels = enforce_cluster_weights(labels, np.asarray(G.vweights), W)
        Gc, mapping = contract(G, labels)
        if Gc.n >= G.n * cfg.min_shrink:
            break  # converged — coarsest distributed level reached
        hierarchy.append((G, mapping))
        G = Gc
        level += 1

    # ---- base case: single-process deep MGP on the coarse graph --------
    part = sp_partition(G, k, config=cfg)

    # ---- uncoarsening: project + distributed refine/balance ------------
    lvec = np.full(k, l_final, dtype=np.int64)
    for (Gf, mapping) in reversed(hierarchy):
        part = part[mapping]
        part = dist_refine_and_balance(
            Gf, part, lvec, P, num_iterations=cfg.refine_iterations,
            num_chunks=cfg.num_chunks,
            seed=cfg.seed + Gf.n % 1000003, use_grid=use_grid)
    return part
