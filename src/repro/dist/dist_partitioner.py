"""Distributed deep multilevel graph partitioning driver (paper Alg. 1).

Mirrors ``core/deep_mgp.py``: while the graph is large it coarsens with
*distributed* LP clustering over graph shards; once the graph fits one
PE's budget it delegates to the single-process deep-MGP path (the paper's
own base case: after log P contractions the coarse graph is gathered and
partitioned on fewer PEs). Uncoarsening projects through the contraction
maps and runs distributed refinement + balancing per level, reusing the
shards built during coarsening — each level is distributed exactly once.

Two ``PartitionerConfig`` knobs select the distributed memory model
(docs/DIST.md): ``contraction`` ("host" gathers each level and contracts
via ``core.contraction``; "sharded" contracts in place via the paper-§5
cluster→PE assignment + all-to-all edge exchange of
``dist_contraction``) and ``weights`` ("replicated" psum-synced tables
vs "owner"-sharded authoritative tables in ``dist_lp``). The defaults
("host"/"replicated") reproduce the original pipeline bit-for-bit.

The public surface is ``repro.api`` (backend names ``"dist"`` /
``"dist-grid"``), which calls ``dist_partition_impl`` and can reuse one
mesh across requests; the old ``dist_partition`` shim is gone.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core import metrics
from ..core.balance import rebalance
from ..core.coarsening import enforce_cluster_weights
from ..core.contraction import contract
from ..core.deep_mgp import (PartitionerConfig, check_k,
                             partition as sp_partition, trace_event,
                             uncoarsen_seed)
from ..graphs.distribute import GraphShards, distribute_graph
from ..graphs.format import Graph
from .dist_balance import dist_enforce_cluster_weights, dist_rebalance
from .dist_contraction import dist_contract
from .dist_lp import dist_cluster, dist_lp_refine, dist_ulp_refine


def dist_refine_and_balance(g: Graph,
                            part: np.ndarray,
                            l_max_vec: np.ndarray,
                            P: int,
                            num_iterations: int = 2,
                            num_chunks: int = 8,
                            seed: int = 0,
                            use_grid: bool = True,
                            mesh=None,
                            shards: Optional[GraphShards] = None,
                            weights: str = "replicated",
                            balance: str = "host",
                            kernel: str = "auto",
                            refine: str = "lp",
                            balance_stats: Optional[Dict] = None
                            ) -> np.ndarray:
    """Distributed BalanceAndRefine: sharded refinement (block weights
    replicated or owner-sharded per ``weights``) followed by the exact
    global balancer so the result always satisfies the per-block
    budgets. ``shards`` lets the driver pass the level's existing
    distribution instead of re-sharding ``g``.

    ``refine`` picks the improvement pass: ``"lp"`` (default) is the
    size-constrained LP with races bounced, ``"unconstrained"`` the
    Jet-style penalty-weighted search of ``dist_ulp_refine`` whose
    overloads the trailing balancer repairs (the afterburner —
    docs/REFINEMENT.md). ``balance`` picks where that exact balancer
    runs: ``"host"`` gathers the level into
    ``core.balance.rebalance``'s single-chunk arc slab (one O(m) gather
    per call), ``"dist"`` runs ``dist_balance.dist_rebalance`` over the
    same shards the refinement used — no host gather, O(P·top_m) pooled
    candidates per round, bit-identical to ``"host"`` at P=1."""
    from ..core.refinement import check_refine_mode
    check_refine_mode(refine)
    part = np.asarray(part, dtype=np.int64)
    l_max_vec = np.asarray(l_max_vec, dtype=np.int64)
    if shards is None:
        shards = distribute_graph(g, P)
    if refine == "unconstrained":
        part = dist_ulp_refine(shards, part, l_max_vec,
                               num_iterations=num_iterations,
                               num_chunks=num_chunks, seed=seed,
                               use_grid=use_grid, mesh=mesh,
                               weights=weights)
    else:
        part = dist_lp_refine(shards, part, l_max_vec,
                              num_iterations=num_iterations,
                              num_chunks=num_chunks, seed=seed,
                              use_grid=use_grid, mesh=mesh,
                              weights=weights)
    if balance == "dist":
        part = dist_rebalance(shards, part, l_max_vec, seed=seed + 1,
                              use_grid=use_grid, mesh=mesh,
                              weights=weights, kernel=kernel,
                              stats=balance_stats)
    else:
        part = rebalance(g, part, l_max_vec, seed=seed + 1, kernel=kernel,
                         stats=balance_stats)
    return part


def dist_partition_impl(g: Graph,
                        k: int,
                        P: int,
                        cfg: Optional[PartitionerConfig] = None,
                        use_grid: bool = True,
                        mesh=None,
                        trace: Optional[List[Dict]] = None) -> np.ndarray:
    """Distributed deep multilevel k-way partition over P PEs.

    Returns (n,) int64 block ids satisfying the paper's relaxed balance
    constraint. Matches the single-process reference pipeline except that
    fine levels cluster, contract and refine under shard_map. ``mesh``
    lets a serving session reuse one 1D 'pe' mesh across requests;
    ``trace`` collects per-level size/cut/timing records.
    """
    cfg = (cfg or PartitionerConfig()).validate()
    check_k(k, "dist_partition")
    if P < 1:
        raise ValueError(f"dist_partition: P must be >= 1, got {P}")
    if k == 1 or g.n == 0:
        return np.zeros(g.n, dtype=np.int64)
    total_c = g.total_vweight
    l_final = metrics.l_max(total_c, k, cfg.epsilon,
                            int(g.vweights.max()) if g.n else 1)
    C, K = cfg.contraction_limit, cfg.initial_k

    # ---- distributed deep coarsening -----------------------------------
    # hierarchy rows carry the level's shards so uncoarsening reuses them
    # instead of re-distributing the same graph
    hierarchy: List[Tuple[Graph, np.ndarray, GraphShards]] = []
    G = g
    shards: Optional[GraphShards] = None
    level = 0
    while G.n > C * min(k, K) and G.n >= 2 * P and level < cfg.max_levels:
        kprime = max(1, min(k, G.n // max(1, C)))
        W = max(1, int(cfg.epsilon * total_c / kprime))
        t0 = time.perf_counter()
        if shards is None:  # sharded contraction hands us the next level
            shards = distribute_graph(G, P)
        labels = dist_cluster(shards, W,
                              num_iterations=cfg.cluster_iterations,
                              num_chunks=cfg.num_chunks,
                              seed=cfg.seed + level, use_grid=use_grid,
                              mesh=mesh, weights=cfg.weights,
                              kernel=cfg.kernel)
        if cfg.balance == "dist":
            # coarsening-side balancing stays sharded: the exact
            # eject-to-singleton sweep runs owner-side instead of
            # round-tripping the labels through host numpy
            labels = dist_enforce_cluster_weights(
                shards, labels, W, use_grid=use_grid, mesh=mesh)
        else:
            labels = enforce_cluster_weights(labels,
                                             np.asarray(G.vweights), W)
        if cfg.contraction == "sharded":
            res = dist_contract(shards, labels, use_grid=use_grid,
                                mesh=mesh, kernel=cfg.kernel)
            Gc, mapping, next_shards = res.graph, res.mapping, res.shards
            cstats = res.stats
        else:
            Gc, mapping = contract(G, labels, kernel=cfg.kernel)
            next_shards, cstats = None, None
        if Gc.n >= G.n * cfg.min_shrink:
            # converged — coarsest distributed level reached; record the
            # discarded level so benchmark traces explain the early exit
            trace_event(trace, phase="dist-coarsen-converged", level=level,
                        n=G.n, m=G.m, coarse_n=Gc.n, W=W, P=P,
                        time_s=round(time.perf_counter() - t0, 6))
            break
        rec = dict(phase="dist-coarsen", level=level, n=G.n, m=G.m,
                   coarse_n=Gc.n, W=W, P=P, contraction=cfg.contraction,
                   weights=cfg.weights,
                   time_s=round(time.perf_counter() - t0, 6))
        if cstats is not None:
            rec.update(exchange_s=cstats["exchange_s"],
                       payload_bytes=cstats["payload_bytes"])
        trace_event(trace, **rec)
        hierarchy.append((G, mapping, shards))
        G, shards = Gc, next_shards
        level += 1

    # ---- base case: single-process deep MGP on the coarse graph --------
    part = sp_partition(G, k, cfg, trace=trace)

    # ---- uncoarsening: project + distributed refine/balance ------------
    lvec = np.full(k, l_final, dtype=np.int64)
    for lvl, (Gf, mapping, fshards) in enumerate(reversed(hierarchy)):
        t0 = time.perf_counter()
        part = part[mapping]
        lvl_seed = uncoarsen_seed(cfg.seed, lvl, stream=1)
        bal_stats: Dict = {}
        part = dist_refine_and_balance(
            Gf, part, lvec, P, num_iterations=cfg.refine_iterations,
            num_chunks=cfg.num_chunks,
            seed=lvl_seed, use_grid=use_grid, mesh=mesh,
            shards=fshards, weights=cfg.weights, balance=cfg.balance,
            kernel=cfg.kernel, refine=cfg.refine,
            balance_stats=bal_stats)
        if trace is not None:
            rec = dict(phase="dist-uncoarsen", level=lvl, n=Gf.n,
                       m=Gf.m, blocks=k, P=P, seed=lvl_seed,
                       balance=cfg.balance,
                       balance_rounds=bal_stats.get("rounds"),
                       cut=metrics.edge_cut(Gf, part),
                       time_s=round(time.perf_counter() - t0, 6))
            if cfg.refine != "lp":
                # unconstrained tier: the balancer doubles as the
                # feasibility afterburner, so balance_rounds IS the
                # repair-round count (docs/REFINEMENT.md)
                from ..core.unconstrained import penalty_schedule
                rec.update(refine=cfg.refine,
                           penalty=penalty_schedule(cfg.refine_iterations),
                           repair_rounds=bal_stats.get("rounds"))
            trace_event(trace, **rec)
    from ..kernels import dispatch
    for rec in dispatch.drain_fallback_records():
        trace_event(trace, **rec)
    return part


# The deprecated ``dist_partition`` shim was removed after its release
# of grace: route through ``repro.api`` (backends "dist" / "dist-grid"),
# which calls ``dist_partition_impl`` — see docs/API.md.
