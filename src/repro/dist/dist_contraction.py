"""Distributed cluster contraction (paper §5, Graph Contraction).

The host-side ``core.contraction.contract`` gathers the whole fine graph
to one process; here each level stays sharded:

  1. **cluster → PE ownership** — clusters are assigned to PEs by a
     multiplicative hash of the cluster id (the paper's load-spreading
     assignment) and renumbered so each owner holds a contiguous coarse
     id range (the layout every downstream shard_map kernel expects).
  2. **local pre-contraction** — every PE maps its own arc slab through
     the cluster mapping and runs the shared sequential kernel
     (``core.contraction.dedup_arcs``) over its local arcs only, so the
     exchange ships deduplicated coarse arcs instead of raw fine arcs.
  3. **segmented all-to-all edge exchange** — pre-contracted arcs are
     routed to the owner of their coarse tail through
     ``collectives.exchange_segments`` (direct or two-level grid), with
     the owner-side duplicate merge running inside the same jitted
     program (sort + segment-sum, mirroring the kernel of step 2).
  4. **owner-side assembly** — owners hold the final coarse arc and
     vertex-weight shards; ``graphs.distribute.assemble_shards`` turns
     them into the next level's ``GraphShards`` without re-sharding.

Segment sizes are exact (the host knows the cluster assignment when it
pads the exchange slab), so the padded slab is ~m/P per PE rather than a
worst-case bound. The coarse graph's host view is assembled only for the
phases that are host-side by design (the single-process base case and
the exact balancer); no PE's device state ever exceeds O(n/P + k).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as PS

from ..core.contraction import dedup_arcs
from ..core.lp import I32_MAX
from ..graphs.distribute import GraphShards, assemble_shards
from ..graphs.format import Graph, from_coo
from ..kernels import dispatch
from ..kernels.seg_merge.seg_merge import seg_merge, seg_merge_vmem_bytes
from .collectives import exchange_segments
from .compat import shard_map
from .dist_lp import _check_int32_weights, _resolve_mesh


@dataclasses.dataclass(frozen=True)
class DistContraction:
    """Result of one sharded contraction level."""
    shards: GraphShards      # coarse graph, contiguous per-owner ranges
    graph: Graph             # host view (base case / exact balancer only)
    mapping: np.ndarray      # (n_fine,) int64 fine gid -> coarse gid
    stats: Dict              # exchange payload / timing for benchmarks


def cluster_owners(cluster_ids: np.ndarray, P: int) -> np.ndarray:
    """Hash-based cluster → PE assignment (paper §5): spreads ownership
    independently of the id distribution the clustering produced."""
    h = (cluster_ids.astype(np.uint64) * np.uint64(2654435761)) \
        & np.uint64(0xFFFFFFFF)
    h ^= np.uint64(0x9E3779B9)
    h ^= h >> np.uint64(15)
    return (h % np.uint64(max(1, P))).astype(np.int64)


def _next_pow2(x: int) -> int:
    return 1 << max(0, (int(x) - 1)).bit_length()


@functools.lru_cache(maxsize=32)
def _build_exchange_fn(mesh, P: int, S_e: int, use_grid: bool,
                       fused: bool = False, interpret: bool = True):
    """Jitted program: segmented all-to-all of (src, dst, w) coarse-arc
    records followed by the owner-side duplicate merge (sort by arc key,
    segment-sum the weights — or the fused seg_merge Pallas kernel,
    bit-identical)."""
    L = P * S_e

    def per_pe(slab, counts):
        slab, counts = slab[0], counts[0]
        recv, rcounts = exchange_segments(slab, counts, "pe", P,
                                          use_grid=use_grid)
        valid = jnp.arange(S_e, dtype=jnp.int32)[None, :] < \
            rcounts[:, None]                                  # (P, S_e)
        src = jnp.where(valid, recv[:, :, 0], I32_MAX).reshape(L)
        dst = jnp.where(valid, recv[:, :, 1], I32_MAX).reshape(L)
        w = jnp.where(valid, recv[:, :, 2], 0).reshape(L)
        if fused:
            s_src, s_dst, tot, first32 = seg_merge(src, dst, w,
                                                   interpret=interpret)
            return (s_src[None], s_dst[None], tot[None],
                    (first32 != 0)[None])
        s_src, s_dst, s_w = lax.sort((src, dst, w), num_keys=2)
        first = jnp.concatenate([
            jnp.ones((1,), jnp.bool_),
            (s_src[1:] != s_src[:-1]) | (s_dst[1:] != s_dst[:-1])])
        gid = jnp.cumsum(first.astype(jnp.int32)) - 1
        tot = jax.ops.segment_sum(s_w, gid, num_segments=L,
                                  indices_are_sorted=True)
        return (s_src[None], s_dst[None], tot[gid][None],
                first[None])

    pe = PS("pe")
    fn = shard_map(per_pe, mesh=mesh, in_specs=(pe, pe),
                   out_specs=(pe, pe, pe, pe), check_rep=not fused)
    return jax.jit(fn)


def _global_vweights(shards: GraphShards) -> np.ndarray:
    vw = np.zeros(shards.n, dtype=np.int64)
    valid = shards.local_gid < shards.n
    vw[shards.local_gid[valid]] = shards.vweights[valid]
    return vw


def dist_contract(shards: GraphShards,
                  labels: np.ndarray,
                  use_grid: bool = False,
                  mesh=None,
                  kernel: str = "auto") -> DistContraction:
    """Contract clustering ``labels`` over graph shards without gathering
    the fine graph. Returns the coarse graph both as shards (fed straight
    into the next level's distributed clustering) and as a host view
    (consumed only by the host-side base case / exact balancer), plus the
    fine→coarse mapping used for uncoarsening projection.
    """
    P, n = shards.P, shards.n
    labels = np.asarray(labels, dtype=np.int64)
    assert labels.shape == (n,), (labels.shape, n)
    _check_int32_weights(shards)   # the exchange slab is int32
    mesh = _resolve_mesh(mesh, P)

    # ---- ownership + owner-contiguous renumbering ----------------------
    uniq, inv = np.unique(labels, return_inverse=True)
    nc = int(uniq.size)
    owner = cluster_owners(uniq, P)
    order = np.lexsort((uniq, owner))       # group clusters by owner PE
    rank = np.empty(nc, dtype=np.int64)
    rank[order] = np.arange(nc)
    mapping = rank[inv]
    coff = np.concatenate(
        [[0], np.cumsum(np.bincount(owner, minlength=P))]).astype(np.int64)

    # coarse vertex weights, accumulated into owner slices
    cvw = np.zeros(nc, dtype=np.int64)
    np.add.at(cvw, mapping, _global_vweights(shards))

    # ---- per-PE local pre-contraction (shared sequential kernel) -------
    kmode = dispatch.resolve_kernel_mode(kernel)
    t0 = time.perf_counter()
    pre_parts = []
    seg_counts = np.zeros((P, P), dtype=np.int32)
    for p in range(P):
        valid = shards.arc_src[p] < shards.n_loc
        src_g = shards.local_gid[p][shards.arc_src[p][valid]]
        tab_g = np.concatenate([shards.local_gid[p], shards.ghost_gid[p]])
        dst_g = tab_g[shards.arc_dst_idx[p][valid]]
        cs, cd, cw = dedup_arcs(mapping[src_g], mapping[dst_g],
                                shards.arc_w[p][valid].astype(np.int64),
                                kernel=kmode)
        # dedup_arcs sorts by coarse tail; owner ranges are contiguous in
        # coarse-id space, so destination segments are already contiguous
        dest = np.searchsorted(coff, cs, side="right") - 1
        seg_counts[p] = np.bincount(dest, minlength=P)
        pre_parts.append((cs, cd, cw))
    pre_s = time.perf_counter() - t0

    # ---- segmented all-to-all + owner-side merge (jit) -----------------
    S_e = _next_pow2(max(1, int(seg_counts.max())))
    slab = np.zeros((P, P, S_e, 3), dtype=np.int32)
    for p in range(P):
        cs, cd, cw = pre_parts[p]
        ends = np.cumsum(seg_counts[p])
        starts = ends - seg_counts[p]
        for q in range(P):
            s0, s1 = int(starts[q]), int(ends[q])
            slab[p, q, :s1 - s0, 0] = cs[s0:s1]
            slab[p, q, :s1 - s0, 1] = cd[s0:s1]
            slab[p, q, :s1 - s0, 2] = cw[s0:s1]
    t0 = time.perf_counter()
    est = seg_merge_vmem_bytes(P * S_e)
    fused = kmode == "fused" and est <= dispatch.VMEM_BUDGET_BYTES
    if kmode == "fused" and not fused:
        dispatch.report_fallback("seg_merge", est,
                                 detail="dist_contract")
    fn = _build_exchange_fn(mesh, P, S_e, use_grid, fused=fused,
                            interpret=dispatch.kernel_interpret())
    s_src, s_dst, wsum, first = (np.asarray(x) for x in fn(
        jnp.asarray(slab), jnp.asarray(seg_counts)))
    exchange_s = time.perf_counter() - t0

    # ---- owner-side coarse shards + host view --------------------------
    arc_parts = []
    for p in range(P):
        take = (s_src[p] < int(I32_MAX)) & first[p]
        arc_parts.append((s_src[p][take].astype(np.int64),
                          s_dst[p][take].astype(np.int64),
                          wsum[p][take].astype(np.int64)))
    vw_parts = [cvw[coff[p]:coff[p + 1]] for p in range(P)]
    coarse_shards = assemble_shards(nc, coff, arc_parts, vw_parts)
    # arc parts are sorted by coarse tail within each PE and owner ranges
    # ascend with p, so the concatenation is already in CSR order
    graph = from_coo(nc,
                     np.concatenate([a[0] for a in arc_parts]),
                     np.concatenate([a[1] for a in arc_parts]),
                     eweights=np.concatenate([a[2] for a in arc_parts]),
                     vweights=cvw, symmetrize=False, dedup=False)
    stats = {
        "nc": nc,
        "payload_bytes": int(seg_counts.astype(np.int64).sum()) * 12,
        "slab_bytes_per_pe": int(P * S_e * 3 * 4),
        "precontract_s": round(pre_s, 6),
        "exchange_s": round(exchange_s, 6),
    }
    return DistContraction(shards=coarse_shards, graph=graph,
                           mapping=mapping, stats=stats)
