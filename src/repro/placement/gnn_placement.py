"""GNN node placement: partition the input graph into #devices blocks so
that the halo-exchange payload (== edge cut, paper's objective) shrinks;
relabel vertices block-contiguously so the 1D-range machine model of
graphs/distribute.py applies unchanged."""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core import metrics
from ..core.deep_mgp import partition
from ..core.partitioner import PartitionerConfig, fast_config
from ..graphs.distribute import GraphShards, distribute_graph
from ..graphs.format import Graph, permute


@dataclasses.dataclass(frozen=True)
class GNNPlacement:
    graph: Graph              # vertex-relabelled (block-contiguous)
    perm: np.ndarray          # old id -> new id
    offsets: np.ndarray       # (P+1,) block boundaries
    cut: int
    halo_bytes: int           # per full halo exchange (sum over PEs)
    baseline_halo_bytes: int  # naive contiguous 1D split of the input


def plan(g: Graph, n_devices: int,
         config: Optional[PartitionerConfig] = None,
         epsilon: float = 0.03, seed: int = 0) -> GNNPlacement:
    cfg = config or fast_config(seed=seed, epsilon=epsilon)
    part = partition(g, n_devices, cfg)
    order = np.argsort(part, kind="stable")
    perm = np.empty(g.n, dtype=np.int64)
    perm[order] = np.arange(g.n)
    g2, _ = permute(g, perm)
    counts = np.bincount(part, minlength=n_devices)
    offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    shards = _shards_with_offsets(g2, offsets)
    base = distribute_graph(g, n_devices)   # naive contiguous split
    return GNNPlacement(graph=g2, perm=perm, offsets=offsets,
                        cut=metrics.edge_cut(g, part),
                        halo_bytes=shards.comm_bytes_per_halo(),
                        baseline_halo_bytes=base.comm_bytes_per_halo())


def _shards_with_offsets(g: Graph, offsets: np.ndarray) -> GraphShards:
    """distribute_graph with externally fixed block boundaries."""
    from ..graphs import distribute as D
    P = offsets.shape[0] - 1
    orig = D.balanced_offsets
    try:
        D.balanced_offsets = lambda *_a, **_k: offsets
        return D.distribute_graph(g, P)
    finally:
        D.balanced_offsets = orig
