"""Placement engine: the paper's partitioner as the device-placement
oracle for GNN graphs, DLRM tables and MoE experts (DESIGN.md §3)."""
