"""DLRM table sharding via the partitioner: vertices = embedding tables
(weight = rows x dim = HBM cost), edges = co-lookup frequency from
sampled batches. The k-way balanced min-cut groups co-accessed tables on
the same shard, cutting cross-device fused-lookup traffic."""
from __future__ import annotations

from typing import Dict

import numpy as np

from ..core import metrics
from ..core.deep_mgp import partition
from ..core.partitioner import fast_config
from ..graphs.format import from_coo


def cooccurrence_graph(sparse_batches: np.ndarray, table_rows: np.ndarray):
    """sparse_batches: (B, F, bag) indices; co-occurrence = same-example
    joint lookups (all F fire each example for DLRM, so the weight is
    uniform unless bags are empty; real deployments would use per-feature
    activity)."""
    B, F, _ = sparse_batches.shape
    active = (sparse_batches >= 0).any(axis=2)           # (B, F)
    co = active.astype(np.int64).T @ active.astype(np.int64)
    np.fill_diagonal(co, 0)
    iu, ju = np.nonzero(np.triu(co))
    return from_coo(F, iu, ju, eweights=co[iu, ju],
                    vweights=np.maximum(table_rows, 1))


def plan(sparse_batches: np.ndarray, table_rows: np.ndarray,
         n_shards: int, epsilon: float = 0.1, seed: int = 0
         ) -> Dict:
    g = cooccurrence_graph(sparse_batches, table_rows)
    part = partition(g, n_shards,
                     fast_config(seed=seed, epsilon=epsilon,
                                 contraction_limit=8))
    return {
        "assignment": part,                     # table -> shard
        "cut": metrics.edge_cut(g, part),
        "imbalance": metrics.imbalance(g, part, n_shards),
        "feasible": metrics.is_feasible(g, part, n_shards, epsilon),
    }
