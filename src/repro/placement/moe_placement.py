"""MoE expert placement across pods: vertices = experts, edge weight =
top-2 co-activation counts from router statistics. Partitioning into
#pods blocks puts frequently co-routed experts in the same pod, so a
token's two experts usually live one ICI hop apart instead of crossing
the DCI inter-pod link (DESIGN.md §3/§8)."""
from __future__ import annotations

from typing import Dict

import numpy as np

from ..core import metrics
from ..core.deep_mgp import partition
from ..core.partitioner import fast_config
from ..graphs.format import from_coo


def coactivation_graph(topk_samples: np.ndarray, n_experts: int):
    """topk_samples: (T, k) expert ids per token."""
    T, k = topk_samples.shape
    co = np.zeros((n_experts, n_experts), dtype=np.int64)
    for a in range(k):
        for b in range(a + 1, k):
            np.add.at(co, (topk_samples[:, a], topk_samples[:, b]), 1)
    co = co + co.T
    np.fill_diagonal(co, 0)
    iu, ju = np.nonzero(np.triu(co))
    return from_coo(n_experts, iu, ju, eweights=co[iu, ju])


def plan(topk_samples: np.ndarray, n_experts: int, n_pods: int,
         epsilon: float = 0.0, seed: int = 0) -> Dict:
    g = coactivation_graph(topk_samples, n_experts)
    part = partition(g, n_pods,
                     fast_config(seed=seed, epsilon=max(epsilon, .01),
                                 contraction_limit=4))
    total = int(g.total_eweight) // 2
    cut = metrics.edge_cut(g, part)
    # naive baseline: contiguous expert ranges per pod
    naive = np.arange(n_experts) * n_pods // n_experts
    naive_cut = metrics.edge_cut(g, naive)
    return {
        "assignment": part,
        "cross_pod_fraction": cut / max(total, 1),
        "naive_cross_pod_fraction": naive_cut / max(total, 1),
        "experts_per_pod": np.bincount(part, minlength=n_pods).tolist(),
    }
