"""Optimizers from scratch (no optax): AdamW and Adafactor.

Adafactor (Shazeer & Stern, arXiv:1804.04235) is the default for >=10B
configs: the factored second moment keeps optimizer state ~O(r+c) per
matrix, which is what lets arctic-480b fit a v5e-256 pod (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"               # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    # adafactor
    decay_rate: float = 0.8
    min_dim_factored: int = 128


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), \
        norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(grads, state, params, cfg: OptConfig):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - cfg.lr * delta).astype(p.dtype), m, v

    flat_p, td = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree_util.tree_unflatten(td, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(td, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(td, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}


# ---------------------------------------------------------------------------
# Adafactor (factored second moments, no first moment)
# ---------------------------------------------------------------------------

def _factored(shape, min_dim) -> bool:
    return len(shape) >= 2 and shape[-1] >= min_dim and shape[-2] >= min_dim


def adafactor_init(params, cfg: OptConfig):
    def one(p):
        if _factored(p.shape, cfg.min_dim_factored):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                    jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"slots": jax.tree_util.tree_map(one, params),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(grads, state, params, cfg: OptConfig):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** (-cfg.decay_rate)
    lr = cfg.lr

    def upd(g, slot, p):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + 1e-30
        if "vr" in slot:
            vr = beta2 * slot["vr"] + (1 - beta2) * g2.mean(-1)
            vc = beta2 * slot["vc"] + (1 - beta2) * g2.mean(-2)
            rfac = vr / jnp.maximum(vr.mean(-1, keepdims=True), 1e-30)
            prec = rfac[..., None] * vc[..., None, :]
            new_slot = {"vr": vr, "vc": vc}
        else:
            v = beta2 * slot["v"] + (1 - beta2) * g2
            prec = v
            new_slot = {"v": v}
        u = g * jax.lax.rsqrt(prec + 1e-30)
        # update clipping (RMS <= 1)
        rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
        u = u / jnp.maximum(1.0, rms)
        newp = p.astype(jnp.float32) - lr * u - \
            lr * cfg.weight_decay * p.astype(jnp.float32)
        return newp.astype(p.dtype), new_slot

    flat_p, td = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    slot_leaves = jax.tree_util.tree_flatten(
        state["slots"], is_leaf=lambda x: isinstance(x, dict) and
        ("v" in x or "vr" in x))[0]
    out = [upd(g, s, p) for g, s, p in zip(flat_g, slot_leaves, flat_p)]
    new_p = jax.tree_util.tree_unflatten(td, [o[0] for o in out])
    new_slots = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(params), [o[1] for o in out])
    return new_p, {"slots": new_slots, "step": step}


def make_optimizer(cfg: OptConfig):
    if cfg.name == "adamw":
        return adamw_init, lambda g, s, p: adamw_update(g, s, p, cfg)
    if cfg.name == "adafactor":
        return (lambda p: adafactor_init(p, cfg),
                lambda g, s, p: adafactor_update(g, s, p, cfg))
    raise ValueError(cfg.name)
