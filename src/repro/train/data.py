"""Deterministic synthetic data pipelines.

Every batch is a pure function of (seed, step) — restart-safe data
skipping comes for free: after restoring step N, the pipeline resumes at
batch N+1 with no state to persist (the paper-grade alternative for real
corpora is an offset manifest in the checkpoint; the interface below
carries the offset through ``state['data_step']``)."""
from __future__ import annotations

from typing import Dict

import numpy as np


def lm_batch(step: int, batch: int, seq: int, vocab: int,
             seed: int = 0) -> Dict[str, np.ndarray]:
    """Zipf-ish token stream with local structure (next-token learnable)."""
    rng = np.random.default_rng(np.uint64(seed * 1_000_003 + step))
    base = rng.zipf(1.3, size=(batch, seq)).astype(np.int64)
    toks = base % (vocab - 2) + 1
    # inject copy structure so a real signal exists: shift-by-1 spans
    src = np.roll(toks, 1, axis=1)
    mask = rng.random((batch, seq)) < 0.3
    toks = np.where(mask, src, toks)
    return {"tokens": toks.astype(np.int32)}


def dlrm_batch(step: int, batch: int, n_dense: int, n_sparse: int,
               vocab: int, bag: int = 1, seed: int = 0
               ) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(np.uint64(seed * 9_176_549 + step))
    dense = rng.standard_normal((batch, n_dense)).astype(np.float32)
    sparse = (rng.zipf(1.2, size=(batch, n_sparse, bag)) - 1) % vocab
    # clicks correlated with a fixed random hyperplane over dense feats
    w = np.random.default_rng(seed + 7).standard_normal(n_dense)
    p = 1.0 / (1.0 + np.exp(-(dense @ w) * 0.7))
    labels = (rng.random(batch) < p).astype(np.float32)
    return {"dense": dense, "sparse": sparse.astype(np.int32),
            "labels": labels}
