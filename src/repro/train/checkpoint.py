"""Sharded, restartable checkpointing (fault tolerance substrate).

Layout:  <dir>/step_<N>/
            manifest.json       — step, pytree structure, shapes, dtypes,
                                  mesh shape at save time, data offset
            arrays/<idx>.npy    — one file per leaf (host-gathered)

Restore supports *elastic re-meshing*: arrays are loaded on host and
device_put with the shardings of the *current* mesh, so a job can resume
on a different pod slice (e.g. 2x16x16 -> 16x16 after losing a pod).
On a real multi-host deployment each host writes only its addressable
shards; the manifest/format stays identical (process-local file names
gain a host suffix).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    return paths, [v for _, v in flat], treedef


def save(ckpt_dir: str, step: int, state: Any,
         extra: Optional[Dict] = None) -> str:
    out = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = out + ".tmp"
    os.makedirs(os.path.join(tmp, "arrays"), exist_ok=True)
    paths, leaves, _ = _flatten_with_paths(state)
    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, "arrays", f"{i}.npy"), arr)
        manifest["leaves"].append(
            {"path": p, "idx": i, "shape": list(arr.shape),
             "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    # atomic publish: rename tmp -> final (crash-safe)
    if os.path.exists(out):
        shutil.rmtree(out)
    os.rename(tmp, out)
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, state_like: Any, step: Optional[int] = None,
            shardings: Any = None) -> Tuple[Any, Dict]:
    """Restore into the structure of ``state_like``. With ``shardings``
    (same pytree structure), arrays are placed sharded — this is the
    elastic-remesh path."""
    step = step if step is not None else latest_step(ckpt_dir)
    assert step is not None, f"no checkpoint in {ckpt_dir}"
    src = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)
    _, leaves, treedef = _flatten_with_paths(state_like)
    assert len(leaves) == len(manifest["leaves"]), "structure mismatch"
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    out = []
    for i, (leaf, sh) in enumerate(zip(leaves, shard_leaves)):
        arr = np.load(os.path.join(src, "arrays", f"{i}.npy"))
        assert list(arr.shape) == list(leaf.shape), \
            (arr.shape, leaf.shape, manifest["leaves"][i]["path"])
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.device_put(arr.astype(leaf.dtype)))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]


def prune(ckpt_dir: str, keep: int = 3) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
