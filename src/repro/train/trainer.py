"""Train-step builder + fault-tolerant training loop.

``make_train_step`` closes over a loss function and an optimizer and
returns a jit-able ``(state, batch) -> (state, metrics)``. The loop
layers the production concerns on top:

  * checkpoint/restart   — periodic atomic saves, auto-resume (checkpoint.py)
  * deterministic data   — batch = f(seed, step): restart-safe skipping
  * straggler/failure    — synchronous SPMD steps mean a straggler stalls
    the collective, not corrupts it; recovery = restart from the last
    checkpoint, possibly on a smaller mesh (elastic re-mesh in
    checkpoint.restore). A watchdog wall-clock per step aborts the run
    (exit code 75) so the scheduler can relaunch it.
  * NaN containment      — non-finite grad norms skip the update and are
    counted; persistent NaNs abort.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import checkpoint
from .optimizer import OptConfig, clip_by_global_norm, make_optimizer


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep_ckpts: int = 3
    log_every: int = 10
    step_timeout_s: float = 0.0      # 0 = no watchdog
    max_nan_skips: int = 10


def make_train_step(loss_fn: Callable, opt_cfg: OptConfig,
                    microbatches: int = 1, accum_dtype=None):
    """loss_fn(params, batch) -> scalar. Returns step(state, batch).

    ``microbatches > 1`` enables gradient accumulation: the global batch
    is split on the leading axis and grads are averaged over a lax.scan —
    the standard lever to fit activation transients in HBM (used for the
    MoE-480B train cells, EXPERIMENTS.md §Perf)."""
    opt_init, opt_update = make_optimizer(opt_cfg)

    def init_state(params):
        return {"params": params, "opt": opt_init(params),
                "step": jnp.zeros((), jnp.int32),
                "nan_skips": jnp.zeros((), jnp.int32)}

    def _value_and_grad(params, batch):
        if microbatches == 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        mb = jax.tree_util.tree_map(
            lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                + x.shape[1:]), batch)

        def body(carry, b):
            loss_acc, grads_acc = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, b)
            grads_acc = jax.tree_util.tree_map(
                lambda a, g: a + (g / microbatches).astype(a.dtype),
                grads_acc, grads)
            return (loss_acc + loss / microbatches, grads_acc), ()

        adt = accum_dtype or jnp.float32
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, adt), params)
        (loss, grads), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zeros), mb)
        return loss, grads

    def train_step(state, batch):
        loss, grads = _value_and_grad(state["params"], batch)
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        finite = jnp.isfinite(loss) & jnp.isfinite(gnorm)

        def do_update(_):
            new_p, new_opt = opt_update(grads, state["opt"],
                                        state["params"])
            return new_p, new_opt

        def skip(_):
            return state["params"], state["opt"]

        new_p, new_opt = jax.lax.cond(finite, do_update, skip, None)
        new_state = {"params": new_p, "opt": new_opt,
                     "step": state["step"] + 1,
                     "nan_skips": state["nan_skips"]
                     + (1 - finite.astype(jnp.int32))}
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "finite": finite}
        return new_state, metrics

    return init_state, train_step


def run_loop(init_state, train_step, make_batch: Callable[[int], Any],
             params, loop_cfg: TrainLoopConfig,
             jit: bool = True) -> Tuple[Any, Dict]:
    """Fault-tolerant loop. Returns (final_state, history)."""
    # defensive copy: the first jitted step donates the state buffers, and
    # the caller's params must stay alive for reuse (e.g. eval, restarts)
    params = jax.tree_util.tree_map(jnp.copy, params)
    state = init_state(params)
    start = 0
    if loop_cfg.ckpt_dir:
        last = checkpoint.latest_step(loop_cfg.ckpt_dir)
        if last is not None:
            state, extra = checkpoint.restore(loop_cfg.ckpt_dir, state)
            start = int(extra.get("next_step", last))
    step_fn = jax.jit(train_step, donate_argnums=(0,)) if jit else train_step
    history = {"loss": [], "grad_norm": []}
    for step in range(start, loop_cfg.steps):
        t0 = time.time()
        batch = make_batch(step)
        state, metrics = step_fn(state, batch)
        if loop_cfg.step_timeout_s and \
                time.time() - t0 > loop_cfg.step_timeout_s:
            # straggler watchdog: surface to the scheduler for relaunch
            raise SystemExit(75)
        if (step + 1) % loop_cfg.log_every == 0 or step == start:
            loss = float(metrics["loss"])
            history["loss"].append((step, loss))
            history["grad_norm"].append((step, float(metrics["grad_norm"])))
        nan_skips = int(jax.device_get(state["nan_skips"]))
        if nan_skips > loop_cfg.max_nan_skips:
            raise RuntimeError(f"too many non-finite steps ({nan_skips})")
        if loop_cfg.ckpt_dir and (step + 1) % loop_cfg.ckpt_every == 0:
            checkpoint.save(loop_cfg.ckpt_dir, step + 1, state,
                            extra={"next_step": step + 1})
            checkpoint.prune(loop_cfg.ckpt_dir, loop_cfg.keep_ckpts)
    return state, history
