"""dimenet [arXiv:2003.03123]: 6 blocks, d=128, bilinear 8, 7 spherical x
6 radial basis — triplet-gather kernel regime."""
from ..models.gnn.dimenet import DimeNetConfig
from . import ArchEntry, GNN_SHAPES, register

CONFIG = DimeNetConfig(name="dimenet", n_blocks=6, d_hidden=128,
                       n_bilinear=8, n_spherical=7, n_radial=6, cutoff=5.0)
SMOKE = DimeNetConfig(name="dimenet-smoke", n_blocks=2, d_hidden=32,
                      n_bilinear=4, n_spherical=4, n_radial=4, cutoff=5.0)

ENTRY = register(ArchEntry(
    arch_id="dimenet", kind="gnn", family="gnn",
    config=CONFIG, smoke_config=SMOKE, shapes=GNN_SHAPES,
    notes="triplet lists are built host-side (graphs/sampler + "
          "gnn/dimenet.build_triplets) and padded; cap 2x edges for "
          "full-graph dry-runs."))
