"""Architecture registry: one module per assigned arch (+ the paper's own
partitioner config). Each registers an ArchEntry; the launch layer builds
train/serve steps from (entry, shape_name, mesh)."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Tuple

_REGISTRY: Dict[str, "ArchEntry"] = {}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode | long_decode |
    #                      gnn_full | gnn_minibatch | gnn_molecule |
    #                      recsys_train | recsys_serve | recsys_retrieval
    params: Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    arch_id: str
    kind: str            # lm | gnn | recsys
    family: str          # dense | moe | gnn | recsys
    config: Any          # full-size model config
    smoke_config: Any    # reduced config for CPU smoke tests
    shapes: Tuple[ShapeSpec, ...]
    notes: str = ""

    def shape(self, name: str) -> ShapeSpec:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.arch_id} has no shape {name}")


def register(entry: ArchEntry) -> ArchEntry:
    _REGISTRY[entry.arch_id] = entry
    return entry


_MODULES = [
    "arctic_480b", "granite_moe_1b", "gemma_2b", "stablelm_12b", "qwen2_7b",
    "schnet", "nequip", "gat_cora", "dimenet", "dlrm_rm2",
]


def load_all() -> Dict[str, ArchEntry]:
    for m in _MODULES:
        importlib.import_module(f"repro.configs.{m}")
    return dict(_REGISTRY)


def get(arch_id: str) -> ArchEntry:
    if arch_id not in _REGISTRY:
        load_all()
    return _REGISTRY[arch_id]


# ---------------------------------------------------------------------------
# shared shape sets
# ---------------------------------------------------------------------------

LM_SHAPES = (
    ShapeSpec("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
    ShapeSpec("prefill_32k", "prefill",
              {"seq_len": 32768, "global_batch": 32}),
    ShapeSpec("decode_32k", "decode",
              {"seq_len": 32768, "global_batch": 128}),
    # long-context DECODE is linear per step (one query against a
    # sequence-sharded KV cache) — runnable with full attention; 500k
    # PREFILL would be quadratic and is skipped (DESIGN.md §8)
    ShapeSpec("long_500k", "long_decode",
              {"seq_len": 524288, "global_batch": 1}),
)


def _pad512(n: int) -> int:
    return -(-n // 512) * 512


GNN_SHAPES = (
    ShapeSpec("full_graph_sm", "gnn_full",
              {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433,
               "n_pad": _pad512(2708 + 1), "e_pad": _pad512(2 * 10556)}),
    ShapeSpec("minibatch_lg", "gnn_minibatch",
              {"n_nodes": 232965, "n_edges": 114615892,
               "batch_nodes": 1024, "fanout": (15, 10),
               # sampled subgraph (padded): seeds*(1+15+150) nodes
               "n_pad": _pad512(1024 * 166 + 1),
               "e_pad": _pad512(1024 * (15 + 150))}),
    ShapeSpec("ogb_products", "gnn_full",
              {"n_nodes": 2449029, "n_edges": 61859140, "d_feat": 100,
               "n_pad": _pad512(2449029 + 1),
               "e_pad": _pad512(2 * 61859140)}),
    ShapeSpec("molecule", "gnn_molecule",
              {"n_nodes": 30, "n_edges": 64, "batch": 128,
               "n_pad": _pad512(30 * 128 + 1),
               "e_pad": _pad512(2 * 64 * 128)}),
)

RECSYS_SHAPES = (
    ShapeSpec("train_batch", "recsys_train", {"batch": 65536}),
    ShapeSpec("serve_p99", "recsys_serve", {"batch": 512}),
    ShapeSpec("serve_bulk", "recsys_serve", {"batch": 262144}),
    ShapeSpec("retrieval_cand", "recsys_retrieval",
              {"batch": 1, "n_candidates": 1_000_000}),
)
