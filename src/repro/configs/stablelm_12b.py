"""stablelm-12b [hf:stabilityai]: 40L d=5120 32H (GQA kv=8) d_ff=13824
vocab=100352."""
from ..models.transformer import TransformerConfig
from . import ArchEntry, LM_SHAPES, register

CONFIG = TransformerConfig(
    name="stablelm-12b", n_layers=40, d_model=5120, n_heads=32,
    n_kv_heads=8, head_dim=160, d_ff=13824, vocab=100352, glu=True,
    activation="silu", remat=True)

SMOKE = TransformerConfig(
    name="stablelm-12b-smoke", n_layers=2, d_model=80, n_heads=4,
    n_kv_heads=2, head_dim=20, d_ff=128, vocab=512, glu=True,
    activation="silu", remat=False)

ENTRY = register(ArchEntry(
    arch_id="stablelm-12b", kind="lm", family="dense",
    config=CONFIG, smoke_config=SMOKE, shapes=LM_SHAPES,
    notes="partitioner inapplicable (dense LM, DESIGN §8)."))
