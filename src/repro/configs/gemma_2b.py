"""gemma-2b [arXiv:2403.08295]: 18L d=2048 8H MQA(kv=1) head_dim=256
d_ff=16384 GeGLU vocab=256000, tied embeddings."""
from ..models.transformer import TransformerConfig
from . import ArchEntry, LM_SHAPES, register

CONFIG = TransformerConfig(
    name="gemma-2b", n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
    head_dim=256, d_ff=16384, vocab=256000, glu=True,
    activation="gelu_tanh", tied_embeddings=True, remat=True)

SMOKE = TransformerConfig(
    name="gemma-2b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
    head_dim=16, d_ff=128, vocab=512, glu=True, activation="gelu_tanh",
    tied_embeddings=True, remat=False)

ENTRY = register(ArchEntry(
    arch_id="gemma-2b", kind="lm", family="dense",
    config=CONFIG, smoke_config=SMOKE, shapes=LM_SHAPES,
    notes="partitioner inapplicable (dense LM, DESIGN §8); MQA kv=1 "
          "replicates KV over the model axis."))
