"""nequip [arXiv:2101.03164]: 5 layers, mult=32, l_max=2, 8 RBF, cutoff 5,
E(3)-equivariant tensor products (Cartesian irreps, DESIGN §8)."""
from ..models.gnn.nequip import NequIPConfig
from . import ArchEntry, GNN_SHAPES, register

CONFIG = NequIPConfig(name="nequip", n_layers=5, d_hidden=32, l_max=2,
                      n_rbf=8, cutoff=5.0)
SMOKE = NequIPConfig(name="nequip-smoke", n_layers=2, d_hidden=8, l_max=2,
                     n_rbf=4, cutoff=5.0)

ENTRY = register(ArchEntry(
    arch_id="nequip", kind="gnn", family="gnn",
    config=CONFIG, smoke_config=SMOKE, shapes=GNN_SHAPES))
