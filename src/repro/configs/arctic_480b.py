"""arctic-480b [hf:Snowflake/snowflake-arctic-base]: 35L d=7168 56H (GQA
kv=8) d_ff=4864 vocab=32000, MoE 128 experts top-2 + dense residual."""
import jax.numpy as jnp

from ..models.transformer import TransformerConfig
from . import ArchEntry, LM_SHAPES, register

CONFIG = TransformerConfig(
    name="arctic-480b", n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    head_dim=128, d_ff=4864, vocab=32000, glu=True, activation="silu",
    moe=True, n_experts=128, top_k=2, moe_dense_residual=True,
    moe_d_ff=4864, param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    remat=True)

SMOKE = TransformerConfig(
    name="arctic-480b-smoke", n_layers=2, d_model=128, n_heads=8,
    n_kv_heads=2, head_dim=16, d_ff=96, vocab=512, glu=True,
    activation="silu", moe=True, n_experts=8, top_k=2,
    moe_dense_residual=True, moe_d_ff=96, remat=False)

ENTRY = register(ArchEntry(
    arch_id="arctic-480b", kind="lm", family="moe",
    config=CONFIG, smoke_config=SMOKE, shapes=LM_SHAPES,
    notes="MoE placement engine applies (expert co-activation, DESIGN §8); "
          "Adafactor + bf16 params for pod memory fit."))
