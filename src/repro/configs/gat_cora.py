"""gat-cora [arXiv:1710.10903]: 2 layers, 8 hidden, 8 heads, attn agg."""
from ..models.gnn.gat import GATConfig
from . import ArchEntry, GNN_SHAPES, register

CONFIG = GATConfig(name="gat-cora", n_layers=2, d_in=1433, d_hidden=8,
                   n_heads=8, n_classes=7)
SMOKE = GATConfig(name="gat-cora-smoke", n_layers=2, d_in=32, d_hidden=4,
                  n_heads=2, n_classes=5)

ENTRY = register(ArchEntry(
    arch_id="gat-cora", kind="gnn", family="gnn",
    config=CONFIG, smoke_config=SMOKE, shapes=GNN_SHAPES,
    notes="partitioner applies directly: node placement minimizes halo "
          "volume (collective roofline term ~ edge cut)."))
