"""qwen2-7b [arXiv:2407.10671]: 28L d=3584 28H (GQA kv=4) d_ff=18944
vocab=152064, QKV bias."""
from ..models.transformer import TransformerConfig
from . import ArchEntry, LM_SHAPES, register

CONFIG = TransformerConfig(
    name="qwen2-7b", n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    head_dim=128, d_ff=18944, vocab=152064, glu=True, activation="silu",
    qkv_bias=True, remat=True)

SMOKE = TransformerConfig(
    name="qwen2-7b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=128, vocab=512, glu=True, activation="silu",
    qkv_bias=True, remat=False)

ENTRY = register(ArchEntry(
    arch_id="qwen2-7b", kind="lm", family="dense",
    config=CONFIG, smoke_config=SMOKE, shapes=LM_SHAPES,
    notes="28 heads not divisible by model=16: planner shards FFN/vocab, "
          "replicates the head dim (DESIGN §6). Partitioner inapplicable."))
