"""dlrm-rm2 [arXiv:1906.00091]: 13 dense + 26 sparse, embed 64,
bot 13-512-256-64, top 512-512-256-1, dot interaction."""
from ..models.dlrm import DLRMConfig
from . import ArchEntry, RECSYS_SHAPES, register

CONFIG = DLRMConfig(name="dlrm-rm2", n_dense=13, n_sparse=26, embed_dim=64,
                    vocab_per_table=1_000_000, bot_mlp=(512, 256, 64),
                    top_mlp=(512, 512, 256, 1))
SMOKE = DLRMConfig(name="dlrm-rm2-smoke", n_dense=13, n_sparse=6,
                   embed_dim=16, vocab_per_table=1000, bot_mlp=(32, 16),
                   top_mlp=(64, 32, 1))

ENTRY = register(ArchEntry(
    arch_id="dlrm-rm2", kind="recsys", family="recsys",
    config=CONFIG, smoke_config=SMOKE, shapes=RECSYS_SHAPES,
    notes="partitioner applies via table co-occurrence placement "
          "(placement/dlrm_placement.py)."))
