"""schnet [arXiv:1706.08566]: 3 interactions, d=64, 300 RBF, cutoff 10."""
from ..models.gnn.schnet import SchNetConfig
from . import ArchEntry, GNN_SHAPES, register

CONFIG = SchNetConfig(name="schnet", n_interactions=3, d_hidden=64,
                      n_rbf=300, cutoff=10.0)
SMOKE = SchNetConfig(name="schnet-smoke", n_interactions=2, d_hidden=16,
                     n_rbf=24, cutoff=5.0)

ENTRY = register(ArchEntry(
    arch_id="schnet", kind="gnn", family="gnn",
    config=CONFIG, smoke_config=SMOKE, shapes=GNN_SHAPES,
    notes="non-molecular shapes (full_graph/minibatch) use synthesized 3D "
          "positions; the kernel regime (gather+segment_sum) is identical."))
