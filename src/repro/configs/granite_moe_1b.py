"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base]:
24L d=1024 16H (GQA kv=8) d_ff=512 vocab=49155, MoE 32 experts top-8."""
from ..models.transformer import TransformerConfig
from . import ArchEntry, LM_SHAPES, register

CONFIG = TransformerConfig(
    name="granite-moe-1b", n_layers=24, d_model=1024, n_heads=16,
    n_kv_heads=8, head_dim=64, d_ff=512, vocab=49155, glu=True,
    activation="silu", moe=True, n_experts=32, top_k=8, moe_d_ff=512,
    remat=True)

SMOKE = TransformerConfig(
    name="granite-moe-1b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=64, vocab=512, glu=True,
    activation="silu", moe=True, n_experts=4, top_k=2, moe_d_ff=64,
    remat=False)

ENTRY = register(ArchEntry(
    arch_id="granite-moe-1b-a400m", kind="lm", family="moe",
    config=CONFIG, smoke_config=SMOKE, shapes=LM_SHAPES,
    notes="vocab 49155 is not divisible by 16: the sharding planner "
          "replicates the vocab dim (DESIGN §6) — exercised on purpose."))
