"""Deep multilevel graph partitioning driver (paper Algorithm 1).

Single-process reference driver; dist/dist_partitioner.py runs the same
phases under shard_map. The driver is host Python (dynamic level shapes)
around jitted per-level programs — see DESIGN.md §2 (Static shapes).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..graphs.format import Graph, from_coo
from . import metrics
from .coarsening import cluster
from .contraction import contract
from .initial_partition import (bipartition, distribute_counts,
                                partition_into_counts, split_count)
from .refinement import balance_and_refine

log = logging.getLogger("repro.deep_mgp")


@dataclasses.dataclass(frozen=True)
class PartitionerConfig:
    """dKaMinPar-Fast defaults (paper §6: C=2000, 3 LP iterations);
    the Strong preset uses C=5000 / 5 iterations."""
    contraction_limit: int = 2000          # C
    initial_k: int = 2                     # K (bipartitioning base case)
    epsilon: float = 0.03
    cluster_iterations: int = 3
    refine_iterations: int = 2
    num_chunks: int = 8
    ip_repetitions: int = 3
    max_levels: int = 64
    min_shrink: float = 0.95               # stop coarsening if n_c/n above
    seed: int = 0
    # distributed-backend knobs (ignored by the single-process driver):
    # where each level contracts, how cluster/block weight tables are
    # laid out across PEs, and where balancing runs during uncoarsening
    # and coarsening — see docs/DIST.md for the memory model
    contraction: str = "host"              # "host" | "sharded"
    weights: str = "replicated"            # "replicated" | "owner"
    balance: str = "host"                  # "host" | "dist"
    # hot-loop implementation: "auto" (fused on TPU, composed elsewhere),
    # "fused" (Pallas kernels), "composed" (XLA pipelines) — bit-identical
    # results either way; see docs/KERNELS.md
    kernel: str = "auto"
    # refinement algorithm for the main per-level passes: "lp" (paper §4
    # size-constrained LP) or "unconstrained" (Jet-style penalty-weighted
    # search + afterburner repair, better cuts for more refinement time)
    # — see docs/REFINEMENT.md. The sibling-restricted extension pass
    # always uses LP.
    refine: str = "lp"

    def validate(self) -> "PartitionerConfig":
        """Reject configurations that would only fail later as opaque
        shape errors. Returns self so drivers can chain it."""
        if self.epsilon <= 0:
            raise ValueError(
                f"epsilon must be > 0, got {self.epsilon!r} (the balance "
                "constraint L_max is undefined for non-positive slack)")
        if self.initial_k < 1:
            raise ValueError(f"initial_k must be >= 1, got {self.initial_k}")
        if self.contraction_limit < self.initial_k:
            raise ValueError(
                f"contraction_limit ({self.contraction_limit}) must be >= "
                f"initial_k ({self.initial_k}); the coarsest graph must "
                "hold at least one vertex per initial block")
        if self.num_chunks < 1:
            raise ValueError(
                f"num_chunks must be >= 1, got {self.num_chunks}")
        if self.cluster_iterations < 1 or self.refine_iterations < 0:
            raise ValueError(
                "cluster_iterations must be >= 1 and refine_iterations "
                f">= 0, got {self.cluster_iterations}/"
                f"{self.refine_iterations}")
        if self.contraction not in ("host", "sharded"):
            raise ValueError(
                "contraction must be 'host' or 'sharded', "
                f"got {self.contraction!r}")
        if self.weights not in ("replicated", "owner"):
            raise ValueError(
                "weights must be 'replicated' or 'owner', "
                f"got {self.weights!r}")
        if self.balance not in ("host", "dist"):
            raise ValueError(
                f"balance must be 'host' or 'dist', got {self.balance!r}")
        from ..kernels.dispatch import check_kernel_mode
        check_kernel_mode(self.kernel)
        from .refinement import check_refine_mode
        check_refine_mode(self.refine)
        return self


def check_k(k: int, where: str = "partition") -> None:
    """Shared driver guard: k must be a positive block count."""
    if k < 1:
        raise ValueError(f"{where}: k must be >= 1, got {k}")


def trace_event(trace: Optional[List[Dict]], **record) -> None:
    """Append one per-level record to ``trace`` (no-op when None)."""
    if trace is not None:
        trace.append(record)


def _refine_stats(cfg: "PartitionerConfig",
                  trace: Optional[List[Dict]]) -> Optional[Dict]:
    """A stats dict for ``balance_and_refine`` when the trace wants a
    ``refine-mode`` record; None keeps the default path allocation-free."""
    if trace is not None and cfg.refine != "lp":
        return {}
    return None


def _trace_refine_mode(trace: Optional[List[Dict]],
                       cfg: "PartitionerConfig", stage: str,
                       level: Optional[int],
                       stats: Optional[Dict]) -> None:
    """One ``refine-mode`` record per non-default refinement pass: the
    mode, the penalty schedule actually applied, and how many afterburner
    rounds the feasibility repair took (docs/REFINEMENT.md)."""
    if stats is None:
        return
    rec: Dict = dict(phase="refine-mode", stage=stage, mode=cfg.refine)
    if level is not None:
        rec["level"] = level
    rec.update(stats)
    trace_event(trace, **rec)


def ceil2(x: int) -> int:
    return 1 << max(0, (int(x) - 1)).bit_length()


def uncoarsen_seed(base_seed: int, lvl: int, stream: int = 0) -> int:
    """Per-level refinement/balancer seed during uncoarsening.

    Derived from the level *index*, never from the level's vertex count:
    the historical ``seed + n % 1000003`` collided whenever two hierarchy
    levels had equal n (possible near the min_shrink exit), correlating
    LP and balancer tie-breaking across levels. ``stream`` separates
    independent uncoarsening loops that share one base seed — the
    distributed driver (stream 1) delegates its base case to this
    driver (stream 0), and both count levels from 0; the 500009 offset
    is not a multiple of the 1000003 level stride, so no (stream, lvl)
    pair collides with another."""
    return base_seed + stream * 500009 + (lvl + 1) * 1000003


def _l_vec(block_k: np.ndarray, l_final: int) -> np.ndarray:
    return block_k.astype(np.int64) * int(l_final)


def extract_block_subgraphs(g: Graph, part: np.ndarray, nb: int
                            ) -> Tuple[List[Graph], List[np.ndarray]]:
    """All block-induced subgraphs in one O(m log m) pass.

    Returns (graphs, old_ids) lists indexed by block."""
    counts = np.bincount(part, minlength=nb)
    starts = np.concatenate([[0], np.cumsum(counts)])
    order = np.argsort(part, kind="stable")      # vertices grouped by block
    local = np.empty(g.n, dtype=np.int64)
    local[order] = np.arange(g.n) - starts[part[order]]
    src = g.arc_tails()
    keep = part[src] == part[g.adjncy]
    ksrc, kdst, kw = src[keep], g.adjncy[keep], g.eweights[keep]
    kblk = part[ksrc]
    eorder = np.argsort(kblk, kind="stable")
    ksrc, kdst, kw, kblk = ksrc[eorder], kdst[eorder], kw[eorder], kblk[eorder]
    ecounts = np.bincount(kblk, minlength=nb)
    estarts = np.concatenate([[0], np.cumsum(ecounts)])
    graphs, ids = [], []
    for b in range(nb):
        v0, v1 = starts[b], starts[b + 1]
        e0, e1 = estarts[b], estarts[b + 1]
        old = order[v0:v1]
        sub = from_coo(int(counts[b]), local[ksrc[e0:e1]], local[kdst[e0:e1]],
                       eweights=kw[e0:e1], vweights=g.vweights[old],
                       symmetrize=False, dedup=False)
        graphs.append(sub)
        ids.append(old)
    return graphs, ids


def extend_partition(g: Graph, part: np.ndarray, block_k: np.ndarray,
                     k: int, l_final: int, cfg: PartitionerConfig,
                     rng: np.random.Generator, target_blocks: int
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Paper Algorithm 1 lines 13–18: while |Pi| < target, split every
    splittable block via (gathered) sequential bipartitioning, then refine
    restricted to siblings."""
    while block_k.shape[0] < target_blocks and np.any(block_k > 1):
        nb = block_k.shape[0]
        graphs, ids = extract_block_subgraphs(g, part, nb)
        new_part = np.empty(g.n, dtype=np.int64)
        new_counts: List[int] = []
        parent: List[int] = []
        off = 0
        for b in range(nb):
            if block_k[b] <= 1:
                new_part[ids[b]] = off
                new_counts.append(1)
                parent.append(b)
                off += 1
                continue
            k1, k2 = split_count(int(block_k[b]))
            half = bipartition(graphs[b], k1, k2, l_final, rng,
                               cfg.ip_repetitions)
            new_part[ids[b]] = off + half
            new_counts.extend([k1, k2])
            parent.extend([b, b])
            off += 2
        block_k = np.asarray(new_counts, dtype=np.int64)
        part = new_part
        # sibling-restricted refinement pass (cheap cleanup of the split)
        lv = _l_vec(block_k, l_final)
        part = balance_and_refine(g, part, lv,
                                  parent=np.asarray(parent, dtype=np.int64),
                                  num_iterations=1,
                                  num_chunks=cfg.num_chunks,
                                  seed=cfg.seed + off, kernel=cfg.kernel)
    return part, block_k


def level0_cluster_plan(g: Graph, k: int,
                        cfg: Optional[PartitionerConfig] = None
                        ) -> Optional[Dict]:
    """Parameters of the level-0 ``cluster`` call :func:`partition`
    would make for this input, or None when coarsening would not run
    (small graph, ``k == 1``, ``max_levels == 0`` — a hint would go
    unused). Pure function of the same inputs as the driver, so a
    batching layer can precompute level-0 labels out-of-band and pass
    them back via ``level0_labels`` with exact fidelity."""
    cfg = (cfg or PartitionerConfig()).validate()
    check_k(k, "deep_mgp.level0_cluster_plan")
    if k == 1 or g.n == 0 or cfg.max_levels < 1:
        return None
    C, K = cfg.contraction_limit, cfg.initial_k
    if not g.n > C * min(k, K):
        return None
    total_c = g.total_vweight
    kprime = max(1, min(k, g.n // max(1, C)))
    return {"W": max(1, int(cfg.epsilon * total_c / kprime)),
            "num_iterations": cfg.cluster_iterations,
            "num_chunks": cfg.num_chunks,
            "seed": cfg.seed}


def partition(g: Graph, k: int, cfg: Optional[PartitionerConfig] = None,
              trace: Optional[List[Dict]] = None,
              level0_labels: Optional[np.ndarray] = None) -> np.ndarray:
    """Deep multilevel k-way partition. Returns block ids (n,).

    ``trace``, when given, receives one dict per phase/level (sizes, cuts,
    wall times) — the structured log surfaced by ``repro.api``.

    ``level0_labels``, when given, replaces the level-0 ``cluster`` call
    with precomputed labels. The caller guarantees they equal what that
    call would return (use :func:`level0_cluster_plan` to reproduce its
    parameters) — this is how the serving tier's batched dispatch runs
    one stacked clustering program for many requests while keeping every
    result bit-identical to a solo run.
    """
    cfg = (cfg or PartitionerConfig()).validate()
    check_k(k, "deep_mgp.partition")
    if k == 1 or g.n == 0:
        return np.zeros(g.n, dtype=np.int64)
    rng = np.random.default_rng(cfg.seed)
    total_c = g.total_vweight
    max_c = int(g.vweights.max()) if g.n else 1
    l_final = metrics.l_max(total_c, k, cfg.epsilon, max_c)
    C, K = cfg.contraction_limit, cfg.initial_k

    # ---- deep coarsening (lines 6–8) -----------------------------------
    hierarchy: List[Tuple[Graph, np.ndarray]] = []
    G = g
    level = 0
    while G.n > C * min(k, K) and level < cfg.max_levels:
        kprime = max(1, min(k, G.n // max(1, C)))
        W = max(1, int(cfg.epsilon * total_c / kprime))
        t0 = time.perf_counter()
        if level == 0 and level0_labels is not None:
            labels = np.asarray(level0_labels)
            if labels.shape[0] != G.n:
                raise ValueError(
                    f"level0_labels has {labels.shape[0]} entries for a "
                    f"{G.n}-vertex graph")
        else:
            labels = cluster(G, W, num_iterations=cfg.cluster_iterations,
                             num_chunks=cfg.num_chunks, seed=cfg.seed + level,
                             kernel=cfg.kernel)
        Gc, mapping = contract(G, labels, kernel=cfg.kernel)
        log.info("level %d: n=%d -> n_c=%d (W=%d)", level, G.n, Gc.n, W)
        if Gc.n >= G.n * cfg.min_shrink:
            break  # converged — coarsest level reached
        trace_event(trace, phase="coarsen", level=level, n=G.n, m=G.m,
                    coarse_n=Gc.n, W=W,
                    time_s=round(time.perf_counter() - t0, 6))
        hierarchy.append((G, mapping))
        G = Gc
        level += 1

    # ---- initial partition of the coarsest graph (base case) -----------
    t0 = time.perf_counter()
    k0 = max(1, min(k, K))
    counts = distribute_counts(k, k0)
    part = partition_into_counts(G, counts, l_final, rng,
                                 cfg.ip_repetitions)
    block_k = np.asarray(counts, dtype=np.int64)
    ref_stats = _refine_stats(cfg, trace)
    part = balance_and_refine(G, part, _l_vec(block_k, l_final),
                              num_iterations=cfg.refine_iterations,
                              num_chunks=cfg.num_chunks, seed=cfg.seed,
                              kernel=cfg.kernel, refine=cfg.refine,
                              stats=ref_stats)
    if trace is not None:
        trace_event(trace, phase="initial", n=G.n, m=G.m,
                    blocks=int(block_k.shape[0]),
                    cut=metrics.edge_cut(G, part),
                    time_s=round(time.perf_counter() - t0, 6))
        _trace_refine_mode(trace, cfg, "initial", None, ref_stats)

    # ---- uncoarsening: project, extend, refine (lines 7–9, 13–18) ------
    for lvl, (Gf, mapping) in enumerate(reversed(hierarchy)):
        t0 = time.perf_counter()
        part = part[mapping]
        target = min(k, ceil2(max(1, Gf.n // max(1, C))))
        target = max(target, block_k.shape[0])
        part, block_k = extend_partition(Gf, part, block_k, k, l_final,
                                         cfg, rng, target)
        ref_stats = _refine_stats(cfg, trace)
        part = balance_and_refine(Gf, part, _l_vec(block_k, l_final),
                                  num_iterations=cfg.refine_iterations,
                                  num_chunks=cfg.num_chunks,
                                  seed=uncoarsen_seed(cfg.seed, lvl),
                                  kernel=cfg.kernel, refine=cfg.refine,
                                  stats=ref_stats)
        if trace is not None:
            trace_event(trace, phase="uncoarsen", level=lvl, n=Gf.n,
                        m=Gf.m, blocks=int(block_k.shape[0]),
                        cut=metrics.edge_cut(Gf, part),
                        time_s=round(time.perf_counter() - t0, 6))
            _trace_refine_mode(trace, cfg, "uncoarsen", lvl, ref_stats)

    # ---- final extension to exactly k blocks (omitted-case in Alg. 1) --
    t0 = time.perf_counter()
    part, block_k = extend_partition(g, part, block_k, k, l_final, cfg,
                                     rng, target_blocks=k)
    if block_k.shape[0] < k:  # blocks that cannot split further (tiny n)
        pad = k - block_k.shape[0]
        block_k = np.concatenate([block_k, np.ones(pad, dtype=np.int64)])
    ref_stats = _refine_stats(cfg, trace)
    part = balance_and_refine(g, part, np.full(k, l_final, dtype=np.int64),
                              num_iterations=cfg.refine_iterations,
                              num_chunks=cfg.num_chunks, seed=cfg.seed + 17,
                              kernel=cfg.kernel, refine=cfg.refine,
                              stats=ref_stats)
    if trace is not None:
        trace_event(trace, phase="final", n=g.n, m=g.m, blocks=k,
                    cut=metrics.edge_cut(g, part),
                    time_s=round(time.perf_counter() - t0, 6))
        _trace_refine_mode(trace, cfg, "final", None, ref_stats)
    from ..kernels import dispatch
    for rec in dispatch.drain_fallback_records():
        trace_event(trace, **rec)
    return part
