"""Greedy global balancing (paper §4, Balancing).

TPU-native adaptation of the PQ + binary-tree-reduction scheme:

  * per-PE priority queues        ->  ``lax.top_k`` over relative gains
    (a PQ is only ever popped from the top; top-k is the array equivalent
    and the queue-size invariant is the pool size ``top_m``)
  * binary tree reduction + root  ->  gather of per-shard top lists + the
    decides + broadcast               same deterministic greedy selection
                                      executed redundantly everywhere
  * "update gains of neighbors"   ->  gains recomputed per round (rounds
                                      are few; the paper assumes few moves
                                      suffice, so recompute is cheap)

Relative gain (paper): g·c(v) if g >= 0 else g/c(v) where g is the best
cut reduction over targets that would not become overloaded. Moving to any
*non-adjacent* block has g = -own_connection; the lightest such block is
always a valid fallback because L_max >= c(V)/k + max_v c(v), which is what
guarantees termination (feasibility is always reachable).

The round is factored into two kernels shared with the distributed
balancer (``dist.dist_balance``): ``balance_gains`` (per-vertex relative
gains + targets over an arc slab — each PE runs it over its own shard)
and ``greedy_select`` (the deterministic greedy application of a ranked
candidate pool — run redundantly on every PE so no root/broadcast step
is needed). Two historical host edge cases are fixed here: padded
vertices can no longer enter the candidate pool (their zero relative
gain used to displace real negative-gain candidates), and feasibility
comparisons are arranged as ``w <= budget - c`` so they cannot wrap at
the int32 boundary.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs.format import Graph
from ..kernels import dispatch
from . import lp
from .lp import I32_MAX, _argmax_target, _group_conns, _own_connection

NEG_INF = np.float32(-np.inf)


def balance_gains(lab_src_tab, s_src, s_lab, s_w, block_w, l_max, parent,
                  vw_pad, salt, n, valid, restricted=False):
    """Per-vertex relative gains + targets for one balancing round.

    ``(s_src, s_lab, s_w)`` is the arc slab sorted by (src, label[dst]);
    ``lab_src_tab``/``vw_pad``/``valid`` live over the (n+1,) src space
    (slot n is the sentinel). ``valid`` masks real vertices — padded
    slots must never enter the candidate pool. Returns ``(rel, tgt)``:
    the paper's relative gain (NEG_INF where the vertex must not move)
    and the chosen target block.

    All weight comparisons are written ``w <= budget - c`` so that they
    stay exact for totals at the int32 boundary (``w + c`` could wrap).
    """
    k = block_w.shape[0]
    over = block_w > l_max
    conn = _group_conns(s_src, s_lab, s_w)
    own_lab = lab_src_tab[s_src]
    # target must not become overloaded (fits) and differ from own block
    fits = block_w[s_lab] <= l_max[s_lab] - vw_pad[s_src]
    ok = fits & (s_lab != own_lab)
    if restricted:
        ok &= parent[s_lab] == parent[own_lab]
    score = jnp.where(ok, conn, -1)
    best, target = _argmax_target(s_src, s_lab, score, block_w[s_lab],
                                  salt, n)
    own_conn = _own_connection(s_src, s_lab, s_w, lab_src_tab, n)

    has_adj = (best >= 0) & (target < I32_MAX)
    tgt_adj = jnp.where(has_adj, target, 0)
    gain_adj = best - own_conn

    if restricted:
        # fallback target: the lightest sibling within the own parent group
        # (O(k) via segment-min over blocks grouped by parent)
        grp_min = jax.ops.segment_min(block_w, parent, num_segments=k)
        is_min = block_w == grp_min[parent]
        bid = jnp.where(is_min, jnp.arange(k, dtype=jnp.int32), I32_MAX)
        grp_argmin = jax.ops.segment_min(bid, parent, num_segments=k)
        fb_t = grp_argmin[parent[lab_src_tab]]
    else:
        fb_t = jnp.full((n + 1,), jnp.argmin(block_w).astype(jnp.int32))
    fb_ok = (block_w[fb_t] <= l_max[fb_t] - vw_pad) & (fb_t != lab_src_tab)
    gain_fb = -own_conn

    tgt = jnp.where(has_adj, tgt_adj, fb_t)
    g = jnp.where(has_adj, gain_adj, gain_fb)
    movable = over[lab_src_tab] & (has_adj | fb_ok) & valid

    gf = g.astype(jnp.float32)
    cv = jnp.maximum(vw_pad.astype(jnp.float32), 1.0)
    rel = jnp.where(g >= 0, gf * cv, gf / cv)
    rel = jnp.where(movable, rel, NEG_INF)
    return rel, tgt


def greedy_select(vals, tgt_blk, src_blk, cand_w, block_w, l_max):
    """Deterministic greedy application of a ranked candidate pool.

    The pool arrays must already be ordered by descending relative gain
    (ties by ascending vertex id); every PE of the distributed balancer
    runs this redundantly over the identical gathered pool, so accept
    decisions agree everywhere without a root/broadcast step. Returns
    ``(accept, block_w)``.
    """
    m = vals.shape[0]

    def body(i, carry):
        block_w, accept = carry
        t = tgt_blk[i]
        b = src_blk[i]
        cw = cand_w[i]
        ok = (vals[i] > NEG_INF) & (block_w[b] > l_max[b]) & \
             (block_w[t] <= l_max[t] - cw) & (t != b)
        cwd = jnp.where(ok, cw, 0)
        block_w = block_w.at[b].add(-cwd).at[t].add(cwd)
        accept = accept.at[i].set(ok)
        return block_w, accept

    block_w, accept = jax.lax.fori_loop(
        0, m, body, (block_w, jnp.zeros((m,), jnp.bool_)))
    return accept, block_w


@functools.partial(jax.jit, static_argnames=("n", "top_m", "restricted"))
def balance_round(labels, block_w, l_max, parent, src, dst, w, vweights,
                  valid, salt, *, n, top_m, restricted=False):
    """One global balancing round. Returns (labels, block_w, still_overloaded).

    All arrays over vertices have size n+1 (sentinel slot n); ``valid``
    marks the real vertices among them."""
    lab_dst = labels[dst]
    s_src, s_lab, s_w = jax.lax.sort((src, lab_dst, w), num_keys=2)
    rel, tgt = balance_gains(labels, s_src, s_lab, s_w, block_w, l_max,
                             parent, vweights, salt, n, valid,
                             restricted=restricted)
    vals, vidx = jax.lax.top_k(rel, top_m)
    accept, block_w = greedy_select(vals, tgt[vidx], labels[vidx],
                                    vweights[vidx], block_w, l_max)
    labels = labels.at[vidx].set(
        jnp.where(accept, tgt[vidx], labels[vidx]))
    return labels, block_w, jnp.any(block_w > l_max)


def rebalance(g: Graph,
              part: np.ndarray,
              l_max_vec: np.ndarray,
              parent: Optional[np.ndarray] = None,
              top_m: int = 128,
              max_rounds: int = 200,
              seed: int = 0,
              kernel: str = "auto",
              stats: Optional[Dict] = None) -> np.ndarray:
    """Host driver: run balance rounds until feasible. ``part`` is (n,) block
    ids; ``l_max_vec`` is (k,) per-block budgets.

    Already-feasible partitions return immediately without building the
    O(m) chunk slabs or touching a device. ``kernel="fused"`` runs the
    round through the ``kernels.bal_round`` Pallas pair (bit-identical;
    keeps the composed round when the ELL slab exceeds the VMEM budget,
    reporting the fallback via ``dispatch.report_fallback``). ``stats``,
    when given, receives ``rounds`` / ``time_s`` / ``gather_bytes`` for
    benchmarks.
    """
    n = g.n
    k = int(l_max_vec.shape[0])
    t_start = time.perf_counter()
    from . import metrics
    block_w = metrics.block_weights(g, part, k)
    if not bool(np.any(block_w > l_max_vec)):
        if stats is not None:
            stats.update(rounds=0, gather_bytes=0,
                         time_s=time.perf_counter() - t_start)
        return np.array(part, dtype=np.int64)   # fresh array, never a view
    # build_chunks raises a clear ValueError for totals >= 2^31 (the
    # int32 jit path would wrap)
    chunks = lp.build_chunks(g, 1)
    n_pad = chunks.n_pad
    top_m = min(top_m, n_pad + 1)
    labels = np.zeros(n_pad + 1, dtype=np.int32)
    labels[:n] = part
    vw = np.zeros(n_pad + 1, dtype=np.int32)
    vw[:n] = g.vweights
    from .refinement import pad_blocks
    bw_p, lv_p, pr_p, _ = pad_blocks(block_w, l_max_vec, parent)
    labels = jnp.asarray(labels)
    vw_j = jnp.asarray(vw)
    block_w = jnp.asarray(bw_p)
    l_max_j = jnp.asarray(lv_p)
    parent_j = jnp.asarray(pr_p)
    valid = jnp.asarray(np.arange(n_pad + 1) < n)
    restricted = parent is not None
    fused_ell = None
    if dispatch.resolve_kernel_mode(kernel) == "fused":
        from ..kernels.bal_round import ops as bal_ops
        idx, ew = bal_ops.build_balance_ell(g, n_pad)
        if bal_ops.balance_ell_fits(idx.shape[0], idx.shape[1],
                                    restricted=restricted):
            fused_ell = (jnp.asarray(idx), jnp.asarray(ew))
        else:
            dispatch.report_fallback(
                "bal_round",
                bal_ops.bal_scores_vmem_bytes(
                    idx.shape[0], idx.shape[1], bal_ops.ROW_TILE,
                    restricted=restricted),
                detail="rebalance")
    if fused_ell is None:
        src = jnp.asarray(chunks.src[0])
        dst = jnp.asarray(chunks.dst[0])
        w = jnp.asarray(chunks.w[0])
    rounds = 0
    for r in range(max_rounds):
        salt = jnp.uint32((seed * 7919 + r) % (2**32))
        if fused_ell is not None:
            from ..kernels.bal_round import ops as bal_ops
            labels, block_w, overloaded = bal_ops.balance_round_fused(
                labels, block_w, l_max_j, parent_j, fused_ell[0],
                fused_ell[1], vw_j, valid, salt, n=n_pad, top_m=top_m,
                restricted=restricted,
                interpret=dispatch.kernel_interpret())
        else:
            labels, block_w, overloaded = balance_round(
                labels, block_w, l_max_j, parent_j, src, dst, w, vw_j,
                valid, salt, n=n_pad, top_m=top_m, restricted=restricted)
        rounds = r + 1
        if not bool(overloaded):
            break
    if stats is not None:
        # the host balancer pays one O(m) single-chunk gather up front
        stats.update(rounds=rounds,
                     gather_bytes=int(chunks.src.nbytes + chunks.dst.nbytes
                                      + chunks.w.nbytes),
                     time_s=time.perf_counter() - t_start)
    return np.asarray(labels)[:n].astype(np.int64)
