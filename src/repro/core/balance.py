"""Greedy global balancing (paper §4, Balancing).

TPU-native adaptation of the PQ + binary-tree-reduction scheme:

  * per-PE priority queues        ->  ``lax.top_k`` over relative gains
    (a PQ is only ever popped from the top; top-k is the array equivalent
    and the queue-size invariant is the pool size ``top_m``)
  * binary tree reduction + root  ->  gather of per-shard top lists + the
    decides + broadcast               same deterministic greedy selection
                                      executed redundantly everywhere
  * "update gains of neighbors"   ->  gains recomputed per round (rounds
                                      are few; the paper assumes few moves
                                      suffice, so recompute is cheap)

Relative gain (paper): g·c(v) if g >= 0 else g/c(v) where g is the best
cut reduction over targets that would not become overloaded. Moving to any
*non-adjacent* block has g = -own_connection; the lightest such block is
always a valid fallback because L_max >= c(V)/k + max_v c(v), which is what
guarantees termination (feasibility is always reachable).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs.format import Graph
from . import lp
from .lp import I32_MAX, _argmax_target, _group_conns, _own_connection

NEG_INF = np.float32(-np.inf)


@functools.partial(jax.jit, static_argnames=("n", "top_m", "restricted"))
def balance_round(labels, block_w, l_max, parent, src, dst, w, vweights,
                  salt, *, n, top_m, restricted=False):
    """One global balancing round. Returns (labels, block_w, still_overloaded).

    All arrays over vertices have size n+1 (sentinel slot n)."""
    k = block_w.shape[0]
    over = block_w > l_max
    lab_dst = labels[dst]
    s_src, s_lab, s_w = jax.lax.sort((src, lab_dst, w), num_keys=2)
    conn = _group_conns(s_src, s_lab, s_w)
    own_lab = labels[s_src]
    # target must not become overloaded (fits) and differ from own block
    fits = (block_w[s_lab] + vweights[s_src] <= l_max[s_lab])
    valid = fits & (s_lab != own_lab)
    if restricted:
        valid &= parent[s_lab] == parent[own_lab]
    score = jnp.where(valid, conn, -1)
    best, target = _argmax_target(s_src, s_lab, score, block_w[s_lab], salt, n)
    own_conn = _own_connection(s_src, s_lab, s_w, labels, n)

    has_adj = (best >= 0) & (target < I32_MAX)
    tgt_adj = jnp.where(has_adj, target, 0)
    gain_adj = best - own_conn

    if restricted:
        # fallback target: the lightest sibling within the own parent group
        # (O(k) via segment-min over blocks grouped by parent)
        grp_min = jax.ops.segment_min(block_w, parent, num_segments=k)
        is_min = block_w == grp_min[parent]
        bid = jnp.where(is_min, jnp.arange(k, dtype=jnp.int32), I32_MAX)
        grp_argmin = jax.ops.segment_min(bid, parent, num_segments=k)
        fb_t = grp_argmin[parent[labels]]
    else:
        fb_t = jnp.full((n + 1,), jnp.argmin(block_w).astype(jnp.int32))
    fb_ok = (block_w[fb_t] + vweights <= l_max[fb_t]) & (fb_t != labels)
    gain_fb = -own_conn

    use_adj = has_adj
    tgt = jnp.where(use_adj, tgt_adj, fb_t)
    g = jnp.where(use_adj, gain_adj, gain_fb)
    movable = over[labels] & (has_adj | fb_ok)
    movable = movable.at[n].set(False)

    gf = g.astype(jnp.float32)
    cv = jnp.maximum(vweights.astype(jnp.float32), 1.0)
    rel = jnp.where(g >= 0, gf * cv, gf / cv)
    rel = jnp.where(movable, rel, NEG_INF)
    vals, vidx = jax.lax.top_k(rel, top_m)

    def body(i, carry):
        block_w, labels = carry
        v = vidx[i]
        t = tgt[v]
        b = labels[v]
        cw = vweights[v]
        ok = (vals[i] > NEG_INF) & (block_w[b] > l_max[b]) & \
             (block_w[t] + cw <= l_max[t]) & (t != b)
        cwd = jnp.where(ok, cw, 0)
        block_w = block_w.at[b].add(-cwd).at[t].add(cwd)
        labels = labels.at[v].set(jnp.where(ok, t, b))
        return block_w, labels

    block_w, labels = jax.lax.fori_loop(0, top_m, body, (block_w, labels))
    return labels, block_w, jnp.any(block_w > l_max)


def rebalance(g: Graph,
              part: np.ndarray,
              l_max_vec: np.ndarray,
              parent: Optional[np.ndarray] = None,
              top_m: int = 128,
              max_rounds: int = 200,
              seed: int = 0) -> np.ndarray:
    """Host driver: run balance rounds until feasible. ``part`` is (n,) block
    ids; ``l_max_vec`` is (k,) per-block budgets."""
    n = g.n
    k = int(l_max_vec.shape[0])
    chunks = lp.build_chunks(g, 1)
    n_pad = chunks.n_pad
    top_m = min(top_m, n_pad + 1)
    labels = np.zeros(n_pad + 1, dtype=np.int32)
    labels[:n] = part
    vw = np.zeros(n_pad + 1, dtype=np.int32)
    vw[:n] = g.vweights
    from .refinement import pad_blocks
    block_w = np.zeros(k, dtype=np.int64)
    np.add.at(block_w, part, g.vweights)
    bw_p, lv_p, pr_p, _ = pad_blocks(block_w, l_max_vec, parent)
    labels = jnp.asarray(labels)
    vw_j = jnp.asarray(vw)
    block_w = jnp.asarray(bw_p)
    l_max_j = jnp.asarray(lv_p)
    parent_j = jnp.asarray(pr_p)
    restricted = parent is not None
    src = jnp.asarray(chunks.src[0])
    dst = jnp.asarray(chunks.dst[0])
    w = jnp.asarray(chunks.w[0])
    if bool(np.any(np.asarray(block_w) > np.asarray(l_max_j))):
        for r in range(max_rounds):
            labels, block_w, overloaded = balance_round(
                labels, block_w, l_max_j, parent_j, src, dst, w, vw_j,
                jnp.uint32((seed * 7919 + r) % (2**32)), n=n_pad, top_m=top_m,
                restricted=restricted)
            if not bool(overloaded):
                break
    return np.asarray(labels)[:n].astype(np.int64)
