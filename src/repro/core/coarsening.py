"""Coarsening via size-constrained label propagation clustering (paper §4).

Host driver: degree-bucket reorder -> chunked LP iterations (jitted) ->
exact max-cluster-weight enforcement (the paper's "unwind contractions that
lead to overweight clusters", applied as a final eject-to-singleton sweep;
multi-member clusters are always reducible below W, singletons heavier than
W are tolerated exactly as in the paper — the balance constraint absorbs
them via the ``+ max_v c(v)`` term).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..graphs.format import Graph, degree_bucket_order, permute
from ..kernels import dispatch
from ..kernels.lp_move import ops as move_ops
from . import lp


def ejection_candidates(labels: np.ndarray, vweights: np.ndarray,
                        max_weight: int) -> np.ndarray:
    """Vertices that must leave their overweight cluster, under the
    deterministic keep-heaviest-first-prefix rule: members sort by
    (cluster, -weight, id) and a member is ejected once the cumulative
    kept weight including it exceeds ``max_weight`` — except each
    cluster's first (heaviest) member, since singletons may legitimately
    exceed W. This is the shared decision rule: the sharded enforcement
    (``dist.dist_balance.dist_enforce_cluster_weights``) runs the same
    sort owner-side and must eject the identical vertex set."""
    n = labels.shape[0]
    cw = np.zeros(n, dtype=np.int64)
    np.add.at(cw, labels, vweights)
    over = cw > max_weight
    if not over.any():
        return np.empty(0, dtype=np.int64)
    members = np.flatnonzero(over[labels])
    # keep heaviest-first prefix per cluster (fewest ejections)
    order = np.lexsort((members, -vweights[members], labels[members]))
    sid = labels[members][order]
    sw = vweights[members][order]
    csum = np.cumsum(sw)
    starts = np.concatenate([[True], sid[1:] != sid[:-1]])
    gidx = np.cumsum(starts) - 1
    gstart = np.flatnonzero(starts)
    base = (csum[gstart] - sw[gstart])[gidx]
    within = csum - base
    eject = (within > max_weight) & ~starts
    return members[order][eject].astype(np.int64)


def enforce_cluster_weights(labels: np.ndarray, vweights: np.ndarray,
                            max_weight: int) -> np.ndarray:
    """Eject members of overweight clusters into fresh singleton clusters
    until every multi-member cluster fits. One exact pass."""
    n = labels.shape[0]
    ej = ejection_candidates(labels, vweights, max_weight)
    if ej.size == 0:
        return labels
    used = np.zeros(n, dtype=bool)
    keep_members = np.setdiff1d(np.arange(n), ej, assume_unique=False)
    used[labels[keep_members]] = True
    free = np.flatnonzero(~used)
    assert free.size >= ej.size, "no free cluster ids for ejection"
    out = labels.copy()
    out[ej] = free[:ej.size]
    return out


def cluster_prepare(g: Graph, num_chunks: int, seed: int,
                    kernel: str = "composed"):
    """Host-side setup shared by the solo and stacked clustering paths:
    seeded degree-bucket reorder, permuted graph, padded chunk slabs.
    Returns ``(perm, g2, chunks)``. Kept per-request even when requests
    are batched — the reorder draws from a per-request RNG, so any
    batch-level change here would break solo bit-identity.

    ``kernel="fused"`` builds ELL slabs for the Pallas move kernel
    instead of arc slabs (falling back to arc slabs when the chunk
    working set would not fit the kernel's VMEM budget); both describe
    identical vertex ranges (``lp.chunk_bounds``)."""
    n = g.n
    rng = np.random.default_rng(seed)
    order = degree_bucket_order(g, rng)
    perm = np.empty(n, dtype=np.int64)
    perm[order] = np.arange(n)
    g2, _ = permute(g, perm)
    if kernel == "fused":
        chunks = move_ops.build_move_chunks(g2, num_chunks)
        if move_ops.move_chunks_fit_vmem(chunks):
            return perm, g2, chunks
        _, R, D = chunks.shape
        dispatch.report_fallback(
            "lp_move",
            move_ops.lp_move_vmem_bytes(R, D, move_ops.ROW_TILE),
            detail="cluster_prepare")
    chunks = lp.build_chunks(g2, num_chunks)
    return perm, g2, chunks


def cluster_seed(seed: int, iteration: int) -> np.uint32:
    """The jit-side salt stream for LP-clustering iteration ``it``."""
    return np.uint32((seed * 1000003 + iteration) % (2**32))


def cluster_finish(labels_pad: np.ndarray, g2: Graph, perm: np.ndarray,
                   max_cluster_weight: int) -> np.ndarray:
    """Shared epilogue: slice the padded label vector to the real
    vertices, exactly enforce the cluster-weight bound, and map the
    labels back to the input graph's vertex numbering."""
    n = g2.n
    lab2 = np.asarray(labels_pad)[:n].astype(np.int64)
    lab2 = enforce_cluster_weights(lab2, np.asarray(g2.vweights),
                                   int(max_cluster_weight))
    return lab2[perm]


def cluster(g: Graph,
            max_cluster_weight: int,
            num_iterations: int = 3,
            num_chunks: int = 8,
            seed: int = 0,
            kernel: str = "auto") -> np.ndarray:
    """Size-constrained LP clustering. Returns cluster labels (n,) in the
    input graph's vertex numbering; label values are arbitrary ids.

    ``kernel`` selects the chunk-move implementation (see
    ``kernels.dispatch``); "fused" and "composed" produce bit-identical
    labels."""
    n = g.n
    if n <= 1:
        return np.zeros(n, dtype=np.int64)
    mode = dispatch.resolve_kernel_mode(kernel)
    perm, g2, chunks = cluster_prepare(g, num_chunks, seed, kernel=mode)
    np_pad = chunks.n_pad
    labels = jnp.arange(np_pad + 1, dtype=jnp.int32)
    vw = np.zeros(np_pad + 1, dtype=np.int32)
    vw[:n] = g2.vweights
    vw = jnp.asarray(vw)
    cluster_w = vw
    W = jnp.int32(max(1, max_cluster_weight))
    if isinstance(chunks, move_ops.MoveChunks):
        idx, cw_slab = jnp.asarray(chunks.idx), jnp.asarray(chunks.w)
        v0s = jnp.asarray(chunks.v0)
        interp = dispatch.kernel_interpret()
        for it in range(num_iterations):
            labels, cluster_w = move_ops.cluster_iteration_fused(
                labels, cluster_w, idx, cw_slab, v0s, vw, W,
                jnp.uint32(cluster_seed(seed, it)), n=np_pad,
                interpret=interp)
    else:
        for it in range(num_iterations):
            labels, cluster_w = lp.cluster_iteration(
                labels, cluster_w, jnp.asarray(chunks.src),
                jnp.asarray(chunks.dst), jnp.asarray(chunks.w), vw, W,
                jnp.uint32(cluster_seed(seed, it)), n=np_pad)
    return cluster_finish(labels, g2, perm, int(W))
