"""Partition quality metrics (paper Section 2 definitions)."""
from __future__ import annotations

import numpy as np

from ..graphs.format import Graph


def edge_cut(g: Graph, part: np.ndarray) -> int:
    """Sum of weights of cut (undirected) edges."""
    src = g.arc_tails()
    cut_arcs = part[src] != part[g.adjncy]
    return int(g.eweights[cut_arcs].sum()) // 2


def block_weights(g: Graph, part: np.ndarray, k: int) -> np.ndarray:
    bw = np.zeros(k, dtype=np.int64)
    np.add.at(bw, part, g.vweights)
    return bw


def l_max(total_vweight: int, k: int, eps: float, max_vweight: int) -> int:
    """Paper balance constraint:
    L_max = max{(1+eps)·c(V)/k, c(V)/k + max_v c(v)} (relaxed variant)."""
    l1 = int(np.floor((1.0 + eps) * total_vweight / k))
    l2 = -(-total_vweight // k) + max_vweight
    return max(l1, l2)


def imbalance(g: Graph, part: np.ndarray, k: int) -> float:
    bw = block_weights(g, part, k)
    avg = g.total_vweight / k
    return float(bw.max() / avg - 1.0)


def is_feasible(g: Graph, part: np.ndarray, k: int, eps: float) -> bool:
    bw = block_weights(g, part, k)
    lim = l_max(g.total_vweight, k, eps, int(g.vweights.max()))
    return bool(bw.max() <= lim)


def summarize(g: Graph, part: np.ndarray, k: int, eps: float) -> dict:
    bw = block_weights(g, part, k)
    lim = l_max(g.total_vweight, k, eps, int(g.vweights.max()))
    return {
        "cut": edge_cut(g, part),
        "imbalance": imbalance(g, part, k),
        "max_block_weight": int(bw.max()),
        "min_block_weight": int(bw.min()),
        "l_max": lim,
        "feasible": bool(bw.max() <= lim),
        "k": k,
        "nonempty_blocks": int((bw > 0).sum()),
    }
