"""dKaMinPar core: distributed deep multilevel graph partitioning in JAX."""
from .deep_mgp import PartitionerConfig
from .partitioner import fast_config, strong_config

__all__ = ["PartitionerConfig", "fast_config", "strong_config"]
