"""Unconstrained (Jet-style) k-way refinement with penalty-weighted gains.

Second refinement tier behind ``PartitionerConfig(refine="unconstrained")``
(arXiv 2406.03169, same authors as the source paper): moves may violate
the balance constraint during the pass, so the search escapes the local
optima that the size-constrained LP rule (``core.lp._refine_chunk``)
gets pinned against when every improving move targets a full block.
Feasibility is restored afterwards by the balancer acting as an
*afterburner* (``core.balance.rebalance`` /
``dist.dist_balance.dist_rebalance``) — callers through
``refinement.balance_and_refine`` never observe an infeasible result.

The move rule replaces the hard budget mask with a **penalty-weighted
gain**: a move whose target block would exceed its budget is charged

    pen = (own_connection // R) * r          (round r of R, integer math)

so round 0 is fully unconstrained (pure gain-greedy) and later rounds
escalate the required gain for overloading moves toward ~2x the own
connection, herding the partition back toward feasibility before the
repair pass. The penalty is integer-only and overflow-safe:
``pen <= own_connection < 2^31``. Everything else — the chunked arc
slabs, the 4-stage argmax tie-break, the zero-gain-into-lighter-block
rule, the salt streams — reuses ``core.lp`` verbatim, so the tier costs
no new kernel machinery. The distributed twin lives in
``dist.dist_lp.dist_ulp_refine``. See docs/REFINEMENT.md.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs.format import Graph, degree_bucket_order, permute
from . import lp
from .lp import I32_MAX, _argmax_target, _group_conns, _own_connection


def penalty_schedule(num_iterations: int) -> list:
    """The escalating per-round penalty fractions ``r / R`` (round 0 is
    fully unconstrained) — recorded in trace records and docs."""
    R = max(1, int(num_iterations))
    return [round(r / R, 4) for r in range(R)]


def _urefine_chunk(labels, block_w, l_max, parent, chunk_src, chunk_dst,
                   chunk_w, vweights, salt, pen_num, pen_den, n,
                   restricted):
    """One chunk of unconstrained LP refinement over k blocks.

    Identical to ``lp._refine_chunk`` except the budget mask: instead of
    rejecting moves into full blocks, candidates whose target would end
    up over budget pay ``(own_conn // pen_den) * pen_num`` off their
    connection before the argmax, and the block-weight tables track the
    (possibly overloaded) truth. ``restricted`` keeps the
    sibling-confinement semantics of the extension pass."""
    lab_dst = labels[chunk_dst]
    s_src, s_lab, s_w = jax.lax.sort(
        (chunk_src, lab_dst, chunk_w), num_keys=2)
    conn = _group_conns(s_src, s_lab, s_w)
    own_lab = labels[s_src]
    staying = s_lab == own_lab
    own_conn = _own_connection(s_src, s_lab, s_w, labels, n)
    # would the target overflow its budget after taking this vertex?
    # (``w > budget - c`` form: exact at the int32 boundary)
    over_after = block_w[s_lab] > l_max[s_lab] - vweights[s_src]
    pen = jnp.where(over_after,
                    (own_conn[s_src] // pen_den) * pen_num, 0)
    ok = ~staying
    if restricted:
        ok &= parent[s_lab] == parent[own_lab]
    # clamping to -1 loses nothing: a candidate with penalized score < 0
    # can never pass the move rule (it would need score >= own_conn >= 0)
    score = jnp.where(ok, jnp.maximum(conn - pen, -1), -1)
    best, target = _argmax_target(s_src, s_lab, score,
                                  block_w[s_lab], salt, n)
    gain = best - own_conn
    tgt_safe = jnp.where(target < I32_MAX, target, 0)
    lighter = block_w[tgt_safe] < block_w[labels] - vweights
    move = (target < I32_MAX) & (best >= 0) & \
        ((gain > 0) | ((gain == 0) & lighter))
    move = move.at[n].set(False)
    new_labels = jnp.where(move, tgt_safe, labels)
    vw_moved = jnp.where(move, vweights, 0)
    k = block_w.shape[0]
    d_in = jax.ops.segment_sum(vw_moved, jnp.where(move, tgt_safe, 0),
                               num_segments=k)
    d_out = jax.ops.segment_sum(vw_moved, jnp.where(move, labels, 0),
                                num_segments=k)
    return new_labels, block_w + d_in - d_out


@functools.partial(jax.jit, static_argnames=("n", "restricted"))
def urefine_iteration(labels, block_w, l_max, parent, chunks_src,
                      chunks_dst, chunks_w, vweights, seed, pen_num,
                      pen_den, *, n, restricted=False):
    """One unconstrained refinement pass over all chunks. ``pen_num`` /
    ``pen_den`` are traced int32 scalars so every round of the schedule
    shares one compiled program."""
    B = chunks_src.shape[0]

    def body(carry, xs):
        labels, block_w = carry
        c_src, c_dst, c_w, salt = xs
        labels, block_w = _urefine_chunk(
            labels, block_w, l_max, parent, c_src, c_dst, c_w, vweights,
            salt, pen_num, pen_den, n, restricted)
        return (labels, block_w), ()

    salts = (jnp.arange(B, dtype=jnp.uint32) * np.uint32(0xC2B2AE35)
             + seed.astype(jnp.uint32))
    (labels, block_w), _ = jax.lax.scan(
        body, (labels, block_w), (chunks_src, chunks_dst, chunks_w, salts))
    return labels, block_w


def unconstrained_refine(g: Graph,
                         part: np.ndarray,
                         l_max_vec: np.ndarray,
                         parent: Optional[np.ndarray] = None,
                         num_iterations: int = 2,
                         num_chunks: int = 8,
                         seed: int = 0,
                         stats: Optional[Dict] = None) -> np.ndarray:
    """Host driver: chunked unconstrained refinement (jitted inner loops).

    Same skeleton as ``refinement.lp_refine`` — degree-bucket reorder,
    padded arc slabs, one ``urefine_iteration`` per round — but the
    result may violate the per-block budgets; callers must follow with
    ``balance.rebalance`` (``balance_and_refine`` does). ``stats``,
    when given, receives the ``penalty`` schedule actually applied."""
    n = g.n
    k = int(l_max_vec.shape[0])
    if stats is not None:
        stats["penalty"] = penalty_schedule(num_iterations)
    if n == 0 or k <= 1 or num_iterations < 1:
        return part
    rng = np.random.default_rng(seed)
    order = degree_bucket_order(g, rng)
    perm = np.empty(n, dtype=np.int64)
    perm[order] = np.arange(n)
    g2, _ = permute(g, perm)
    part2 = np.empty(n, dtype=np.int64)
    part2[perm] = part
    chunks = lp.build_chunks(g2, num_chunks)
    n_pad = chunks.n_pad
    labels = np.zeros(n_pad + 1, dtype=np.int32)
    labels[:n] = part2
    vw = np.zeros(n_pad + 1, dtype=np.int32)
    vw[:n] = g2.vweights
    block_w = np.zeros(k, dtype=np.int64)
    np.add.at(block_w, part, g.vweights)
    from .refinement import pad_blocks   # deferred: refinement imports us
    bw_p, lv_p, pr_p, _ = pad_blocks(block_w, l_max_vec, parent)
    labels = jnp.asarray(labels)
    vw_j = jnp.asarray(vw)
    block_w = jnp.asarray(bw_p)
    l_max_j = jnp.asarray(lv_p)
    parent_j = jnp.asarray(pr_p)
    restricted = parent is not None
    pen_den = jnp.int32(num_iterations)
    for it in range(num_iterations):
        labels, block_w = urefine_iteration(
            labels, block_w, l_max_j, parent_j,
            jnp.asarray(chunks.src), jnp.asarray(chunks.dst),
            jnp.asarray(chunks.w), vw_j,
            jnp.uint32((seed * 2654435761 + it) % (2**32)),
            jnp.int32(it), pen_den, n=n_pad, restricted=restricted)
    out2 = np.asarray(labels)[:n].astype(np.int64)
    return out2[perm]
