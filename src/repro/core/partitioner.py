"""Legacy single-process entrypoint — superseded by ``repro.api``.

``partition`` is kept as a thin deprecation shim; new code should build a
``repro.api.PartitionRequest`` and run it through ``repro.api.Partitioner``
(or the ``repro.api.partition`` convenience wrapper). The preset builders
``fast_config`` / ``strong_config`` remain the canonical way to spell the
paper's two configurations and are *not* deprecated.
"""
from __future__ import annotations

import warnings
from typing import Optional

import numpy as np

from ..graphs.format import Graph
from . import metrics
from .deep_mgp import PartitionerConfig, partition as _partition


def fast_config(seed: int = 0, **overrides) -> PartitionerConfig:
    """dKaMinPar-Fast (paper §6): C=2000, 3 LP iterations."""
    return PartitionerConfig(contraction_limit=overrides.pop(
        "contraction_limit", 2000), cluster_iterations=overrides.pop(
        "cluster_iterations", 3), seed=seed, **overrides)


def strong_config(seed: int = 0, **overrides) -> PartitionerConfig:
    """dKaMinPar-Strong (paper §6): C=5000, 5 LP iterations, more reps."""
    return PartitionerConfig(contraction_limit=overrides.pop(
        "contraction_limit", 5000), cluster_iterations=overrides.pop(
        "cluster_iterations", 5), ip_repetitions=overrides.pop(
        "ip_repetitions", 6), refine_iterations=overrides.pop(
        "refine_iterations", 3), seed=seed, **overrides)


PRESETS = {"fast": fast_config, "strong": strong_config}


def resolve_config(preset: str = "fast",
                   config: Optional[PartitionerConfig] = None,
                   epsilon: float = 0.03, seed: int = 0
                   ) -> PartitionerConfig:
    """One place that turns (preset, explicit config, epsilon, seed) into
    a validated ``PartitionerConfig`` — an explicit config wins."""
    if config is not None:
        return config.validate()
    try:
        builder = PRESETS[preset]
    except KeyError:
        raise ValueError(f"unknown preset {preset!r}; "
                         f"expected one of {sorted(PRESETS)}") from None
    return builder(seed=seed, epsilon=epsilon).validate()


def partition(g: Graph, k: int,
              epsilon: float = 0.03,
              config: Optional[PartitionerConfig] = None,
              seed: int = 0) -> np.ndarray:
    """Deep multilevel k-way partition of ``g`` into ``k`` blocks.

    .. deprecated:: 0.2
       Use ``repro.api.partition(g, k, ...)`` (returns a
       ``PartitionResult`` whose ``.assignment`` is this array).
    """
    warnings.warn(
        "repro.core.partitioner.partition is deprecated; use "
        "repro.api.partition / repro.api.Partitioner instead",
        DeprecationWarning, stacklevel=2)
    if k <= 1:
        return np.zeros(g.n, dtype=np.int64)
    return _partition(g, k, resolve_config("fast", config, epsilon, seed))


__all__ = ["partition", "fast_config", "strong_config", "resolve_config",
           "PRESETS", "PartitionerConfig", "metrics"]
