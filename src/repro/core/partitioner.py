"""Preset configurations for the paper's two partitioner variants.

The preset builders ``fast_config`` / ``strong_config`` are the
canonical way to spell the paper's configurations; ``resolve_config``
turns (preset, explicit config, epsilon, seed) into a validated
``PartitionerConfig``. The legacy ``partition`` entrypoint that lived
here was deprecated in the ``repro.api`` release and has been removed —
use ``repro.api.partition(g, k, ...)`` (see docs/API.md's migration
table) or call ``repro.core.deep_mgp.partition`` directly.
"""
from __future__ import annotations

from typing import Optional

from . import metrics
from .deep_mgp import PartitionerConfig


def fast_config(seed: int = 0, **overrides) -> PartitionerConfig:
    """dKaMinPar-Fast (paper §6): C=2000, 3 LP iterations."""
    return PartitionerConfig(contraction_limit=overrides.pop(
        "contraction_limit", 2000), cluster_iterations=overrides.pop(
        "cluster_iterations", 3), seed=seed, **overrides)


def strong_config(seed: int = 0, **overrides) -> PartitionerConfig:
    """dKaMinPar-Strong (paper §6): C=5000, 5 LP iterations, more reps."""
    return PartitionerConfig(contraction_limit=overrides.pop(
        "contraction_limit", 5000), cluster_iterations=overrides.pop(
        "cluster_iterations", 5), ip_repetitions=overrides.pop(
        "ip_repetitions", 6), refine_iterations=overrides.pop(
        "refine_iterations", 3), seed=seed, **overrides)


PRESETS = {"fast": fast_config, "strong": strong_config}


def resolve_config(preset: str = "fast",
                   config: Optional[PartitionerConfig] = None,
                   epsilon: float = 0.03, seed: int = 0
                   ) -> PartitionerConfig:
    """One place that turns (preset, explicit config, epsilon, seed) into
    a validated ``PartitionerConfig`` — an explicit config wins."""
    if config is not None:
        return config.validate()
    try:
        builder = PRESETS[preset]
    except KeyError:
        raise ValueError(f"unknown preset {preset!r}; "
                         f"expected one of {sorted(PRESETS)}") from None
    return builder(seed=seed, epsilon=epsilon).validate()


__all__ = ["fast_config", "strong_config", "resolve_config",
           "PRESETS", "PartitionerConfig", "metrics"]
