"""Public partitioner API."""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..graphs.format import Graph
from . import metrics
from .deep_mgp import PartitionerConfig, partition as _partition


def fast_config(seed: int = 0, **overrides) -> PartitionerConfig:
    """dKaMinPar-Fast (paper §6): C=2000, 3 LP iterations."""
    return PartitionerConfig(contraction_limit=overrides.pop(
        "contraction_limit", 2000), cluster_iterations=overrides.pop(
        "cluster_iterations", 3), seed=seed, **overrides)


def strong_config(seed: int = 0, **overrides) -> PartitionerConfig:
    """dKaMinPar-Strong (paper §6): C=5000, 5 LP iterations, more reps."""
    return PartitionerConfig(contraction_limit=overrides.pop(
        "contraction_limit", 5000), cluster_iterations=overrides.pop(
        "cluster_iterations", 5), ip_repetitions=overrides.pop(
        "ip_repetitions", 6), refine_iterations=overrides.pop(
        "refine_iterations", 3), seed=seed, **overrides)


def partition(g: Graph, k: int,
              epsilon: float = 0.03,
              config: Optional[PartitionerConfig] = None,
              seed: int = 0) -> np.ndarray:
    """Deep multilevel k-way partition of ``g`` into ``k`` blocks.

    Returns an (n,) int64 array of block ids. The result always satisfies
    the paper's (relaxed) balance constraint — validated by
    ``metrics.is_feasible``.
    """
    if config is None:
        config = fast_config(seed=seed, epsilon=epsilon)
    if k <= 1:
        return np.zeros(g.n, dtype=np.int64)
    return _partition(g, k, config)


__all__ = ["partition", "fast_config", "strong_config", "PartitionerConfig",
           "metrics"]
