"""k-way refinement drivers (paper §4 + the unconstrained tier).

``balance_and_refine`` is the per-level entry point: restore
feasibility, improve, re-restore. The improvement pass is selected by
the ``refine`` knob — ``"lp"`` (default) is the paper's size-constrained
LP; ``"unconstrained"`` is the Jet-style penalty-weighted search of
``core.unconstrained`` whose trailing rebalance acts as the feasibility
*afterburner* (docs/REFINEMENT.md). Either way the function never
returns an infeasible partition.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..graphs.format import Graph, degree_bucket_order, permute
from . import balance as bal
from . import lp

_BIG_L = np.int32(2**31 - 1)


def pad_blocks(block_w: np.ndarray, l_max_vec: np.ndarray,
               parent: Optional[np.ndarray], min_bucket: int = 64
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Pad the block count to a power-of-two bucket (>= min_bucket) with
    unreachable dummy blocks so jitted programs are shared across k:
    dummies carry the maximal int32 weight (so ``argmin`` never picks one
    as the balancer's lightest-block fallback — with the historical 2^30
    filler a dummy *could* win once every real block exceeded 2^30, and
    the balancer then emitted block ids >= k), have the same maximal
    budget (never overloaded, never a fitting target) and are adjacent to
    no vertex (never an adjacency target).

    Block weights must fit int32 — the jit tables are int32 throughout —
    so overlarge totals raise a ``ValueError`` instead of silently
    wrapping (the historical cast inverted the ``block_w > l_max``
    overload test)."""
    k = int(block_w.shape[0])
    if np.any(block_w.astype(np.int64) > int(_BIG_L)) or \
            np.any(block_w.astype(np.int64) < 0):
        raise ValueError(
            "pad_blocks: block weights must fit int32 (max "
            f"{int(block_w.max())}); totals >= 2^31 are not supported by "
            "the int32 jit path")
    k_pad = max(min_bucket, 1 << max(0, (k - 1)).bit_length())
    if k_pad == k:
        p = parent if parent is not None else np.arange(k)
        return (block_w.astype(np.int32),
                np.minimum(l_max_vec, _BIG_L).astype(np.int32),
                p.astype(np.int32), k)
    bw = np.full(k_pad, _BIG_L, dtype=np.int32)
    bw[:k] = block_w
    lv = np.full(k_pad, _BIG_L, dtype=np.int32)
    lv[:k] = np.minimum(l_max_vec, _BIG_L)
    pr = np.arange(k_pad, dtype=np.int32)
    if parent is not None:
        pr[:k] = parent
    else:
        pr[:k] = np.arange(k)
    return bw, lv, pr, k


def lp_refine(g: Graph,
              part: np.ndarray,
              l_max_vec: np.ndarray,
              parent: Optional[np.ndarray] = None,
              num_iterations: int = 2,
              num_chunks: int = 8,
              seed: int = 0) -> np.ndarray:
    """Chunked size-constrained LP refinement (jitted inner loops)."""
    n = g.n
    k = int(l_max_vec.shape[0])
    if n == 0 or k <= 1:
        return part
    rng = np.random.default_rng(seed)
    order = degree_bucket_order(g, rng)
    perm = np.empty(n, dtype=np.int64)
    perm[order] = np.arange(n)
    g2, _ = permute(g, perm)
    part2 = np.empty(n, dtype=np.int64)
    part2[perm] = part  # part2[new_id] = part[old_id]
    chunks = lp.build_chunks(g2, num_chunks)
    n_pad = chunks.n_pad
    labels = np.zeros(n_pad + 1, dtype=np.int32)
    labels[:n] = part2
    vw = np.zeros(n_pad + 1, dtype=np.int32)
    vw[:n] = g2.vweights
    block_w = np.zeros(k, dtype=np.int64)
    np.add.at(block_w, part, g.vweights)
    bw_p, lv_p, pr_p, _ = pad_blocks(block_w, l_max_vec, parent)
    labels = jnp.asarray(labels)
    vw_j = jnp.asarray(vw)
    block_w = jnp.asarray(bw_p)
    l_max_j = jnp.asarray(lv_p)
    parent_j = jnp.asarray(pr_p)
    restricted = parent is not None
    for it in range(num_iterations):
        labels, block_w = lp.refine_iteration(
            labels, block_w, l_max_j, parent_j,
            jnp.asarray(chunks.src), jnp.asarray(chunks.dst),
            jnp.asarray(chunks.w), vw_j,
            jnp.uint32((seed * 2654435761 + it) % (2**32)), n=n_pad,
            restricted=restricted)
    out2 = np.asarray(labels)[:n].astype(np.int64)
    return out2[perm]  # back to original ids: part[old] = out2[perm[old]]


REFINE_MODES = ("lp", "unconstrained")


def check_refine_mode(refine: str) -> str:
    if refine not in REFINE_MODES:
        raise ValueError(f"unknown refine mode {refine!r}; expected one "
                         f"of {REFINE_MODES}")
    return refine


def balance_and_refine(g: Graph,
                       part: np.ndarray,
                       l_max_vec: np.ndarray,
                       parent: Optional[np.ndarray] = None,
                       num_iterations: int = 2,
                       num_chunks: int = 8,
                       seed: int = 0,
                       kernel: str = "auto",
                       refine: str = "lp",
                       stats: Optional[Dict] = None) -> np.ndarray:
    """Paper's BalanceAndRefine: restore feasibility, improve, re-restore.

    ``refine="unconstrained"`` swaps the improvement pass for the
    penalty-weighted unconstrained search; the trailing rebalance then
    acts as the feasibility afterburner, so the result satisfies the
    budgets under either mode. ``stats`` (unconstrained mode only)
    receives the ``penalty`` schedule and the afterburner's
    ``repair_rounds``."""
    check_refine_mode(refine)
    part = bal.rebalance(g, part, l_max_vec, parent=parent, seed=seed,
                         kernel=kernel)
    if refine == "unconstrained":
        from .unconstrained import unconstrained_refine
        part = unconstrained_refine(g, part, l_max_vec, parent=parent,
                                    num_iterations=num_iterations,
                                    num_chunks=num_chunks, seed=seed,
                                    stats=stats)
        repair: Dict = {}
        part = bal.rebalance(g, part, l_max_vec, parent=parent,
                             seed=seed + 1, kernel=kernel, stats=repair)
        if stats is not None:
            stats["repair_rounds"] = repair.get("rounds")
        return part
    part = lp_refine(g, part, l_max_vec, parent=parent,
                     num_iterations=num_iterations,
                     num_chunks=num_chunks, seed=seed)
    part = bal.rebalance(g, part, l_max_vec, parent=parent, seed=seed + 1,
                         kernel=kernel)
    return part
