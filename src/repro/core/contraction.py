"""Cluster contraction (paper §5, Graph Contraction) — host side.

Deduplicates inter-cluster arcs and accumulates vertex/edge weights. The
distributed version (dist/dist_partitioner.py) adds the cluster->PE
assignment and the all-to-all edge exchange; the sequential kernel below is
shared by both (per-PE local contraction)."""
from __future__ import annotations

from typing import Tuple

import numpy as np

from ..graphs.format import Graph, from_coo


def contract(g: Graph, labels: np.ndarray) -> Tuple[Graph, np.ndarray]:
    """Contract clustering ``labels`` (arbitrary ids). Returns
    (coarse_graph, fine_to_coarse) with fine_to_coarse[v] in [0, n_c)."""
    uniq, cl = np.unique(labels, return_inverse=True)
    nc = int(uniq.size)
    cvw = np.zeros(nc, dtype=np.int64)
    np.add.at(cvw, cl, g.vweights)
    src = g.arc_tails()
    csrc = cl[src]
    cdst = cl[g.adjncy]
    keep = csrc != cdst
    gc = from_coo(nc, csrc[keep], cdst[keep], eweights=g.eweights[keep],
                  vweights=cvw, symmetrize=False, dedup=True)
    return gc, cl.astype(np.int64)
