"""Cluster contraction (paper §5, Graph Contraction) — host side.

Deduplicates inter-cluster arcs and accumulates vertex/edge weights. The
distributed version (dist/dist_contraction.py) adds the cluster->PE
assignment and the all-to-all edge exchange; ``dedup_arcs`` below is the
sequential kernel shared by both (the host contraction here, the per-PE
local pre-contraction and owner-side accumulation there)."""
from __future__ import annotations

from typing import Tuple

import numpy as np

from ..graphs.format import Graph, from_coo
from ..kernels import dispatch


def dedup_arcs(csrc: np.ndarray, cdst: np.ndarray, w: np.ndarray,
               kernel: str = "composed"
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Drop self loops and merge parallel arcs (summing weights).

    Returns (src, dst, w) int64 arrays sorted by (src, dst). This is the
    local contraction kernel: ``contract`` runs it over the whole arc
    set, the distributed path runs it per PE before and after the edge
    exchange. ``kernel="fused"`` routes through the seg_merge Pallas
    kernel (bit-identical; keeps numpy when the records exceed the
    kernel's int32/VMEM envelope, reported via
    ``dispatch.report_fallback``).
    """
    if dispatch.resolve_kernel_mode(kernel) == "fused":
        from ..kernels.seg_merge import ops as seg_ops
        if seg_ops.dedup_fits(csrc, cdst, w):
            return seg_ops.dedup_arcs_fused(
                csrc, cdst, w, interpret=dispatch.kernel_interpret())
        if csrc.size:
            from ..kernels.seg_merge.seg_merge import seg_merge_vmem_bytes
            dispatch.report_fallback(
                "seg_merge", seg_merge_vmem_bytes(csrc.size),
                detail="dedup_arcs (int32/VMEM envelope)")
    keep = csrc != cdst
    csrc, cdst, w = csrc[keep], cdst[keep], w[keep]
    if csrc.size == 0:
        return (csrc.astype(np.int64), cdst.astype(np.int64),
                w.astype(np.int64))
    order = np.lexsort((cdst, csrc))
    csrc, cdst, w = csrc[order], cdst[order], w[order]
    first = np.concatenate(
        [[True], (csrc[1:] != csrc[:-1]) | (cdst[1:] != cdst[:-1])])
    seg = np.cumsum(first) - 1
    merged = np.zeros(int(seg[-1]) + 1, dtype=np.int64)
    np.add.at(merged, seg, w)
    return (csrc[first].astype(np.int64), cdst[first].astype(np.int64),
            merged)


def contract(g: Graph, labels: np.ndarray,
             kernel: str = "composed") -> Tuple[Graph, np.ndarray]:
    """Contract clustering ``labels`` (arbitrary ids). Returns
    (coarse_graph, fine_to_coarse) with fine_to_coarse[v] in [0, n_c)."""
    uniq, cl = np.unique(labels, return_inverse=True)
    nc = int(uniq.size)
    cvw = np.zeros(nc, dtype=np.int64)
    np.add.at(cvw, cl, g.vweights)
    src = g.arc_tails()
    csrc, cdst, w = dedup_arcs(cl[src], cl[g.adjncy], g.eweights,
                               kernel=kernel)
    gc = from_coo(nc, csrc, cdst, eweights=w, vweights=cvw,
                  symmetrize=False, dedup=False)
    return gc, cl.astype(np.int64)
