"""Initial partitioning of (small) coarsest graphs and block-induced
subgraphs (paper Algorithm 1, base case + LocalPartitioning).

The paper gathers the coarsest graph / the block-induced subgraphs on
single PEs and runs a *sequential* partitioner (KaMinPar / Mt-KaHyPar).
Our sequential partitioner is greedy graph growing + FM-lite refinement,
run with repetitions; graphs here are ~2C vertices so host numpy/heapq is
the right tool (matching the paper's design point exactly).
"""
from __future__ import annotations

import heapq
from typing import List, Tuple

import numpy as np

from ..graphs.format import Graph, induced_subgraph


def _neighbors(g: Graph, v: int) -> Tuple[np.ndarray, np.ndarray]:
    a0, a1 = int(g.indptr[v]), int(g.indptr[v + 1])
    return g.adjncy[a0:a1], g.eweights[a0:a1]


def ggg_bipartition(g: Graph, target1: int, lmax0: int, lmax1: int,
                    rng: np.random.Generator) -> np.ndarray:
    """Greedy graph growing: grow block 1 from a random seed by max gain
    until it reaches ``target1`` (and block 0 fits ``lmax0``)."""
    n = g.n
    part = np.zeros(n, dtype=np.int64)
    if n == 0:
        return part
    vw = g.vweights
    total = int(vw.sum())
    min_w1 = max(0, total - lmax0)
    # initial gains: joining an empty B1 loses all incident weight
    wdeg = np.zeros(n, dtype=np.int64)
    np.add.at(wdeg, g.arc_tails(), g.eweights)
    gain = -wdeg
    in1 = np.zeros(n, dtype=bool)
    heap: list = []
    seed = int(rng.integers(n))
    heapq.heappush(heap, (0, seed))
    gain[seed] = 0
    w1 = 0
    visited_push = np.zeros(n, dtype=bool)
    visited_push[seed] = True
    # iteration guard: when no remaining vertex fits lmax1 but min_w1 is
    # unreachable (overweight parent block), the grow loop cannot make
    # progress — bail out and let the balancer repair feasibility
    budget = 8 * n + 64
    while w1 < target1 or w1 < min_w1:
        budget -= 1
        if budget <= 0:
            break
        if not heap:
            rest = np.flatnonzero(~in1)
            if rest.size == 0:
                break
            fits = rest[vw[rest] + w1 <= lmax1]
            if fits.size == 0:
                break
            v = int(rng.choice(fits))
            heapq.heappush(heap, (-int(gain[v]), v))
            visited_push[v] = True
            continue
        negg, v = heapq.heappop(heap)
        if in1[v] or -negg != gain[v]:
            continue  # stale entry
        if w1 + int(vw[v]) > lmax1:
            continue
        in1[v] = True
        w1 += int(vw[v])
        nbr, nw = _neighbors(g, v)
        upd = nbr[~in1[nbr]]
        uw = nw[~in1[nbr]]
        gain[upd] += 2 * uw
        for u, _ in zip(upd.tolist(), uw.tolist()):
            heapq.heappush(heap, (-int(gain[u]), u))
            visited_push[u] = True
    part[in1] = 1
    return part


def fm_lite_refine(g: Graph, part: np.ndarray, lmax: np.ndarray,
                   rounds: int = 3) -> np.ndarray:
    """Greedy sequential 2-way refinement with live gain updates."""
    n = g.n
    if n == 0:
        return part
    part = part.copy()
    vw = g.vweights
    src = g.arc_tails()
    for _ in range(rounds):
        conn = np.zeros((n, 2), dtype=np.int64)
        np.add.at(conn, (src, part[g.adjncy]), g.eweights)
        own = conn[np.arange(n), part]
        oth = conn[np.arange(n), 1 - part]
        gains = oth - own
        bw = np.zeros(2, dtype=np.int64)
        np.add.at(bw, part, vw)
        order = np.argsort(-gains, kind="stable")
        moved = 0
        for v in order.tolist():
            gcur = conn[v, 1 - part[v]] - conn[v, part[v]]
            if gcur < 0:
                break
            t = 1 - part[v]
            if bw[t] + vw[v] > lmax[t]:
                continue
            if gcur == 0 and bw[t] + vw[v] >= bw[part[v]]:
                continue  # zero-gain only if it improves balance
            bw[part[v]] -= vw[v]
            bw[t] += vw[v]
            nbr, nw = _neighbors(g, v)
            conn[nbr, part[v]] -= nw
            conn[nbr, t] += nw
            part[v] = t
            moved += 1
        if moved == 0:
            break
    return part


def bipartition(g: Graph, k1: int, k2: int, l_max_final: int,
                rng: np.random.Generator, repetitions: int = 3
                ) -> np.ndarray:
    """Bipartition with target weights proportional to (k1, k2) final
    blocks; per-side budgets ki * L_max_final. Best of ``repetitions``."""
    total = int(g.vweights.sum())
    target1 = int(round(total * k2 / (k1 + k2)))
    lmax = np.asarray([k1 * l_max_final, k2 * l_max_final], dtype=np.int64)
    best, best_key = None, None
    for _ in range(max(1, repetitions)):
        part = ggg_bipartition(g, target1, int(lmax[0]), int(lmax[1]), rng)
        part = fm_lite_refine(g, part, lmax)
        bw = np.zeros(2, dtype=np.int64)
        np.add.at(bw, part, g.vweights)
        over = max(0, int(bw[0] - lmax[0])) + max(0, int(bw[1] - lmax[1]))
        cut_arcs = part[g.arc_tails()] != part[g.adjncy]
        cut = int(g.eweights[cut_arcs].sum()) // 2
        key = (over, cut)
        if best_key is None or key < best_key:
            best, best_key = part, key
    return best


def split_count(c: int) -> Tuple[int, int]:
    return (c + 1) // 2, c // 2


def distribute_counts(k: int, k0: int) -> List[int]:
    """Distribute k final blocks over k0 produced blocks (ceil/floor)."""
    base = k // k0
    extra = k % k0
    return [base + (1 if i < extra else 0) for i in range(k0)]


def partition_into_counts(g: Graph, counts: List[int], l_max_final: int,
                          rng: np.random.Generator, repetitions: int = 3
                          ) -> np.ndarray:
    """Partition ``g`` into ``len(counts)`` blocks where block i must hold
    ~counts[i] final blocks' worth of weight (budget counts[i]*L_max).
    Returns part (n,) with block ids in counts order."""
    n = g.n
    part = np.zeros(n, dtype=np.int64)
    if len(counts) <= 1 or n == 0:
        return part
    h = len(counts) // 2
    left, right = counts[:h], counts[h:]
    k1, k2 = sum(left), sum(right)
    half = bipartition(g, k1, k2, l_max_final, rng, repetitions)
    off = 0
    for side, sub_counts in ((0, left), (1, right)):
        mask = half == side
        if len(sub_counts) == 1:
            part[mask] = off
        else:
            sub, old_ids = induced_subgraph(g, mask)
            sp = partition_into_counts(sub, sub_counts, l_max_final, rng,
                                       repetitions)
            part[old_ids] = sp + off
        off += len(sub_counts)
    return part


def recursive_bisection(g: Graph, kb: int, l_max_final: int,
                        rng: np.random.Generator, repetitions: int = 3
                        ) -> np.ndarray:
    """Partition ``g`` into ``kb`` unit blocks via recursive bisection."""
    return partition_into_counts(g, [1] * kb, l_max_final, rng, repetitions)
