"""Baseline partitioners the paper compares against (reimplemented):

  * ``single_level_lp`` — XtraPuLP-like: no multilevel; random balanced
    initial assignment + LP refinement + balancing. The paper reports
    cuts ~2x (up to 5 orders of magnitude on rhg) worse than deep MGP.
  * ``plain_mgp`` — classic multilevel (ParMETIS/ParHIP-like): coarsen only
    down to C·k vertices, direct k-way initial partition, refine up.
    Deteriorates for large k (coarsest graph too large / IP too weak).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..graphs.format import Graph
from . import metrics
from .coarsening import cluster
from .contraction import contract
from .deep_mgp import PartitionerConfig
from .initial_partition import recursive_bisection
from .refinement import balance_and_refine


def random_balanced(g: Graph, k: int, seed: int = 0) -> np.ndarray:
    """Weight-aware round-robin over a random vertex order."""
    rng = np.random.default_rng(seed)
    order = rng.permutation(g.n)
    part = np.empty(g.n, dtype=np.int64)
    # greedy: next vertex to the lightest block
    # (vectorized approximation: snake order over weight-sorted vertices)
    w = g.vweights[order]
    worder = np.argsort(-w, kind="stable")
    snake = np.arange(g.n) % (2 * k)
    snake = np.where(snake < k, snake, 2 * k - 1 - snake)
    part[order[worder]] = snake
    return part


def single_level_lp(g: Graph, k: int, eps: float = 0.03,
                    num_iterations: int = 5, seed: int = 0) -> np.ndarray:
    l_final = metrics.l_max(g.total_vweight, k,
                            eps, int(g.vweights.max()) if g.n else 1)
    part = random_balanced(g, k, seed)
    lv = np.full(k, l_final, dtype=np.int64)
    part = balance_and_refine(g, part, lv, num_iterations=num_iterations,
                              seed=seed)
    return part


def plain_mgp(g: Graph, k: int, cfg: Optional[PartitionerConfig] = None
              ) -> np.ndarray:
    cfg = cfg or PartitionerConfig()
    rng = np.random.default_rng(cfg.seed)
    total_c = g.total_vweight
    max_c = int(g.vweights.max()) if g.n else 1
    l_final = metrics.l_max(total_c, k, cfg.epsilon, max_c)
    C = cfg.contraction_limit

    hierarchy = []
    G = g
    level = 0
    # plain MGP: contraction limit scales with k (coarsest has ~C*k vertices)
    while G.n > C * k and level < cfg.max_levels:
        kprime = max(1, min(k, G.n // max(1, C)))
        W = max(1, int(cfg.epsilon * total_c / kprime))
        labels = cluster(G, W, num_iterations=cfg.cluster_iterations,
                         num_chunks=cfg.num_chunks, seed=cfg.seed + level)
        Gc, mapping = contract(G, labels)
        if Gc.n >= G.n * cfg.min_shrink:
            break
        hierarchy.append((G, mapping))
        G = Gc
        level += 1

    part = recursive_bisection(G, k, l_final, rng, cfg.ip_repetitions)
    lv = np.full(k, l_final, dtype=np.int64)
    part = balance_and_refine(G, part, lv,
                              num_iterations=cfg.refine_iterations,
                              num_chunks=cfg.num_chunks, seed=cfg.seed)
    for (Gf, mapping) in reversed(hierarchy):
        part = part[mapping]
        part = balance_and_refine(Gf, part, lv,
                                  num_iterations=cfg.refine_iterations,
                                  num_chunks=cfg.num_chunks,
                                  seed=cfg.seed + Gf.n % 1000003)
    return part
