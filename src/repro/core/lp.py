"""Size-constrained label propagation, vectorized for XLA.

This is the paper's workhorse (coarsening clustering *and* k-way refinement).
The MPI original iterates vertices sequentially inside batches; the TPU-native
adaptation processes a *chunk* of vertices at once:

  gains:   sort arcs by (src, label[dst])  ->  per-(src,label) run lengths
           -> segment_sum of arc weights   ->  per-src argmax with tie-breaks
  races:   optimistic moves + the paper's own overweight-revert mechanism
           absorb intra-chunk weight races (Section 4, Coarsening).

Chunks are *contiguous vertex ranges* of the degree-bucket-reordered graph
(paper Section 4 iteration order), balanced by arc count so every chunk's
padded arc slab has the same static shape — one jitted program per level.

All jit-side integers are int32; the host driver guarantees total vertex /
edge weight < 2**31 (asserted at build).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs.format import Graph

I32_MAX = np.int32(np.iinfo(np.int32).max)


# ---------------------------------------------------------------------------
# Host-side chunk construction
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LPChunks:
    """Padded per-chunk arc slabs. Sentinel arcs: src = dst = n_pad, w = 0.

    ``n_pad`` and ``m_pad`` are rounded to powers of two so that the jitted
    per-level programs hit a small cache of shape buckets instead of
    recompiling for every hierarchy level.
    """
    src: np.ndarray   # (B, m_pad) int32
    dst: np.ndarray   # (B, m_pad) int32
    w: np.ndarray     # (B, m_pad) int32
    n: int            # true vertex count
    n_pad: int        # padded (power-of-two) vertex count == sentinel id
    num_chunks: int


def _next_pow2(x: int) -> int:
    return 1 << max(0, (x - 1)).bit_length()


def chunk_bounds(g: Graph, num_chunks: int) -> list:
    """Chunk boundaries: contiguous vertex ranges with ~equal arc counts.
    Returns ``B + 1`` vertex ids; chunk ``b`` covers ``[bounds[b],
    bounds[b+1])``. Shared by the arc-slab (composed) and ELL (fused
    Pallas) chunk builders so both paths walk identical vertex ranges."""
    n, m = g.n, g.m
    B = max(1, min(num_chunks, max(1, n)))
    target = (m + B - 1) // max(B, 1) if m else 1
    bounds = [0]
    for b in range(1, B):
        v = int(np.searchsorted(g.indptr, b * target, side="left"))
        bounds.append(min(max(v, bounds[-1]), n))
    bounds.append(n)
    return bounds


def build_chunks(g: Graph, num_chunks: int, pad_shapes: bool = True) -> LPChunks:
    if g.total_eweight >= 2**31 or g.total_vweight >= 2**31:
        # a real error, not an assert: asserts vanish under ``python -O``
        # and the int32 tables would then silently wrap
        raise ValueError(
            f"build_chunks: total vertex/edge weight ({g.total_vweight}/"
            f"{g.total_eweight}) must be < 2^31 for the int32 jit path")
    n, m = g.n, g.m
    n_pad = _next_pow2(n) if pad_shapes else n
    bounds = chunk_bounds(g, num_chunks)
    B = len(bounds) - 1
    src = g.arc_tails().astype(np.int64)
    m_pad = 1
    for b in range(B):
        a0, a1 = int(g.indptr[bounds[b]]), int(g.indptr[bounds[b + 1]])
        m_pad = max(m_pad, a1 - a0)
    if pad_shapes:
        m_pad = _next_pow2(m_pad)
    slabs = []
    for b in range(B):
        a0, a1 = int(g.indptr[bounds[b]]), int(g.indptr[bounds[b + 1]])
        cnt = a1 - a0
        s = np.full(m_pad, n_pad, dtype=np.int32)
        d = np.full(m_pad, n_pad, dtype=np.int32)
        ww = np.zeros(m_pad, dtype=np.int32)
        s[:cnt] = src[a0:a1]
        d[:cnt] = g.adjncy[a0:a1]
        ww[:cnt] = g.eweights[a0:a1]
        slabs.append((s, d, ww))
    return LPChunks(src=np.stack([x[0] for x in slabs]),
                    dst=np.stack([x[1] for x in slabs]),
                    w=np.stack([x[2] for x in slabs]),
                    n=n, n_pad=n_pad, num_chunks=B)


# ---------------------------------------------------------------------------
# jit-side gain machinery
# ---------------------------------------------------------------------------

def _hash32(x: jnp.ndarray, salt: jnp.ndarray) -> jnp.ndarray:
    h = (x.astype(jnp.uint32) * np.uint32(2654435761)) ^ salt.astype(jnp.uint32)
    h = h ^ (h >> 15)
    return (h & np.uint32(0x7FFFFFFF)).astype(jnp.int32)


def _group_conns(s_src: jnp.ndarray, s_lab: jnp.ndarray, s_w: jnp.ndarray
                 ) -> jnp.ndarray:
    """Per-arc connection weight of the (src, label) group the arc belongs to.

    Inputs must be sorted by (src, label)."""
    newgrp = jnp.concatenate([
        jnp.ones((1,), jnp.bool_),
        (s_src[1:] != s_src[:-1]) | (s_lab[1:] != s_lab[:-1])])
    gid = jnp.cumsum(newgrp.astype(jnp.int32)) - 1
    conn_g = jax.ops.segment_sum(s_w, gid, num_segments=s_w.shape[0],
                                 indices_are_sorted=True)
    return conn_g[gid]


def _argmax_target(s_src, s_lab, score, weight_key, salt, n):
    """Per-src argmax of ``score`` with ties broken by (lighter weight_key,
    then hash). Returns (best_score, target_label) arrays of size n+1.
    ``score`` must be >= 0 for real candidates and < 0 for masked ones."""
    num = n + 1
    best = jax.ops.segment_max(score, s_src, num_segments=num,
                               indices_are_sorted=True)
    is_best = score == best[s_src]
    wk = jnp.where(is_best, weight_key, I32_MAX)
    light = jax.ops.segment_min(wk, s_src, num_segments=num,
                                indices_are_sorted=True)
    is_best &= weight_key == light[s_src]
    h = _hash32(s_lab, salt)
    hk = jnp.where(is_best, h, I32_MAX)
    hbest = jax.ops.segment_min(hk, s_src, num_segments=num,
                                indices_are_sorted=True)
    is_best &= h == hbest[s_src]
    lk = jnp.where(is_best, s_lab, I32_MAX)
    target = jax.ops.segment_min(lk, s_src, num_segments=num,
                                 indices_are_sorted=True)
    return best, target


def _own_connection(s_src, s_lab, s_w, labels, n):
    own = jax.ops.segment_sum(
        jnp.where(s_lab == labels[s_src], s_w, 0), s_src,
        num_segments=n + 1, indices_are_sorted=True)
    return own


# ---------------------------------------------------------------------------
# Clustering (coarsening) chunk step
# ---------------------------------------------------------------------------

def _cluster_chunk(labels, cluster_w, chunk_src, chunk_dst, chunk_w,
                   vweights, max_cluster_weight, salt, n):
    """One chunk of size-constrained LP clustering. Returns updated
    (labels, cluster_w)."""
    lab_dst = labels[chunk_dst]
    s_src, s_lab, s_w = jax.lax.sort(
        (chunk_src, lab_dst, chunk_w), num_keys=2)
    conn = _group_conns(s_src, s_lab, s_w)
    own_lab = labels[s_src]
    staying = s_lab == own_lab
    fits = (cluster_w[s_lab] + vweights[s_src] <= max_cluster_weight) | staying
    score = jnp.where(fits, conn, -1)
    best, target = _argmax_target(s_src, s_lab, score,
                                  cluster_w[s_lab], salt, n)
    own_conn = _own_connection(s_src, s_lab, s_w, labels, n)
    move = (best > own_conn) & (target != labels) & (target < I32_MAX) & (best > 0)
    move = move.at[n].set(False)
    new_labels = jnp.where(move, target, labels)
    # weight update
    vw_moved = jnp.where(move, vweights, 0)
    num = n + 1
    d_in = jax.ops.segment_sum(vw_moved, new_labels, num_segments=num)
    d_out = jax.ops.segment_sum(vw_moved, labels, num_segments=num)
    new_cw = cluster_w + d_in - d_out

    # --- overweight revert (paper Section 4, Coarsening) -------------------
    # For each cluster that exceeded W this chunk, undo the most recently
    # proposed moves (random order within the chunk) until it fits again.
    over = new_cw > max_cluster_weight
    cand = move & over[new_labels]
    rk = _hash32(jnp.arange(num, dtype=jnp.int32), salt ^ np.uint32(0x9E3779B9))
    sort_lab = jnp.where(cand, new_labels, jnp.int32(num))
    o_lab, o_rk, o_v = jax.lax.sort(
        (sort_lab, rk, jnp.arange(num, dtype=jnp.int32)), num_keys=2)
    o_vw = jnp.where(o_lab < num, vweights[o_v], 0)
    csum = jnp.cumsum(o_vw)
    grp_start = jnp.concatenate([
        jnp.ones((1,), jnp.bool_), o_lab[1:] != o_lab[:-1]])
    gid = jnp.cumsum(grp_start.astype(jnp.int32)) - 1
    base = jax.ops.segment_min(jnp.where(grp_start, csum - o_vw, I32_MAX),
                               gid, num_segments=num)
    within = csum - base[gid]             # cumulative moved-in weight incl self
    lab_safe = jnp.where(o_lab < num, o_lab, 0)
    pre_w = new_cw[lab_safe] - (d_in - d_out)[lab_safe] \
        + jnp.zeros_like(csum)            # weight before this chunk's moves
    # moved-out weight also changed pre->new; allowed extra for moved-in:
    allowed = jnp.maximum(max_cluster_weight - (new_cw[lab_safe] -
                          jax.ops.segment_sum(o_vw, gid, num_segments=num)[gid]),
                          0)
    del pre_w
    revert = (o_lab < num) & (within > allowed)
    rv = jnp.zeros(num, dtype=jnp.bool_).at[o_v].set(revert, mode="drop")
    rv &= move
    final_labels = jnp.where(rv, labels, new_labels)
    vw_rv = jnp.where(rv, vweights, 0)
    r_in = jax.ops.segment_sum(vw_rv, labels, num_segments=num)
    r_out = jax.ops.segment_sum(vw_rv, new_labels, num_segments=num)
    final_cw = new_cw + r_in - r_out
    return final_labels, final_cw


def _cluster_iteration_impl(labels, cluster_w, chunks_src, chunks_dst,
                            chunks_w, vweights, max_cluster_weight, seed, n):
    """One full LP-clustering iteration over all chunks (traceable body
    shared by the solo jit and the stacked vmap entry points)."""
    B = chunks_src.shape[0]

    def body(carry, xs):
        labels, cluster_w = carry
        c_src, c_dst, c_w, salt = xs
        labels, cluster_w = _cluster_chunk(
            labels, cluster_w, c_src, c_dst, c_w, vweights,
            max_cluster_weight, salt, n)
        return (labels, cluster_w), ()

    salts = (jnp.arange(B, dtype=jnp.uint32) * np.uint32(0x85EBCA6B)
             + seed.astype(jnp.uint32))
    (labels, cluster_w), _ = jax.lax.scan(
        body, (labels, cluster_w), (chunks_src, chunks_dst, chunks_w, salts))
    return labels, cluster_w


@functools.partial(jax.jit, static_argnames=("n",))
def cluster_iteration(labels, cluster_w, chunks_src, chunks_dst, chunks_w,
                      vweights, max_cluster_weight, seed, *, n):
    """One full LP-clustering iteration over all chunks."""
    return _cluster_iteration_impl(labels, cluster_w, chunks_src, chunks_dst,
                                   chunks_w, vweights, max_cluster_weight,
                                   seed, n)


@functools.partial(jax.jit, static_argnames=("n",))
def cluster_iteration_stacked(labels, cluster_w, chunks_src, chunks_dst,
                              chunks_w, vweights, max_cluster_weight, seed,
                              *, n):
    """``cluster_iteration`` with a leading request axis: every operand
    carries an extra dim R and requests run as one vmapped program.

    Per-row results are bit-identical to the solo entry point at the
    same padded shape: the body is integer-only, vmap of integer ops is
    exactly semantics-preserving, and padded rows/columns are inert
    (weight-0 singleton vertices with sentinel arcs never move and are
    never adopted as targets — see ``repro.serve.batching``)."""
    return jax.vmap(
        lambda la, cw, cs, cd, cww, vw, mw, sd: _cluster_iteration_impl(
            la, cw, cs, cd, cww, vw, mw, sd, n)
    )(labels, cluster_w, chunks_src, chunks_dst, chunks_w, vweights,
      max_cluster_weight, seed)


# ---------------------------------------------------------------------------
# k-way refinement chunk step
# ---------------------------------------------------------------------------

def _refine_chunk(labels, block_w, l_max, parent, chunk_src, chunk_dst,
                  chunk_w, vweights, salt, n, restricted):
    """One chunk of size-constrained LP refinement over k blocks.

    ``l_max`` is a per-block budget vector (k,) — deep MGP refines
    intermediate partitions whose blocks represent different numbers of
    final blocks. With ``restricted=True`` moves are confined to blocks
    sharing a parent (the partition-extension step: each block of the
    previous partition was split and refinement may only shuffle vertices
    between siblings).
    """
    lab_dst = labels[chunk_dst]
    s_src, s_lab, s_w = jax.lax.sort(
        (chunk_src, lab_dst, chunk_w), num_keys=2)
    conn = _group_conns(s_src, s_lab, s_w)
    own_lab = labels[s_src]
    staying = s_lab == own_lab
    # weight comparisons arranged as ``w <= budget - c`` so they cannot
    # wrap when the totals approach the int32 boundary
    fits = (block_w[s_lab] <= l_max[s_lab] - vweights[s_src]) & ~staying
    if restricted:
        fits &= parent[s_lab] == parent[own_lab]
    score = jnp.where(fits, conn, -1)
    best, target = _argmax_target(s_src, s_lab, score,
                                  block_w[s_lab], salt, n)
    own_conn = _own_connection(s_src, s_lab, s_w, labels, n)
    gain = best - own_conn
    tgt_safe = jnp.where(target < I32_MAX, target, 0)
    # move on strict gain; zero-gain moves only if they strictly improve
    # balance (paper: ties broken in favor of the lighter block)
    lighter = block_w[tgt_safe] < block_w[labels] - vweights
    move = (target < I32_MAX) & (best >= 0) & \
        ((gain > 0) | ((gain == 0) & lighter))
    move = move.at[n].set(False)
    new_labels = jnp.where(move, tgt_safe, labels)
    vw_moved = jnp.where(move, vweights, 0)
    k = block_w.shape[0]
    d_in = jax.ops.segment_sum(vw_moved, jnp.where(move, tgt_safe, 0),
                               num_segments=k)
    d_out = jax.ops.segment_sum(vw_moved, jnp.where(move, labels, 0),
                                num_segments=k)
    return new_labels, block_w + d_in - d_out


@functools.partial(jax.jit, static_argnames=("n", "restricted"))
def refine_iteration(labels, block_w, l_max, parent, chunks_src, chunks_dst,
                     chunks_w, vweights, seed, *, n, restricted=False):
    B = chunks_src.shape[0]

    def body(carry, xs):
        labels, block_w = carry
        c_src, c_dst, c_w, salt = xs
        labels, block_w = _refine_chunk(
            labels, block_w, l_max, parent, c_src, c_dst, c_w, vweights,
            salt, n, restricted)
        return (labels, block_w), ()

    salts = (jnp.arange(B, dtype=jnp.uint32) * np.uint32(0xC2B2AE35)
             + seed.astype(jnp.uint32))
    (labels, block_w), _ = jax.lax.scan(
        body, (labels, block_w), (chunks_src, chunks_dst, chunks_w, salts))
    return labels, block_w
