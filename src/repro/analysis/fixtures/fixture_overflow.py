"""Seeded overflow violation: the unguarded int32 sum-form admission
check ``cluster_w[label] + vweight <= budget`` — exactly the wrap
PR 4 rewrote into the guard form ``w <= budget - c``. The overflow
pass must flag the comparison (OFL001).
"""

from __future__ import annotations

from typing import Any, List, Tuple


def captured() -> List[Tuple[str, Any]]:
    """Stage the defective program; returns ``[(name, jaxpr)]``."""
    import jax
    import jax.numpy as jnp

    def admit(cluster_w, vweights, labels, budget):
        cw = cluster_w[labels]
        proposed = cw + vweights  # int32 sum that can wrap negative
        return proposed <= budget  # unguarded order comparison

    n = 8
    cw = jnp.ones((n,), jnp.int32)
    vw = jnp.ones((n,), jnp.int32)
    lab = jnp.zeros((n,), jnp.int32)
    bud = jnp.full((n,), 100, jnp.int32)
    return [("fixture_overflow", jax.make_jaxpr(admit)(cw, vw, lab, bud))]
