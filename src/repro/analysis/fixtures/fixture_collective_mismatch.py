"""Seeded SPMD violation: a ``lax.cond`` inside a ``shard_map`` body
whose branches issue different collective sequences (one psums, the
other computes locally). If PEs diverge on the predicate, the psum
deadlocks — the collectives pass must flag this (SPMD002), and the
``check_rep=False`` staging is deliberately *not* allowlisted
(SPMD003).
"""

from __future__ import annotations

from typing import Any, List, Tuple


def captured(P: int = 2) -> List[Tuple[str, Any]]:
    """Stage the defective program; returns ``[(name, jaxpr)]``."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh
    from jax.sharding import PartitionSpec as PS

    from repro.dist.compat import shard_map

    mesh = Mesh(np.array(jax.devices()[:P]), ("pe",))

    def body(x):
        pred = x[0, 0] > 0

        def with_psum(v):
            return jax.lax.psum(v, "pe")

        def without(v):
            return v * 2

        return jax.lax.cond(pred, with_psum, without, x)

    fn = jax.jit(
        shard_map(
            body,
            mesh=mesh,
            in_specs=PS("pe"),
            out_specs=PS("pe"),
            check_rep=False,
        )
    )
    x = jnp.zeros((P, 4), jnp.int32)
    return [("fixture_collective_mismatch", jax.make_jaxpr(fn)(x))]
