"""Seeded lint violations (AST-scanned only, never imported by the
pipeline): a jit-staged function calling host numpy and the Python
RNG (LNT001), a ``shard_map`` call without ``check_rep=`` (LNT002),
and a ``.item()`` device sync treated as serve-hot-path code
(LNT003).
"""

import functools
import random

import jax
import numpy as np


@functools.partial(jax.jit, static_argnames=())
def staged_bad(x):
    noise = np.random.rand(*x.shape)  # LNT001: host RNG under jit
    pick = random.random()  # LNT001: Python RNG under jit
    return x + noise + pick


def build(mesh, spec, shard_map):
    return shard_map(  # LNT002: no explicit check_rep=
        lambda v: v,
        mesh=mesh,
        in_specs=spec,
        out_specs=spec,
    )


def hot_path(result):
    return result.assignment.item()  # LNT003: device sync per request
