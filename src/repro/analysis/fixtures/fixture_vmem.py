"""Seeded VMEM estimator violation: a broken static inventory that
forgot a fifth of the working set (think: the row-tile pairwise masks
dropped from the ledger). The cross-check against the runtime gate
must flag the divergence (VMEM001).
"""

from __future__ import annotations


def static_bytes(kernel: str, point: dict) -> int:
    """A 20%-under inventory — beyond the 5% agreement budget."""
    from repro.analysis import vmem

    return int(vmem._static_bytes(kernel, point) * 0.8)
