"""Seeded-violation fixtures proving each analysis pass fires.

Each module stages (or merely contains, for the AST lint) exactly the
defect its pass exists to catch; ``python -m repro.analysis --fixture
<name>`` must exit nonzero on every one of them. Excluded from the
normal repo sweep.
"""
