"""Int32 overflow dataflow pass over captured jaxprs.

The failure mode (PR 4 fixed a batch by hand): weight arithmetic is
int32 on purpose — device tables stay compact — and the repo's
contract is that *totals* are range-checked up front
(``_check_int32_weights``, ``build_chunks``) while *per-comparison*
arithmetic must be arranged so it cannot wrap. The sanctioned guard is
the subtraction form ``w <= budget - c``; the bug shape is the sum
form ``w + c <= budget``, where ``w + c`` can exceed 2^31 - 1 and wrap
negative, silently admitting an overweight move.

The pass taints every int32 value produced by an ``add``/``mul`` of
two non-literal operands (a "summed" value that may exceed the int32
range even when both inputs are in range) and flags any order
comparison (``lt``/``le``/``gt``/``ge``) with a summed operand —
rule ``OFL001``. The guard form never performs a widening add, so it
passes untouched; an explicit widen (``add`` in int64) also passes
because the add is no longer an int32 op. Reductions
(``reduce_sum``/``cumsum``/``psum``/scatter-add) are *not* treated as
summed: they are exactly the totals the up-front range checks bound.
Unsigned int32 is excluded — the hash mixers wrap by design.

Sites that are genuinely bounded (e.g. ``cluster_w + d_in`` where
both terms are bounded by the checked global total) are suppressed
via ``[[overflow]]`` allowlist entries keyed on (file, function),
each with the reason the bound holds.
"""

from __future__ import annotations

from typing import Any, Dict, List, Tuple

from .collectives_pass import _source_site, _sub_jaxprs
from .findings import Finding, Report

# int32 add/mul of two non-literal operands -> result may be out of
# range ("summed")
_SUM_PRIMS = {"add", "mul", "sub"}
# order comparisons that silently go wrong on wrapped operands
_CMP_PRIMS = {"lt", "le", "gt", "ge"}
# reductions bounded by the repo's up-front total-weight range checks
_BOUNDED_PRIMS = {
    "reduce_sum",
    "cumsum",
    "cumlogsumexp",
    "psum",
    "psum2",
    "segment_sum",
    "reduce_max",
    "reduce_min",
    "reduce_and",
    "reduce_or",
    "argmax",
    "argmin",
    "iota",
}
# shape/select/indexing ops through which taint flows unchanged
_TRANSPARENT_PRIMS = {
    "select_n",
    "max",
    "min",
    "neg",
    "abs",
    "gather",
    "dynamic_slice",
    "dynamic_update_slice",
    "slice",
    "squeeze",
    "reshape",
    "broadcast_in_dim",
    "transpose",
    "concatenate",
    "rev",
    "expand_dims",
    "convert_element_type",
    "pad",
    "copy",
    "all_gather",
    "all_to_all",
    "ppermute",
    "pbroadcast",
    "sort",
    "dynamic_gather",
    "where",
    "clamp",
    "rem",
    "device_put",
    "optimization_barrier",
}


def _is_i32(aval: Any) -> bool:
    dtype = getattr(aval, "dtype", None)
    return dtype is not None and str(dtype) == "int32"


def _is_lit(var: Any) -> bool:
    return hasattr(var, "val")


class _Taint:
    """Per-jaxpr var -> summed flag, scoped so vars don't collide."""

    def __init__(self) -> None:
        self.summed: Dict[int, bool] = {}

    def get(self, var: Any) -> bool:
        if _is_lit(var):
            return False
        return self.summed.get(id(var), False)

    def set(self, var: Any, val: bool) -> None:
        if val:
            self.summed[id(var)] = True


def _walk(
    jaxpr: Any,
    taint: _Taint,
    entry: str,
    report: Report,
    in_summed: List[bool],
) -> List[bool]:
    """Propagate taint through ``jaxpr``; returns outvar summed flags."""
    inner = jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr
    for var, summed in zip(inner.invars, in_summed):
        taint.set(var, summed)

    for eqn in inner.eqns:
        name = eqn.primitive.name
        ops = [taint.get(v) for v in eqn.invars]

        if name in _CMP_PRIMS and any(ops):
            file, line, func = _source_site(eqn)
            report.add(
                Finding(
                    rule="OFL001",
                    pass_name="overflow",
                    message=(
                        f"{name} compares an int32 sum that can wrap "
                        "— use the guard form `w <= budget - c` or "
                        "widen to int64 before adding"
                    ),
                    file=file,
                    line=line,
                    function=func,
                    entry=entry,
                )
            )
            continue

        subs = list(_sub_jaxprs(eqn))
        if subs:
            out_flags = _run_subjaxprs(eqn, subs, taint, entry, report)
            for var, flag in zip(eqn.outvars, out_flags):
                taint.set(var, flag)
            continue

        if name in _SUM_PRIMS and len(eqn.invars) == 2:
            out = eqn.outvars[0]
            fresh = (
                name in ("add", "mul")
                and _is_i32(out.aval)
                and not any(_is_lit(v) for v in eqn.invars)
            )
            taint.set(out, fresh or any(ops))
        elif name in _BOUNDED_PRIMS:
            pass  # bounded by the up-front total range checks
        elif name in _TRANSPARENT_PRIMS or name.startswith("scatter"):
            propagate = any(ops)
            for var in eqn.outvars:
                taint.set(var, propagate)
        # anything else (hash mixers, bit ops, ...) drops taint

    return [taint.get(v) for v in inner.outvars]


def _run_subjaxprs(
    eqn: Any,
    subs: List[Tuple[str, Any]],
    taint: _Taint,
    entry: str,
    report: Report,
) -> List[bool]:
    """Map taint through call-like eqns (pjit/cond/scan/shard_map)."""
    name = eqn.primitive.name
    ops = [taint.get(v) for v in eqn.invars]
    n_out = len(eqn.outvars)
    out = [False] * n_out

    def merge(flags: List[bool]) -> None:
        for i in range(min(n_out, len(flags))):
            out[i] = out[i] or flags[i]

    for _, sub in subs:
        inner = sub.jaxpr if hasattr(sub, "jaxpr") else sub
        n_in = len(inner.invars)
        if name == "cond":
            # operand 0 is the predicate/index
            flags = ops[1 : 1 + n_in]
        elif name == "while":
            flags = ops[len(ops) - n_in :]
        else:
            flags = ops[:n_in]
        flags = flags + [False] * (n_in - len(flags))
        sub_out = _walk(sub, taint, entry, report, flags)
        if name == "scan":
            # run the body once more with carry taint fed back, so a
            # sum formed in iteration i is seen by iteration i + 1
            n_consts = int(eqn.params.get("num_consts", 0))
            n_carry = int(eqn.params.get("num_carry", 0))
            fed = list(flags)
            for i in range(min(n_carry, len(sub_out))):
                j = n_consts + i
                if j < len(fed):
                    fed[j] = fed[j] or sub_out[i]
            sub_out = _walk(sub, taint, entry, report, fed)
        merge(sub_out)
    return out


def run(jaxprs: List[Tuple[str, Any]], report: Report) -> int:
    """Run the overflow pass on every captured program."""
    checked = 0
    for item in jaxprs:
        entry, jaxpr = item[0], item[1]
        inner = jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr
        _walk(jaxpr, _Taint(), entry, report, [False] * len(inner.invars))
        checked += 1
    return checked
