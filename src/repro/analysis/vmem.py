"""Static VMEM estimator for the fused Pallas kernels.

For each kernel the repo dispatches (``lp_move``, ``seg_merge``,
``bal_round``) this module enumerates the tensors the kernel actually
keeps resident — operands, outputs, scratch, and the transient
row-tile workspaces — as ``(name, shape, dtype)`` entries derived from
the kernel signatures in ``repro.kernels``. Summing the inventory
gives a worst-case VMEM byte count as a pure function of
``(row_tile, bucket, dtype)``; the pass cross-checks it against the
runtime planning formulas (``lp_move_vmem_bytes`` & co) that gate the
fused->composed fallback (reported via ``dispatch.report_fallback``),
so the fallback boundary is unit-testable without a TPU.

Rules: ``VMEM001`` — static inventory and runtime formula diverge by
more than 5% at some grid point; ``VMEM002`` — they classify a grid
point differently against ``kernels.dispatch.VMEM_BUDGET_BYTES``
(one says the kernel fits, the other says fall back); ``VMEM003`` —
an ops module froze a stale copy of the budget constant.

Scalar operands (the ``[[W, v0]]`` / salt cells) are excluded: they
are O(1) cells, not VMEM-resident slabs, and the runtime formulas
exclude them too.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Tuple

from .findings import Finding, Report

ITEM = 4  # every kernel tensor is an int32/float32 laneset

Tensor = Tuple[str, Tuple[int, ...]]


def _next_pow2(x: int) -> int:
    p = 1
    while p < x:
        p *= 2
    return p


def lp_move_inventory(
    R: int, D: int, row_tile: int, fit_sum: bool
) -> List[Tensor]:
    """Resident tensors of ``kernels.lp_move.lp_move_chunk``."""
    tensors: List[Tensor] = [
        ("nlab", (R, D)),  # ELL neighbor labels
        ("nw", (R, D)),  # ELL arc weights
        ("ncw", (R, D)),  # gathered cluster weights
        ("own", (R, 1)),  # own-cluster connectivity column
        ("vw", (R, 1)),  # vertex weights column
        ("moved", (R, 1)),  # output: move flags
        ("tgt", (R, 1)),  # output: move targets
        ("scratch_pmove", (R, 1)),  # pre-revert move flags
        ("scratch_light", (R, 1)),  # cw[target] at chunk start
        ("scratch_cand", (R, 1)),  # revert candidates
        ("scratch_newcw", (R, 1)),  # updated target weights
        ("eq_cube", (row_tile, D, D)),  # phase-A label equality cube
        ("pair_mask_a", (row_tile, R)),  # phase-B pairwise masks
        ("pair_mask_b", (row_tile, R)),
        ("pair_mask_c", (row_tile, R)),
        ("pair_mask_d", (row_tile, R)),
    ]
    if not fit_sum:
        tensors.insert(3, ("nbud", (R, D)))  # per-target budget slab
    return tensors


def bal_round_inventory(
    R: int, D: int, row_tile: int, restricted: bool
) -> List[Tensor]:
    """Resident tensors of ``kernels.bal_round.bal_scores``."""
    tensors: List[Tensor] = [
        ("nlab", (R, D)),  # ELL neighbor labels
        ("nw", (R, D)),  # ELL arc weights
        ("nbw", (R, D)),  # gathered block weights
        ("nlm", (R, D)),  # gathered block budgets
        ("own", (R, 1)),  # own-block connectivity
        ("vw", (R, 1)),  # vertex weights
        ("ovr", (R, 1)),  # overloaded-block flags
        ("vld", (R, 1)),  # valid-row flags
        ("fb_t", (R, 1)),  # fallback targets
        ("fb_ok", (R, 1)),  # fallback admissibility
        ("rel", (R, 1)),  # output: relative gains
        ("tgt", (R, 1)),  # output: targets
        ("eq_cube", (row_tile, D, D)),  # row-tile equality cube
    ]
    if restricted:
        tensors.insert(4, ("npar", (R, D)))  # gathered parent ids
        tensors.insert(5, ("opar", (R, 1)))  # own parent column
    return tensors


def seg_merge_inventory(L: int) -> List[Tensor]:
    """Resident lanesets of ``kernels.seg_merge.seg_merge``."""
    Lp = max(2, _next_pow2(L))
    names = [
        "src",  # input keys
        "dst",
        "w",  # input payload
        "osrc",  # output: sorted keys
        "odst",
        "tot",  # output: per-run totals
        "first",  # output: run-start flags
        "iota",  # lane ids for the bitonic network
        "partner",  # exchange partner values
        "flags",  # compare/segment flags
    ]
    return [(name, (1, Lp)) for name in names]


def inventory_bytes(tensors: List[Tensor]) -> int:
    total = 0
    for _, shape in tensors:
        size = ITEM
        for dim in shape:
            size *= dim
        total += size
    return total


def _grids() -> Dict[str, List[dict]]:
    """The (row_tile, bucket) grid each kernel is checked over."""
    lp: List[dict] = []
    bal: List[dict] = []
    for row_tile in (8, 16):
        for R in (128, 512, 2048, 8192, 32768):
            for D in (8, 16, 32):
                for flag in (False, True):
                    lp.append(
                        dict(R=R, D=D, row_tile=row_tile, fit_sum=flag)
                    )
                    bal.append(
                        dict(R=R, D=D, row_tile=row_tile, restricted=flag)
                    )
    seg = [dict(L=L) for L in (2, 100, 1024, 4095, 65536, 1 << 20)]
    return {"lp_move": lp, "bal_round": bal, "seg_merge": seg}


def _static_bytes(kernel: str, point: dict) -> int:
    builders: Dict[str, Callable[..., List[Tensor]]] = {
        "lp_move": lp_move_inventory,
        "bal_round": bal_round_inventory,
        "seg_merge": seg_merge_inventory,
    }
    return inventory_bytes(builders[kernel](**point))


def _runtime_bytes(kernel: str, point: dict) -> int:
    if kernel == "lp_move":
        from repro.kernels.lp_move.lp_move import lp_move_vmem_bytes

        return lp_move_vmem_bytes(
            point["R"],
            point["D"],
            row_tile=point["row_tile"],
            fit_sum=point["fit_sum"],
        )
    if kernel == "bal_round":
        from repro.kernels.bal_round.bal_round import bal_scores_vmem_bytes

        return bal_scores_vmem_bytes(
            point["R"],
            point["D"],
            row_tile=point["row_tile"],
            restricted=point["restricted"],
        )
    from repro.kernels.seg_merge.seg_merge import seg_merge_vmem_bytes

    return seg_merge_vmem_bytes(point["L"])


def run(
    report: Report,
    static_fn: Callable[[str, dict], int] = _static_bytes,
    tolerance: float = 0.05,
) -> int:
    """Cross-check static inventories against the runtime gate."""
    from repro.kernels import dispatch

    budget = dispatch.VMEM_BUDGET_BYTES
    checked = 0
    for kernel, grid in _grids().items():
        for point in grid:
            checked += 1
            static = static_fn(kernel, point)
            runtime = _runtime_bytes(kernel, point)
            gap = abs(static - runtime) / max(1, runtime)
            if gap > tolerance:
                report.add(
                    Finding(
                        rule="VMEM001",
                        pass_name="vmem",
                        message=(
                            f"{kernel}{point}: static inventory "
                            f"{static}B vs runtime gate {runtime}B "
                            f"({gap:.1%} > {tolerance:.0%})"
                        ),
                        function=kernel,
                    )
                )
            elif (static <= budget) != (runtime <= budget):
                report.add(
                    Finding(
                        rule="VMEM002",
                        pass_name="vmem",
                        message=(
                            f"{kernel}{point}: fallback boundary "
                            f"disagrees (static {static}B, runtime "
                            f"{runtime}B, budget {budget}B)"
                        ),
                        function=kernel,
                    )
                )

    # ops modules freeze the budget at import; detect drift
    from repro.kernels.bal_round import ops as bal_ops
    from repro.kernels.lp_move import ops as move_ops
    from repro.kernels.seg_merge import ops as seg_ops

    for mod in (move_ops, bal_ops, seg_ops):
        frozen = getattr(mod, "VMEM_BUDGET_BYTES", budget)
        if frozen != budget:
            report.add(
                Finding(
                    rule="VMEM003",
                    pass_name="vmem",
                    message=(
                        f"{mod.__name__} froze VMEM_BUDGET_BYTES="
                        f"{frozen} but kernels.dispatch says {budget}"
                    ),
                    function=mod.__name__,
                )
            )
    return checked
