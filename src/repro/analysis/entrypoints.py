"""Registry of the real entry points the verifier traces.

Each entry names the public call that stages a program (the same one
the partitioner drivers use), the callee attribute :mod:`.tracing`
patches to capture it, and the variant axes that change the staged
program: weight-table layout (``replicated`` vs ``owner``), routing,
and kernel mode (``composed`` XLA vs ``fused`` Pallas). Tracing never
executes anything — a 2-device host mesh is enough to stage the same
collectives an 8192-core run would issue.
"""

from __future__ import annotations

from typing import Any, Callable, List, Tuple

import numpy as np

from . import tracing

# entry spec: (name, module, patched attr, is_builder, invoke thunk)
Spec = Tuple[str, Any, str, bool, Callable[[], Any]]


def build_specs(P: int = 2) -> List[Spec]:
    """Entry registry over a tiny graph sharded across ``P`` PEs."""
    from repro.core import balance as c_balance
    from repro.core import coarsening as c_coarsening
    from repro.core import contraction as c_contraction
    from repro.core import lp as c_lp
    from repro.core import unconstrained as c_unconstrained
    from repro.core.coarsening import enforce_cluster_weights
    from repro.dist import dist_balance, dist_contraction, dist_lp
    from repro.graphs import generators
    from repro.graphs.distribute import distribute_graph
    from repro.kernels.bal_round import ops as bal_ops
    from repro.kernels.lp_move import ops as move_ops
    from repro.kernels.seg_merge import ops as seg_ops

    g = generators.make("rgg2d", 240, 6.0, seed=1)
    shards = distribute_graph(g, P)
    k = 4
    total = int(g.total_vweight)
    W = max(4, total // 8)
    rng = np.random.default_rng(3)
    part = rng.integers(0, k, size=g.n).astype(np.int64)
    # all-in-one-block start: infeasible against lvec, so the balancer
    # entry points cannot early-return before staging a round
    part0 = np.zeros(g.n, dtype=np.int64)
    lvec = np.full(k, max(1, (total + k - 1) // k + 1), dtype=np.int64)
    labels = rng.integers(0, max(2, k), size=g.n).astype(np.int64)
    labels_enf = enforce_cluster_weights(
        labels.copy(), np.asarray(g.vweights), W
    )
    # small duplicate-heavy arc set for the dedup (seg_merge) entry
    csrc = np.array([0, 1, 1, 2, 0, 2, 1], dtype=np.int64)
    cdst = np.array([1, 0, 2, 1, 1, 2, 2], dtype=np.int64)
    cw = np.ones(csrc.size, dtype=np.int64)

    def cluster(weights: str = "replicated", kernel: str = "composed"):
        return lambda: dist_lp.dist_cluster(
            shards,
            W,
            num_iterations=1,
            num_chunks=2,
            seed=0,
            use_grid=True,
            weights=weights,
            kernel=kernel,
        )

    def refine(weights: str):
        return lambda: dist_lp.dist_lp_refine(
            shards,
            part,
            lvec,
            num_iterations=1,
            num_chunks=2,
            seed=0,
            use_grid=True,
            weights=weights,
        )

    def urefine(weights: str):
        return lambda: dist_lp.dist_ulp_refine(
            shards,
            part,
            lvec,
            num_iterations=2,
            num_chunks=2,
            seed=0,
            use_grid=True,
            weights=weights,
        )

    def rebalance(weights: str = "replicated", kernel: str = "composed"):
        return lambda: dist_balance.dist_rebalance(
            shards,
            part0,
            lvec,
            seed=1,
            use_grid=True,
            weights=weights,
            kernel=kernel,
        )

    def contract(kernel: str):
        return lambda: dist_contraction.dist_contract(
            shards, labels_enf, use_grid=True, kernel=kernel
        )

    specs: List[Spec] = [
        (
            "dist_cluster.replicated",
            dist_lp,
            "_build_cluster_fn",
            True,
            cluster("replicated"),
        ),
        (
            "dist_cluster.owner",
            dist_lp,
            "_build_cluster_fn",
            True,
            cluster("owner"),
        ),
        (
            "dist_cluster.fused",
            dist_lp,
            "_build_cluster_fn",
            True,
            cluster("replicated", kernel="fused"),
        ),
        (
            "dist_refine.replicated",
            dist_lp,
            "_build_refine_fn",
            True,
            refine("replicated"),
        ),
        (
            "dist_refine.owner",
            dist_lp,
            "_build_refine_fn",
            True,
            refine("owner"),
        ),
        (
            "dist_urefine.replicated",
            dist_lp,
            "_build_urefine_fn",
            True,
            urefine("replicated"),
        ),
        (
            "dist_urefine.owner",
            dist_lp,
            "_build_urefine_fn",
            True,
            urefine("owner"),
        ),
        (
            "dist_balance.replicated",
            dist_balance,
            "_build_balance_round_fn",
            True,
            rebalance("replicated"),
        ),
        (
            "dist_balance.owner",
            dist_balance,
            "_build_balance_round_fn",
            True,
            rebalance("owner"),
        ),
        (
            "dist_balance.fused",
            dist_balance,
            "_build_balance_round_fn",
            True,
            rebalance("replicated", kernel="fused"),
        ),
        (
            "dist_enforce",
            dist_balance,
            "_build_enforce_fn",
            True,
            lambda: dist_balance.dist_enforce_cluster_weights(
                shards, labels, W, use_grid=True
            ),
        ),
        (
            "dist_contract.composed",
            dist_contraction,
            "_build_exchange_fn",
            True,
            contract("composed"),
        ),
        (
            "dist_contract.fused",
            dist_contraction,
            "_build_exchange_fn",
            True,
            contract("fused"),
        ),
        (
            "host_cluster.composed",
            c_lp,
            "cluster_iteration",
            False,
            lambda: c_coarsening.cluster(
                g,
                W,
                num_iterations=1,
                num_chunks=2,
                seed=0,
                kernel="composed",
            ),
        ),
        (
            "host_cluster.fused",
            move_ops,
            "cluster_iteration_fused",
            False,
            lambda: c_coarsening.cluster(
                g,
                W,
                num_iterations=1,
                num_chunks=2,
                seed=0,
                kernel="fused",
            ),
        ),
        (
            "host_urefine",
            c_unconstrained,
            "urefine_iteration",
            False,
            lambda: c_unconstrained.unconstrained_refine(
                g,
                part.copy(),
                lvec,
                num_iterations=2,
                num_chunks=2,
                seed=0,
            ),
        ),
        (
            "host_balance.composed",
            c_balance,
            "balance_round",
            False,
            lambda: c_balance.rebalance(
                g, part0.copy(), lvec, seed=3, kernel="composed"
            ),
        ),
        (
            "host_balance.fused",
            bal_ops,
            "balance_round_fused",
            False,
            lambda: c_balance.rebalance(
                g, part0.copy(), lvec, seed=3, kernel="fused"
            ),
        ),
        (
            "host_dedup.fused",
            seg_ops,
            "seg_merge",
            False,
            lambda: c_contraction.dedup_arcs(
                csrc, cdst, cw, kernel="fused"
            ),
        ),
    ]
    return specs


def collect_jaxprs(P: int = 2) -> List[Tuple[str, Any, Tuple[str, str]]]:
    """Trace every entry; returns ``[(name, jaxpr, builder site)]``."""
    return tracing.capture_all(build_specs(P))
