"""Finding + report plumbing shared by the ``repro.analysis`` passes.

A :class:`Finding` is one verifier hit: a rule id, a human message and
a source anchor (repo-relative file, line, enclosing function). Passes
append findings to a :class:`Report`; the reviewed suppression file
(``analysis/allowlist.toml``) downgrades known-and-reasoned sites to
"suppressed" so ``python -m repro.analysis`` exits 0 on a clean tree
and nonzero the moment a new unreviewed site appears.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, List, Optional

try:
    import tomllib  # Python >= 3.11
except ModuleNotFoundError:  # pragma: no cover - 3.10 fallback
    import tomli as tomllib  # type: ignore[no-redef]

def _repo_root() -> str:
    here = os.path.abspath(__file__)  # <repo>/src/repro/analysis/...
    for _ in range(4):
        here = os.path.dirname(here)
    return here


REPO_ROOT = _repo_root()
ALLOWLIST_PATH = os.path.join(os.path.dirname(__file__), "allowlist.toml")

# allowlist table names -> the finding rules they may suppress
ALLOWLIST_KINDS = {
    "check_rep": ("SPMD003",),
    "overflow": ("OFL001",),
    "lint": ("LNT001", "LNT002", "LNT003"),
}


def rel_to_repo(path: str) -> str:
    """Repo-relative form of ``path`` (stable suppression keys)."""
    apath = os.path.abspath(path)
    root = REPO_ROOT + os.sep
    if apath.startswith(root):
        return apath[len(root) :].replace(os.sep, "/")
    return path.replace(os.sep, "/")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One verifier hit, anchored to source."""

    rule: str  # e.g. "SPMD001"
    pass_name: str  # "collectives" | "overflow" | "vmem" | "lint"
    message: str
    file: str = ""  # repo-relative path ("" = synthetic site)
    line: int = 0
    function: str = ""
    entry: str = ""  # traced entry point that reached the site

    def anchor(self) -> str:
        where = f"{self.file}:{self.line}" if self.file else "<static>"
        if self.function:
            where += f" ({self.function})"
        return where

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class AllowEntry:
    kind: str
    file: str
    reason: str
    function: str = ""  # "" = whole file

    def matches(self, finding: Finding) -> bool:
        if finding.rule not in ALLOWLIST_KINDS.get(self.kind, ()):
            return False
        if self.file != finding.file:
            return False
        return self.function in ("", finding.function)


class Allowlist:
    """Reviewed suppressions; every entry carries a reason string."""

    def __init__(self, entries: List[AllowEntry]):
        self.entries = entries
        self.used: set = set()

    @classmethod
    def load(cls, path: str = ALLOWLIST_PATH) -> "Allowlist":
        if not os.path.exists(path):
            return cls([])
        with open(path, "rb") as f:
            data = tomllib.load(f)
        entries: List[AllowEntry] = []
        for kind, rows in data.items():
            if kind not in ALLOWLIST_KINDS:
                raise ValueError(
                    f"allowlist: unknown table [[{kind}]] "
                    f"(expected one of {sorted(ALLOWLIST_KINDS)})"
                )
            for row in rows:
                reason = str(row.get("reason", "")).strip()
                if not reason:
                    raise ValueError(
                        f"allowlist: [[{kind}]] entry for "
                        f"{row.get('file')!r} has no reason string — "
                        "every suppression must be justified"
                    )
                entries.append(
                    AllowEntry(
                        kind=kind,
                        file=str(row.get("file", "")),
                        function=str(row.get("function", "")),
                        reason=reason,
                    )
                )
        return cls(entries)

    def suppresses(self, finding: Finding) -> Optional[AllowEntry]:
        for i, entry in enumerate(self.entries):
            if entry.matches(finding):
                self.used.add(i)
                return entry
        return None

    def unused(self) -> List[AllowEntry]:
        return [
            e for i, e in enumerate(self.entries) if i not in self.used
        ]


class Report:
    """Collects findings across passes; renders text and JSON."""

    def __init__(self, allowlist: Optional[Allowlist] = None):
        self.allowlist = allowlist or Allowlist([])
        self.findings: List[Finding] = []
        self.suppressed: List[Finding] = []
        self.notes: List[str] = []

    def add(self, finding: Finding) -> None:
        if self.allowlist.suppresses(finding):
            self.suppressed.append(finding)
        else:
            self.findings.append(finding)

    def note(self, message: str) -> None:
        self.notes.append(message)

    @property
    def ok(self) -> bool:
        return not self.findings

    def to_dict(self) -> Dict[str, Any]:
        return {
            "ok": self.ok,
            "findings": [f.to_dict() for f in self.findings],
            "suppressed": [f.to_dict() for f in self.suppressed],
            "notes": list(self.notes),
        }

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    def to_text(self) -> str:
        lines: List[str] = []
        for f in self.findings:
            lines.append(
                f"[{f.pass_name}:{f.rule}] {f.anchor()}: {f.message}"
            )
        sites: Dict[str, int] = {}
        for f in self.suppressed:
            key = f"[{f.pass_name}:{f.rule}:allowed] {f.file} " + (
                f.function or "(file-wide)"
            )
            sites[key] = sites.get(key, 0) + 1
        for key, count in sites.items():
            lines.append(f"{key} x{count}")
        for n in self.notes:
            lines.append(f"[note] {n}")
        for e in self.allowlist.unused():
            lines.append(
                f"[note] allowlist entry unused: [[{e.kind}]] "
                f"{e.file} {e.function or '(file-wide)'}"
            )
        verdict = "clean" if self.ok else "FAILING"
        lines.append(
            f"[analysis] {len(self.findings)} finding(s), "
            f"{len(self.suppressed)} suppressed — {verdict}"
        )
        return "\n".join(lines)
