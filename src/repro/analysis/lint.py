"""AST lint for repo rules ruff cannot express.

* ``LNT001`` — host numpy / Python RNG calls inside a jit-staged
  function (one decorated with ``jax.jit`` / a ``functools.partial``
  of it, or a function passed to ``shard_map``). Host calls inside a
  staged function either leak a tracer or silently bake a host value
  into the compiled program. Dtype constructors (``np.int32(...)``,
  ``np.iinfo``...) are concrete compile-time constants and stay legal.
* ``LNT002`` — a ``shard_map`` call without an explicit ``check_rep=``
  keyword: the default flips semantics between jax versions, and the
  collectives pass keys its allowlist on the explicit value.
* ``LNT003`` — ``.item()`` / ``jax.device_get`` in the serve-dispatch
  hot path (``src/repro/serve``): a device sync per request melts the
  batched dispatch throughput the serve tier exists to provide.
"""

from __future__ import annotations

import ast
import os
from typing import Iterator, List, Optional, Set

from .findings import REPO_ROOT, Finding, Report, rel_to_repo

# np.<attr> calls that are compile-time constants, legal under jit
_NP_CONST_ATTRS = {
    "int8",
    "int16",
    "int32",
    "int64",
    "uint8",
    "uint16",
    "uint32",
    "uint64",
    "float16",
    "float32",
    "float64",
    "bool_",
    "dtype",
    "iinfo",
    "finfo",
}
_SERVE_HOT_PREFIXES = ("src/repro/serve/",)
_SKIP_PARTS = ("/fixtures/", "/tests/", "/__pycache__/")


def _attr_root(node: ast.AST) -> Optional[str]:
    """Leftmost name of an attribute chain (``np.random.x`` -> np)."""
    while isinstance(node, ast.Attribute):
        node = node.value
    if isinstance(node, ast.Name):
        return node.id
    return None


def _attr_chain(node: ast.AST) -> List[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return list(reversed(parts))


def _decorator_mentions_jit(dec: ast.AST) -> bool:
    for node in ast.walk(dec):
        if isinstance(node, ast.Name) and node.id == "jit":
            return True
        if isinstance(node, ast.Attribute) and node.attr == "jit":
            return True
    return False


def _iter_calls(tree: ast.AST) -> Iterator[ast.Call]:
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            yield node


def _shard_map_body_names(tree: ast.AST) -> Set[str]:
    """Names of functions passed as the body of a shard_map call."""
    names: Set[str] = set()
    for call in _iter_calls(tree):
        chain = _attr_chain(call.func)
        if not chain or chain[-1] != "shard_map":
            continue
        if call.args and isinstance(call.args[0], ast.Name):
            names.add(call.args[0].id)
    return names


def _staged_functions(tree: ast.AST) -> List[ast.FunctionDef]:
    """Functions whose bodies are staged (jitted or shard_map bodies)."""
    body_names = _shard_map_body_names(tree)
    staged: List[ast.FunctionDef] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.FunctionDef):
            continue
        if any(_decorator_mentions_jit(d) for d in node.decorator_list):
            staged.append(node)
        elif node.name in body_names:
            staged.append(node)
    return staged


def _check_staged_fn(
    fn: ast.FunctionDef, file: str, report: Report
) -> None:
    for call in _iter_calls(fn):
        chain = _attr_chain(call.func)
        if len(chain) < 2:
            continue
        root = chain[0]
        if root in ("np", "numpy"):
            if chain[1] == "random" or (
                len(chain) == 2 and chain[1] not in _NP_CONST_ATTRS
            ):
                report.add(
                    Finding(
                        rule="LNT001",
                        pass_name="lint",
                        message=(
                            f"host call {'.'.join(chain)}() inside "
                            f"jit-staged function {fn.name!r}"
                        ),
                        file=file,
                        line=call.lineno,
                        function=fn.name,
                    )
                )
        elif root == "random":
            report.add(
                Finding(
                    rule="LNT001",
                    pass_name="lint",
                    message=(
                        f"Python RNG {'.'.join(chain)}() inside "
                        f"jit-staged function {fn.name!r}"
                    ),
                    file=file,
                    line=call.lineno,
                    function=fn.name,
                )
            )


def _enclosing_function(
    tree: ast.AST, target: ast.AST
) -> str:
    """Name of the innermost FunctionDef containing ``target``."""
    best = ""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if any(child is target for child in ast.walk(node)):
                best = node.name
    return best


def check_file(
    path: str,
    report: Report,
    serve_hot: Optional[bool] = None,
) -> None:
    """Run all lint rules over one file."""
    file = rel_to_repo(path)
    with open(path, "r", encoding="utf-8") as f:
        source = f.read()
    tree = ast.parse(source, filename=path)
    if serve_hot is None:
        serve_hot = file.startswith(_SERVE_HOT_PREFIXES)

    for fn in _staged_functions(tree):
        _check_staged_fn(fn, file, report)

    for call in _iter_calls(tree):
        chain = _attr_chain(call.func)
        if chain and chain[-1] == "shard_map":
            kw_names = {kw.arg for kw in call.keywords}
            if "check_rep" not in kw_names:
                report.add(
                    Finding(
                        rule="LNT002",
                        pass_name="lint",
                        message=(
                            "shard_map call without an explicit "
                            "check_rep= keyword"
                        ),
                        file=file,
                        line=call.lineno,
                        function=_enclosing_function(tree, call),
                    )
                )
        if serve_hot and chain:
            hot = None
            if chain[-1] == "item" and isinstance(
                call.func, ast.Attribute
            ):
                hot = ".item()"
            elif chain[-1] == "device_get":
                hot = "device_get"
            if hot:
                report.add(
                    Finding(
                        rule="LNT003",
                        pass_name="lint",
                        message=(
                            f"{hot} in the serve-dispatch hot path "
                            "forces a device sync per request"
                        ),
                        file=file,
                        line=call.lineno,
                        function=_enclosing_function(tree, call),
                    )
                )


def repo_files() -> List[str]:
    """Python files the lint pass covers (src/repro, launch incl.)."""
    roots = [os.path.join(REPO_ROOT, "src", "repro")]
    files: List[str] = []
    for root in roots:
        for dirpath, _, names in os.walk(root):
            for name in sorted(names):
                if not name.endswith(".py"):
                    continue
                path = os.path.join(dirpath, name)
                norm = "/" + rel_to_repo(path) + "/"
                if any(part in norm for part in _SKIP_PARTS):
                    continue
                files.append(path)
    return files


def run(report: Report, files: Optional[List[str]] = None) -> int:
    targets = files if files is not None else repo_files()
    for path in targets:
        check_file(path, report)
    return len(targets)
