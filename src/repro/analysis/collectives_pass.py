"""Collective-consistency pass over captured ``shard_map`` programs.

Three rules, all aimed at the SPMD failure mode that matters at scale
(a deadlock every PE sits in silently):

* ``SPMD001`` — a collective (``psum``/``all_gather``/``all_to_all``/
  ``ppermute``/...) names an axis the enclosing ``shard_map`` mesh
  does not declare.
* ``SPMD002`` — the branches of a ``lax.cond``/``switch`` inside a
  ``shard_map`` body issue different collective sequences: whichever
  branch a PE takes, its peers must issue the *same* collectives in
  the same order or the program deadlocks.
* ``SPMD003`` — a ``shard_map`` site staged with ``check_rep=False``
  (jax's own replication checker disabled) that is not recorded in the
  reviewed ``analysis/allowlist.toml`` with a reason.
"""

from __future__ import annotations

from typing import Any, Iterator, List, Tuple

from .findings import Finding, Report, rel_to_repo

# primitives that communicate across a named mesh axis
COLLECTIVE_PRIMS = {
    "psum",
    "psum2",
    "pbroadcast",
    "pmax",
    "pmin",
    "all_gather",
    "all_to_all",
    "ppermute",
    "pshuffle",
    "reduce_scatter",
    "axis_index",
}
# collectives whose sequence must agree across PEs for progress (the
# replication bookkeeping prims psum2 emits alongside are excluded)
BLOCKING_PRIMS = COLLECTIVE_PRIMS - {"axis_index", "pbroadcast"}


def _as_closed(jaxpr: Any) -> Any:
    return jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr


def _sub_jaxprs(eqn: Any) -> Iterator[Tuple[str, Any]]:
    """Yield ``(param_name, jaxpr)`` for every subjaxpr of ``eqn``."""
    for key, val in eqn.params.items():
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for item in vals:
            if hasattr(item, "eqns") or hasattr(item, "jaxpr"):
                yield key, _as_closed(item)


def _source_site(eqn: Any) -> Tuple[str, int, str]:
    """(repo-relative file, line, function) of an eqn's user frame."""
    try:
        from jax._src import source_info_util

        frame = source_info_util.user_frame(eqn.source_info)
    except Exception:
        frame = None
    if frame is None:
        return "", 0, ""
    return (
        rel_to_repo(frame.file_name),
        int(frame.start_line),
        frame.function_name,
    )


def _axis_names(eqn: Any) -> List[str]:
    """Named mesh axes a collective eqn communicates over."""
    params = eqn.params
    raw: Any = ()
    for key in ("axes", "axis_name", "axis_index_groups_axis"):
        if key in params and params[key] is not None:
            raw = params[key]
            break
    if not isinstance(raw, (tuple, list)):
        raw = (raw,)
    return [a for a in raw if isinstance(a, str)]


def _mesh_axes(shard_map_eqn: Any) -> Tuple[str, ...]:
    mesh = shard_map_eqn.params.get("mesh")
    names = getattr(mesh, "axis_names", None)
    if names is None:
        return ()
    return tuple(str(a) for a in names)


def iter_shard_maps(jaxpr: Any) -> Iterator[Any]:
    """Yield every ``shard_map`` eqn reachable from ``jaxpr``."""
    for eqn in _as_closed(jaxpr).eqns:
        if eqn.primitive.name == "shard_map":
            yield eqn
        for _, sub in _sub_jaxprs(eqn):
            yield from iter_shard_maps(sub)


def collective_signature(jaxpr: Any) -> Tuple:
    """Ordered tuple of blocking collectives issued by ``jaxpr``.

    Branch-divergence inside is folded in recursively: a nested cond
    contributes its (already checked) first-branch signature.
    """
    sig: List = []
    for eqn in _as_closed(jaxpr).eqns:
        name = eqn.primitive.name
        if name in BLOCKING_PRIMS:
            sig.append((name, tuple(_axis_names(eqn))))
            continue
        for _, sub in _sub_jaxprs(eqn):
            sig.extend(collective_signature(sub))
            if name == "cond":
                break  # branches checked separately; count one
    return tuple(sig)


def _check_body(
    body: Any,
    mesh_axes: Tuple[str, ...],
    entry: str,
    report: Report,
) -> None:
    for eqn in _as_closed(body).eqns:
        name = eqn.primitive.name
        if name in COLLECTIVE_PRIMS:
            for axis in _axis_names(eqn):
                if axis not in mesh_axes:
                    file, line, func = _source_site(eqn)
                    report.add(
                        Finding(
                            rule="SPMD001",
                            pass_name="collectives",
                            message=(
                                f"{name} over undeclared axis "
                                f"{axis!r} (mesh axes: {mesh_axes})"
                            ),
                            file=file,
                            line=line,
                            function=func,
                            entry=entry,
                        )
                    )
        if name == "cond":
            branches = eqn.params.get("branches", ())
            sigs = [collective_signature(b) for b in branches]
            if len(set(sigs)) > 1:
                file, line, func = _source_site(eqn)
                report.add(
                    Finding(
                        rule="SPMD002",
                        pass_name="collectives",
                        message=(
                            "cond branches issue different collective "
                            f"sequences {sigs} — SPMD deadlock if PEs "
                            "diverge"
                        ),
                        file=file,
                        line=line,
                        function=func,
                        entry=entry,
                    )
                )
        for _, sub in _sub_jaxprs(eqn):
            _check_body(sub, mesh_axes, entry, report)


def run(
    jaxprs: List[Tuple[str, Any]],
    report: Report,
    expect_shard_maps: bool = False,
) -> int:
    """Check every captured program; returns shard_map sites seen."""
    sites = 0
    for item in jaxprs:
        entry, jaxpr = item[0], item[1]
        hint = item[2] if len(item) > 2 else None
        found = False
        for sm in iter_shard_maps(jaxpr):
            found = True
            sites += 1
            mesh_axes = _mesh_axes(sm)
            file, line, func = _source_site(sm)
            if hint is not None and (
                not file or file.startswith("src/repro/analysis/")
            ):
                # the shard_map eqn was bound under the tracing proxy;
                # anchor it on the patched builder the entry came from
                file, line, func = hint[0], 0, hint[1]
            if sm.params.get("check_rep", True) is False:
                report.add(
                    Finding(
                        rule="SPMD003",
                        pass_name="collectives",
                        message=(
                            "shard_map staged with check_rep=False "
                            "(replication checking disabled) — must "
                            "be allowlisted with a reason"
                        ),
                        file=file,
                        line=line,
                        function=func,
                        entry=entry,
                    )
                )
            _check_body(sm.params["jaxpr"], mesh_axes, entry, report)
        if expect_shard_maps and not found and entry.startswith("dist_"):
            report.note(
                f"{entry}: no shard_map equation captured — tracing "
                "registry may be stale"
            )
    return sites
