"""Static analysis for the partitioner: jaxpr-level SPMD/overflow/VMEM
verification plus repo AST lint.

``python -m repro.analysis`` traces the real ``repro.dist`` /
``repro.core`` entry points to jaxprs (never executing them) and runs
four passes — collective consistency, int32 overflow dataflow, static
VMEM estimation against the ``kernels.dispatch`` fallback gate, and
an AST lint for rules ruff can't express. See ``docs/ANALYSIS.md``.
"""

from .findings import Allowlist, Finding, Report

__all__ = ["Allowlist", "Finding", "Report"]
