"""Capture jaxprs from *real* entry points without executing them.

The verifier must see exactly the programs the partitioner stages —
same builders, same argument preparation, same static configuration —
but must never compile or run them (CI analyzes TPU-shaped programs on
CPU runners). The trick: temporarily patch the callee attribute that an
entry point looks up (a ``shard_map`` builder in ``repro.dist``, or a
jitted chunk function in ``repro.core``) with a proxy that traces the
real callee via :func:`jax.make_jaxpr` and raises a sentinel carrying
the jaxpr. The public entry point runs its genuine argument prep, hits
the proxy, and unwinds before anything touches a device.
"""

from __future__ import annotations

from typing import Any, Callable, List, Tuple

import jax

from .findings import rel_to_repo


class CapturedJaxpr(Exception):
    """Sentinel carrying the traced jaxpr out of an entry point."""

    def __init__(self, jaxpr: Any):
        super().__init__("captured")
        self.jaxpr = jaxpr


def _is_array(x: Any) -> bool:
    return hasattr(x, "shape") and hasattr(x, "dtype")


def trace_call(fn: Callable, args: tuple, kwargs: dict) -> Any:
    """``jax.make_jaxpr`` of ``fn(*args, **kwargs)``.

    Array arguments (numpy or jax) become dynamic jaxpr inputs; every
    other argument — chunk counts, static flags, ``interpret=`` — is
    closed over, matching how the jitted callees mark them static.
    """
    dyn_pos = [i for i, a in enumerate(args) if _is_array(a)]
    dyn_kw = sorted(k for k, v in kwargs.items() if _is_array(v))

    def wrapper(*dyn: Any) -> Any:
        full = list(args)
        for slot, val in zip(dyn_pos, dyn[: len(dyn_pos)]):
            full[slot] = val
        kw = dict(kwargs)
        for name, val in zip(dyn_kw, dyn[len(dyn_pos) :]):
            kw[name] = val
        return fn(*full, **kw)

    vals = [args[i] for i in dyn_pos] + [kwargs[k] for k in dyn_kw]
    return jax.make_jaxpr(wrapper)(*vals)


def capture(
    module: Any,
    attr: str,
    invoke: Callable[[], Any],
    builder: bool = False,
) -> Any:
    """Run ``invoke()`` with ``module.attr`` patched to capture a jaxpr.

    ``builder=False`` patches a traceable callee directly; its first
    call is traced instead of executed. ``builder=True`` patches a
    factory (the ``repro.dist`` ``_build_*_fn`` builders): the factory
    runs for real (same static configuration, same ``shard_map``
    wrapping) and only the *returned* function is proxied, so the
    captured jaxpr contains the genuine ``shard_map`` equation.
    """
    real = getattr(module, attr)

    if builder:

        def patched(*bargs: Any, **bkw: Any) -> Any:
            fn = real(*bargs, **bkw)

            def proxy(*args: Any, **kwargs: Any) -> Any:
                raise CapturedJaxpr(trace_call(fn, args, kwargs))

            return proxy

    else:

        def patched(*args: Any, **kwargs: Any) -> Any:
            raise CapturedJaxpr(trace_call(real, args, kwargs))

    setattr(module, attr, patched)
    try:
        invoke()
    except CapturedJaxpr as cap:
        return cap.jaxpr
    finally:
        setattr(module, attr, real)
    raise RuntimeError(
        f"analysis: {module.__name__}.{attr} was never called by the "
        "entry point — the tracing registry is out of date"
    )


def capture_all(
    specs: List[Tuple[str, Any, str, bool, Callable[[], Any]]],
) -> List[Tuple[str, Any, Tuple[str, str]]]:
    """Capture ``[(entry_name, jaxpr, site)]`` for a registry of specs.

    ``site`` is the (repo-relative file, function) of the patched
    callee. Top-level equations of a captured jaxpr — notably the
    ``shard_map`` a builder staged — carry *this module's* proxy
    wrapper as their source frame, so passes anchor findings on those
    equations to ``site`` instead; the allowlist keys on it.
    """
    out: List[Tuple[str, Any, Tuple[str, str]]] = []
    for name, module, attr, builder, invoke in specs:
        site = (rel_to_repo(getattr(module, "__file__", "")), attr)
        jaxpr = capture(module, attr, invoke, builder=builder)
        out.append((name, jaxpr, site))
    return out
