"""``python -m repro.analysis`` — run the static verifier suite.

Default mode traces the real entry points over a 2-device host mesh
(forced before jax initializes; nothing executes or compiles) and
runs all four passes; exit code 0 iff there are no unsuppressed
findings. ``--fixture <name>`` runs one pass against its seeded
violation instead and must exit nonzero — CI checks both directions.
"""

from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional

FIXTURES = ("collective", "overflow", "lint", "vmem")


def _run_fixture(name: str, devices: int, report) -> None:
    from . import collectives_pass, lint, overflow_pass, vmem

    if name == "collective":
        from .fixtures import fixture_collective_mismatch as fx

        collectives_pass.run(fx.captured(devices), report)
    elif name == "overflow":
        from .fixtures import fixture_overflow as fx

        overflow_pass.run(fx.captured(), report)
    elif name == "lint":
        from .fixtures import fixture_lint as fx

        lint.check_file(fx.__file__, report, serve_hot=True)
    else:
        from .fixtures import fixture_vmem as fx

        vmem.run(report, static_fn=fx.static_bytes)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jaxpr-level SPMD/overflow/VMEM verifier + AST lint",
    )
    ap.add_argument(
        "--devices",
        type=int,
        default=2,
        help="forced host device count for the tracing mesh",
    )
    ap.add_argument(
        "--json",
        default=None,
        metavar="PATH",
        help="also write the JSON report to PATH",
    )
    ap.add_argument(
        "--fixture",
        default=None,
        choices=FIXTURES,
        help="run one pass against its seeded violation instead",
    )
    args = ap.parse_args(argv)

    # the tracing mesh needs >= 2 host devices, fixed before jax init
    from repro.api import runtime

    runtime.force_host_devices(args.devices)

    from . import collectives_pass, lint, overflow_pass, vmem
    from .findings import Allowlist, Report

    if args.fixture:
        report = Report(Allowlist([]))  # fixtures: nothing suppressed
        _run_fixture(args.fixture, args.devices, report)
    else:
        report = Report(Allowlist.load())
        from . import entrypoints

        jaxprs = entrypoints.collect_jaxprs(args.devices)
        sites = collectives_pass.run(
            jaxprs, report, expect_shard_maps=True
        )
        overflow_pass.run(jaxprs, report)
        points = vmem.run(report)
        files = lint.run(report)
        report.note(
            f"traced {len(jaxprs)} entries ({sites} shard_map sites), "
            f"vmem grid {points} points, linted {files} files"
        )

    print(report.to_text())
    if args.json:
        with open(args.json, "w", encoding="utf-8") as f:
            f.write(report.to_json() + os.linesep)
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
