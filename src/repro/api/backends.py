"""String-keyed backend registry for the partitioning facade.

A backend is a callable ``(g, req, ctx) -> assignment`` where ``ctx`` is
a ``BackendContext`` carrying the resolved device count, an optional
pre-built 1D 'pe' mesh (serving sessions reuse one across requests), and
an optional trace list the driver appends per-level records to.

Built-ins:

  * ``single``          — single-process deep MGP (``core.deep_mgp``)
  * ``dist``            — distributed deep MGP, direct all-to-all
  * ``dist-grid``       — distributed deep MGP, two-level grid routing
  * ``plain_mgp``       — classic multilevel baseline
  * ``single_level_lp`` — XtraPuLP-like single-level LP baseline

The ``dist`` backends honor the request's distributed memory-model knobs
(``contraction="host"|"sharded"``, ``weights="replicated"|"owner"``,
``balance="host"|"dist"``, docs/DIST.md) — they ride in through
``req.resolve_config()``, so no backend signature changes and no caller
changes.

The baselines being ordinary backends is what makes ``--compare`` "run
the same request against N backends" instead of bespoke glue.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from ..core import baselines
from ..core.deep_mgp import partition as _single_partition
from ..graphs.format import Graph

BackendFn = Callable[..., np.ndarray]

_REGISTRY: Dict[str, BackendFn] = {}
# names safe to serve inside a coalesced/stacked batch: deterministic
# pure single-device backends. The distributed backends are excluded
# (they own the mesh for the whole attempt), as are custom backends
# unless registered with batchable=True — an unknown backend keeps the
# solo per-request serve path and its exact retry semantics.
_BATCHABLE: set = set()

# below this many vertices per PE, sharding overhead dominates and the
# auto policy stays single-process (mirrors the driver's own 2*P floor)
MIN_VERTICES_PER_DEVICE = 64
# grid all-to-all routing pays off once the PE count is large (paper §5)
GRID_ROUTING_MIN_DEVICES = 16


def register_backend(name: str, fn: Optional[BackendFn] = None, *,
                     batchable: bool = False):
    """Register ``fn`` under ``name``; usable as a decorator.

    ``batchable=True`` declares the backend safe for the serving tier's
    batched dispatch (pure, deterministic, single-device — see
    ``repro.serve.batching``); the default keeps custom backends on the
    solo serve path."""
    def _do(f: BackendFn) -> BackendFn:
        if not name or not isinstance(name, str):
            raise ValueError("backend name must be a non-empty str, "
                             f"got {name!r}")
        _REGISTRY[name] = f
        if batchable:
            _BATCHABLE.add(name)
        else:
            _BATCHABLE.discard(name)
        return f
    return _do(fn) if fn is not None else _do


def is_batchable(name: str) -> bool:
    """True when ``name`` was registered as safe for batched dispatch."""
    return name in _BATCHABLE


def get_backend(name: str) -> BackendFn:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown backend {name!r}; available: "
                         f"{available_backends()}") from None


def available_backends() -> List[str]:
    return sorted(_REGISTRY)


@dataclasses.dataclass
class BackendContext:
    """Per-run state the facade/session threads into a backend."""
    devices: int = 1
    mesh: Optional[object] = None       # pre-built 1D 'pe' mesh or None
    trace: Optional[list] = None
    # precomputed level-0 clustering labels (batched serving: one
    # stacked jit program clusters several requests' level 0 at once).
    # Must be exactly what core.coarsening.cluster would return for the
    # driver's level-0 call — the hint is an execution strategy, never
    # a result change.
    level0_labels: Optional[np.ndarray] = None


def resolve_backend(req, n_graph_vertices: int) -> str:
    """The ``auto`` policy: distributed iff the caller asked for more
    than one device AND the graph is big enough to shard; grid routing
    once the PE count is large. Pure function of the request — never
    initializes jax."""
    if req.backend != "auto":
        return req.backend
    P = req.devices
    if P > 1 and n_graph_vertices >= MIN_VERTICES_PER_DEVICE * P:
        return "dist-grid" if P >= GRID_ROUTING_MIN_DEVICES else "dist"
    return "single"


def required_devices(req, n_graph_vertices: int) -> int:
    """PE count the request's *resolved* backend actually needs: its
    ``devices`` field for the distributed backends, 1 for everything
    else. Pure (same inputs as ``resolve_backend``) — the serving
    scheduler routes requests to the best-fitting mesh with this,
    without materializing graphs or touching jax."""
    name = resolve_backend(req, n_graph_vertices)
    return max(1, req.devices) if name in ("dist", "dist-grid") else 1


# ---------------------------------------------------------------------------
# built-in backends
# ---------------------------------------------------------------------------

@register_backend("single", batchable=True)
def _single(g: Graph, req, ctx: BackendContext) -> np.ndarray:
    return _single_partition(g, req.k, req.resolve_config(),
                             trace=ctx.trace,
                             level0_labels=ctx.level0_labels)


def _dist(g: Graph, req, ctx: BackendContext,
          use_grid: bool) -> np.ndarray:
    from ..dist.dist_partitioner import dist_partition_impl
    return dist_partition_impl(g, req.k, max(1, ctx.devices),
                               cfg=req.resolve_config(), use_grid=use_grid,
                               mesh=ctx.mesh, trace=ctx.trace)


@register_backend("dist")
def _dist_direct(g: Graph, req, ctx: BackendContext) -> np.ndarray:
    return _dist(g, req, ctx, use_grid=False)


@register_backend("dist-grid")
def _dist_grid(g: Graph, req, ctx: BackendContext) -> np.ndarray:
    return _dist(g, req, ctx, use_grid=True)


@register_backend("plain_mgp", batchable=True)
def _plain_mgp(g: Graph, req, ctx: BackendContext) -> np.ndarray:
    return baselines.plain_mgp(g, req.k, cfg=req.resolve_config())


@register_backend("single_level_lp", batchable=True)
def _single_level_lp(g: Graph, req, ctx: BackendContext) -> np.ndarray:
    return baselines.single_level_lp(g, req.k, eps=req.epsilon,
                                     seed=req.seed)
