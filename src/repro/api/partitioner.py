"""The ``Partitioner`` facade — one entrypoint from 1 to 8192 PEs."""
from __future__ import annotations

import dataclasses
import time
from typing import Iterable, List, Optional, Sequence, Union

import numpy as np

from ..core import metrics
from ..graphs.format import Graph
from .backends import BackendContext, get_backend, resolve_backend
from .request import GraphSpec, PartitionRequest
from .result import PartitionResult


class Partitioner:
    """Runs ``PartitionRequest``s through the backend registry.

    ``backend`` replaces the ``"auto"`` hint of incoming requests (an
    explicit per-request backend always wins); ``None`` keeps the auto
    policy. Stateless apart from that — ``PartitionSession`` adds mesh
    reuse and batching on top.
    """

    def __init__(self, backend: Optional[str] = None):
        self.backend = backend

    def run(self, request: PartitionRequest, *,
            _ctx: Optional[BackendContext] = None) -> PartitionResult:
        req = request
        if self.backend is not None and req.backend == "auto":
            req = dataclasses.replace(req, backend=self.backend)
        req.validate()
        g = req.resolve_graph()
        name = resolve_backend(req, g.n)
        fn = get_backend(name)
        ctx = _ctx or BackendContext(devices=req.devices)
        if ctx.trace is None and req.collect_trace:
            ctx.trace = []
        t0 = time.perf_counter()
        assignment = np.asarray(fn(g, req, ctx), dtype=np.int64)
        dt = time.perf_counter() - t0
        s = metrics.summarize(g, assignment, req.k, req.epsilon)
        s.update({"n": g.n, "m": g.m})
        return PartitionResult(assignment=assignment,
                               feasible=bool(s["feasible"]),
                               metrics=s, backend=name, time_s=dt,
                               trace=tuple(ctx.trace or ()), request=req)

    def run_batch(self, requests: Iterable[PartitionRequest]
                  ) -> List[PartitionResult]:
        """Sequential batch; ``PartitionSession`` runs these concurrently."""
        return [self.run(r) for r in requests]

    def compare(self, request: PartitionRequest,
                backends: Sequence[str]) -> List[PartitionResult]:
        """Run the *same* request against several backends — the
        ``--compare`` flag is exactly this. A GraphSpec is materialized
        once, not once per backend."""
        request = dataclasses.replace(request,
                                      graph=request.resolve_graph())
        return [self.run(dataclasses.replace(request, backend=b))
                for b in backends]


def partition(graph: Union[Graph, GraphSpec], k: int,
              **request_kw) -> PartitionResult:
    """One-shot convenience: build a request, run the default facade.

    ``repro.api.partition(g, k=16, epsilon=0.03).assignment`` replaces
    the removed ``repro.core.partitioner.partition(g, 16)``.
    """
    return Partitioner().run(PartitionRequest(graph=graph, k=k,
                                              **request_kw))
