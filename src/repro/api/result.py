"""Result objects for the unified partitioning facade."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import numpy as np

from .request import PartitionRequest


@dataclasses.dataclass(frozen=True, eq=False)
class PartitionResult:
    """Outcome of one ``PartitionRequest``.

    ``metrics`` is ``repro.core.metrics.summarize`` output plus the graph
    sizes ``n``/``m``; ``feasible`` mirrors its feasibility flag.
    ``trace`` holds one record per driver phase/level (sizes, cuts, wall
    times) in execution order.
    """
    assignment: np.ndarray          # (n,) int64 block ids
    feasible: bool
    metrics: Dict[str, Any]
    backend: str                    # resolved backend name (never "auto")
    time_s: float
    trace: Tuple[Dict[str, Any], ...]
    request: PartitionRequest

    @property
    def cut(self) -> int:
        return int(self.metrics["cut"])

    @property
    def k(self) -> int:
        return int(self.metrics["k"])

    def summary(self) -> Dict[str, Any]:
        """JSON-serializable one-line summary (no assignment array)."""
        out = dict(self.metrics)
        out.update({
            "backend": self.backend,
            "algo": f"dkaminpar-{self.request.preset}"
            if self.backend in ("single", "dist", "dist-grid")
            else self.backend,
            "time_s": round(float(self.time_s), 3),
            "devices": int(self.request.devices),
            "levels": len(self.trace),
        })
        return out
