"""Request objects for the unified partitioning facade.

A ``PartitionRequest`` fully describes one partitioning job: the graph
(either an in-memory ``Graph`` or a ``GraphSpec`` naming a synthetic
family to generate), the block count ``k``, the balance slack, the
preset/config, the seed, and a backend hint. Requests are frozen — a
serving session can hash ``GraphSpec``s for caching and replay a request
byte-for-byte.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

from ..core.deep_mgp import PartitionerConfig
from ..core.partitioner import PRESETS, resolve_config
from ..graphs.format import Graph


@dataclasses.dataclass(frozen=True)
class GraphSpec:
    """Generator spec: which synthetic family to materialize (hashable,
    so sessions can cache the generated graph across requests)."""
    family: str
    n: int
    avg_deg: float = 8.0
    seed: int = 0

    def validate(self) -> "GraphSpec":
        from ..graphs import generators
        if self.family not in generators._FAMILIES:
            raise ValueError(
                f"unknown graph family {self.family!r}; expected one of "
                f"{sorted(generators._FAMILIES)}")
        if self.n < 0:
            raise ValueError(f"graph size n must be >= 0, got {self.n}")
        return self

    def materialize(self) -> Graph:
        from ..graphs import generators
        self.validate()
        return generators.make(self.family, self.n, self.avg_deg,
                               seed=self.seed)


@dataclasses.dataclass(frozen=True, eq=False)
class PartitionRequest:
    """One partitioning job. ``backend="auto"`` lets the facade pick
    single vs. distributed from graph size and ``devices``.

    ``contraction`` / ``weights`` / ``balance`` select the distributed
    memory model (see docs/DIST.md) on the ``dist`` / ``dist-grid``
    backends without spelling out a full config:
    ``contraction="sharded"`` contracts each level in place (paper §5)
    instead of gathering to the host, ``weights="owner"`` shards the
    cluster/block weight tables across PEs instead of replicating them,
    and ``balance="dist"`` runs the exact balancer (and the coarsening
    loop's cluster-weight enforcement) over the level's shards instead
    of gathering every uncoarsening level to the host. ``None`` defers
    to the preset or explicit config; the single-process backends ignore
    all three. ``kernel`` picks the hot-loop implementation on every
    backend ("auto" | "fused" | "composed", docs/KERNELS.md) — results
    are bit-identical either way.

    ``refine`` selects the refinement algorithm on every backend
    ("lp" | "unconstrained", docs/REFINEMENT.md); ``quality`` is the
    serving-facing spelling of the same choice ("fast" -> lp,
    "best" -> unconstrained) that schedulers may downgrade for
    deadline-bearing tickets (docs/SERVING.md). An explicit ``refine``
    always wins over ``quality``.
    """
    graph: Union[Graph, GraphSpec]
    k: int
    epsilon: float = 0.03
    preset: str = "fast"                        # "fast" | "strong"
    config: Optional[PartitionerConfig] = None  # overrides the preset
    seed: int = 0
    backend: str = "auto"
    devices: int = 1                            # PE count for dist backends
    collect_trace: bool = True                  # per-level records cost an
                                                # O(m) cut pass per level
    contraction: Optional[str] = None           # "host" | "sharded"
    weights: Optional[str] = None               # "replicated" | "owner"
    balance: Optional[str] = None               # "host" | "dist"
    kernel: Optional[str] = None                # "auto"|"fused"|"composed"
    refine: Optional[str] = None                # "lp" | "unconstrained"
    quality: Optional[str] = None               # "fast" | "best"

    def validate(self) -> "PartitionRequest":
        from .backends import available_backends
        if self.k < 1:
            raise ValueError(f"k must be >= 1, got {self.k}")
        if self.epsilon <= 0:
            raise ValueError(f"epsilon must be > 0, got {self.epsilon}")
        if self.devices < 1:
            raise ValueError(f"devices must be >= 1, got {self.devices}")
        if self.config is None and self.preset not in PRESETS:
            raise ValueError(f"unknown preset {self.preset!r}; expected "
                             f"one of {sorted(PRESETS)}")
        if self.backend != "auto" and \
                self.backend not in available_backends():
            raise ValueError(
                f"unknown backend {self.backend!r}; expected 'auto' or "
                f"one of {available_backends()}")
        if self.contraction not in (None, "host", "sharded"):
            raise ValueError(
                "contraction must be 'host' or 'sharded', "
                f"got {self.contraction!r}")
        if self.weights not in (None, "replicated", "owner"):
            raise ValueError(
                "weights must be 'replicated' or 'owner', "
                f"got {self.weights!r}")
        if self.balance not in (None, "host", "dist"):
            raise ValueError(
                f"balance must be 'host' or 'dist', got {self.balance!r}")
        if self.kernel is not None:
            from ..kernels.dispatch import check_kernel_mode
            check_kernel_mode(self.kernel)
        if self.refine is not None:
            from ..core.refinement import check_refine_mode
            check_refine_mode(self.refine)
        if self.quality not in (None, "fast", "best"):
            raise ValueError(
                f"quality must be 'fast' or 'best', got {self.quality!r}")
        if self.config is not None:
            self.config.validate()
        if isinstance(self.graph, GraphSpec):
            self.graph.validate()
        return self

    def resolve_graph(self) -> Graph:
        if isinstance(self.graph, GraphSpec):
            return self.graph.materialize()
        return self.graph

    def resolve_config(self) -> PartitionerConfig:
        """Preset (+ epsilon/seed) unless an explicit config was given;
        request-level ``contraction``/``weights``/``balance``/``kernel``/
        ``refine`` override either. ``quality`` maps to ``refine``
        ("best" -> "unconstrained", "fast" -> "lp") only when ``refine``
        itself is unset — the explicit knob wins."""
        cfg = resolve_config(self.preset, self.config, self.epsilon,
                             self.seed)
        overrides = {}
        if self.contraction is not None:
            overrides["contraction"] = self.contraction
        if self.weights is not None:
            overrides["weights"] = self.weights
        if self.balance is not None:
            overrides["balance"] = self.balance
        if self.kernel is not None:
            overrides["kernel"] = self.kernel
        if self.refine is not None:
            overrides["refine"] = self.refine
        elif self.quality is not None:
            overrides["refine"] = ("unconstrained" if self.quality == "best"
                                   else "lp")
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides).validate()
        return cfg
