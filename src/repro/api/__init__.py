"""Unified partitioning facade — the one public surface of the repo.

dKaMinPar's promise is a single robust entrypoint from 1 to 8192 PEs
(paper §1). This package is that entrypoint for the reproduction:

    from repro.api import GraphSpec, PartitionRequest, Partitioner

    req = PartitionRequest(graph=GraphSpec("rgg2d", 20000), k=16,
                           epsilon=0.03, backend="auto", devices=8)
    res = Partitioner().run(req)
    res.assignment, res.feasible, res.metrics, res.trace

Backends ("single", "dist", "dist-grid", plus the paper's baselines
"plain_mgp" / "single_level_lp") live in a string-keyed registry;
``PartitionSession`` serves batches of requests over one shared mesh.
``repro.api.runtime.force_host_devices`` is the one sanctioned way to
force a CPU device count.

Exports resolve lazily (PEP 562) so that importing ``repro.api`` — in
particular ``repro.api.runtime`` from a CLI, before device setup — never
drags in jax-heavy modules.
"""
from importlib import import_module

_EXPORTS = {
    "GraphSpec": ".request",
    "PartitionRequest": ".request",
    "PartitionResult": ".result",
    "Partitioner": ".partitioner",
    "partition": ".partitioner",
    "PartitionSession": ".session",
    "BucketCache": ".session",
    "BackendContext": ".backends",
    "register_backend": ".backends",
    "available_backends": ".backends",
    "get_backend": ".backends",
    "resolve_backend": ".backends",
    "is_batchable": ".backends",
}

__all__ = sorted(_EXPORTS) + ["runtime"]


def __getattr__(name):
    if name == "runtime":
        return import_module(".runtime", __name__)
    try:
        mod = _EXPORTS[name]
    except KeyError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}") from None
    return getattr(import_module(mod, __name__), name)


def __dir__():
    return __all__
