"""Batched serving sessions: one mesh/ShardCtx, many requests.

``PartitionSession`` is the serving-shaped workload from the ROADMAP: it
amortizes per-process state (the 1D 'pe' device mesh, the ShardCtx the
model layers consume, materialized ``GraphSpec`` graphs) across a stream
of requests and runs independent requests concurrently on a thread pool.
Results are bit-identical to running each request alone through
``Partitioner`` — every request is a pure function of its fields.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Dict, Iterable, List, Optional, Sequence

from .backends import BackendContext, resolve_backend
from .partitioner import Partitioner
from .request import GraphSpec, PartitionRequest
from .result import PartitionResult


class BucketCache:
    """Bounded LRU mapping for long-lived serving processes.

    Dict-shaped (``get`` / ``[]`` / ``len`` / ``in``) so it drops into
    every existing graph-cache call site, but capped: inserting beyond
    ``maxsize`` evicts the least-recently-used entry, so a diverse
    traffic mix can no longer grow the shared cache without bound (the
    serve tier's slow leak). The batching layer reuses it for its
    shape-bucket caches — any hashable key works. Not thread-safe on
    its own; callers hold the cache lock, exactly as with the plain
    dict it replaces."""

    def __init__(self, maxsize: int = 64):
        if maxsize < 1:
            raise ValueError(f"maxsize must be >= 1, got {maxsize}")
        self.maxsize = maxsize
        self.evictions = 0
        self._data: OrderedDict = OrderedDict()

    def get(self, key, default=None):
        try:
            value = self._data[key]
        except KeyError:
            return default
        self._data.move_to_end(key)
        return value

    def __getitem__(self, key):
        value = self._data[key]
        self._data.move_to_end(key)
        return value

    def __setitem__(self, key, value) -> None:
        self._data[key] = value
        self._data.move_to_end(key)
        while len(self._data) > self.maxsize:
            self._data.popitem(last=False)
            self.evictions += 1

    def __contains__(self, key) -> bool:
        return key in self._data

    def __len__(self) -> int:
        return len(self._data)

    def keys(self):
        return self._data.keys()


class PartitionSession:
    """Serve batches of ``PartitionRequest``s against shared device state.

    Parameters
    ----------
    devices:
        PE count the session's shared mesh is built for (once, lazily,
        on the first distributed request at that count). Requests keep
        their own ``devices`` field — one at a different count simply
        runs without the shared mesh, exactly as a solo run would.
    backend:
        Optional registry name replacing each request's ``"auto"`` hint.
    max_workers:
        Thread-pool width for concurrent independent requests. Graph
        generation and the numpy driver phases overlap; jitted programs
        serialize on the device, so a small pool is plenty.
    mesh:
        Optional pre-built 1D ``'pe'`` mesh of exactly ``devices``
        devices. The multi-mesh serving tier (``repro.serve``) carves
        the host's devices into disjoint slices and binds one session
        per slice; without it the session lazily builds a mesh over the
        first ``devices`` host devices.
    graph_cache:
        Optional externally owned ``GraphSpec -> Graph`` mapping. The
        serving tier shares one cache across all worker sessions so a
        spec is materialized once per *server*, not once per mesh.
        When omitted, the session owns a :class:`BucketCache` bounded
        at ``graph_cache_size`` entries.
    graph_cache_lock:
        Lock guarding ``graph_cache``. Callers sharing one cache across
        sessions must share one lock too — otherwise two sessions can
        miss concurrently and both pay the materialization. The lock is
        held *through* the materialize on purpose: duplicated generator
        work costs seconds, a serialized cache miss costs a wait.
    graph_cache_size:
        LRU bound of the session-owned cache (ignored when an external
        ``graph_cache`` is supplied).
    stack:
        Stacked-leading-axis execution for ``submit_many`` batches:
        ``"auto"`` (on for accelerator backends, off on CPU where the
        per-row sort is compute-bound and vmap buys nothing),
        ``"on"``, or ``"off"``. See ``repro.serve.batching``.
    """

    def __init__(self, devices: int = 1, backend: Optional[str] = None,
                 max_workers: int = 4, mesh=None,
                 graph_cache: Optional[Dict[GraphSpec, object]] = None,
                 graph_cache_lock: Optional[threading.Lock] = None,
                 graph_cache_size: int = 64, stack: str = "auto"):
        if devices < 1:
            raise ValueError(f"devices must be >= 1, got {devices}")
        if mesh is not None and (mesh.axis_names != ("pe",)
                                 or mesh.devices.size != devices):
            raise ValueError(
                f"mesh must be a 1D 'pe' mesh of exactly {devices} "
                f"device(s), got axes {mesh.axis_names} over "
                f"{mesh.devices.size}")
        if stack not in ("auto", "on", "off"):
            raise ValueError(
                f"stack must be 'auto', 'on' or 'off', got {stack!r}")
        self.devices = devices
        self.stack = stack
        self._engine = Partitioner(backend=backend)
        self._pool = ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="repro-api")
        self._lock = threading.Lock()
        self._mesh = mesh
        self._shard_ctx = None
        self._graph_cache: Dict[GraphSpec, object] = \
            graph_cache if graph_cache is not None \
            else BucketCache(graph_cache_size)
        self._graph_cache_lock = graph_cache_lock if \
            graph_cache_lock is not None else threading.Lock()
        self._served = 0
        self._total_time_s = 0.0
        self._closed = False

    # -- shared state ------------------------------------------------------

    @property
    def mesh(self):
        """The session's 1D 'pe' mesh (built on first use; ``None`` for
        single-device sessions)."""
        if self.devices <= 1:
            return None
        with self._lock:
            if self._mesh is None:
                from ..dist.dist_lp import make_mesh_1d
                self._mesh = make_mesh_1d(self.devices)
            return self._mesh

    @property
    def shard_ctx(self):
        """ShardCtx over the session mesh — the handle model layers use
        to consume this session's partitions."""
        if self._shard_ctx is None:
            from ..dist.sharding import NULL_CTX, ShardCtx
            mesh = self.mesh
            self._shard_ctx = NULL_CTX if mesh is None else ShardCtx(mesh)
        return self._shard_ctx

    def _resolve_graph(self, req: PartitionRequest):
        """Materialize (and cache) GraphSpec graphs once per cache —
        the lock spans the materialize so concurrent misses on one spec
        (possibly from different sessions sharing the cache) never
        duplicate the generator work."""
        if isinstance(req.graph, GraphSpec):
            with self._graph_cache_lock:
                g = self._graph_cache.get(req.graph)
                if g is None:
                    g = req.graph.materialize()
                    self._graph_cache[req.graph] = g
            return dataclasses.replace(req, graph=g)
        return req

    # -- serving -----------------------------------------------------------

    def _run_one(self, req: PartitionRequest,
                 level0_labels=None) -> PartitionResult:
        req = self._resolve_graph(req)
        eff = req
        if self._engine.backend is not None and req.backend == "auto":
            eff = dataclasses.replace(req, backend=self._engine.backend)
        name = resolve_backend(eff, req.graph.n)
        # the shared mesh only fits requests at the session's PE count;
        # anything else runs exactly as a solo Partitioner would
        mesh = self.mesh if (name in ("dist", "dist-grid")
                             and req.devices == self.devices) else None
        res = self._engine.run(
            req, _ctx=BackendContext(devices=req.devices, mesh=mesh,
                                     level0_labels=level0_labels))
        with self._lock:
            self._served += 1
            self._total_time_s += res.time_s
        return res

    def _run_many(self, requests: List[PartitionRequest]
                  ) -> List[PartitionResult]:
        # lazy import: repro.serve layers on repro.api, not the reverse
        from ..serve.batching import run_coalesced
        return run_coalesced(self, requests, stack=self.stack)

    def submit(self, req: PartitionRequest) -> "Future[PartitionResult]":
        """Enqueue one request; returns a future.

        The closed-check and the executor submit happen under one lock
        span: a submit racing ``close()`` either lands before the close
        (and runs/cancels with the pool) or observes ``_closed`` and
        raises the documented session-closed error — never the
        executor's own shutdown ``RuntimeError``."""
        with self._lock:
            if self._closed:
                raise RuntimeError("session is closed")
            return self._pool.submit(self._run_one, req)

    def submit_many(self, requests: Sequence[PartitionRequest]
                    ) -> "Future[List[PartitionResult]]":
        """Enqueue a same-shape-bucket batch as ONE unit of work: the
        returned future resolves to results in request order. Identical
        requests are coalesced into a single partition run (requests
        are pure functions of their fields), and — with ``stack`` on —
        distinct requests share one stacked level-0 clustering program.
        Results are bit-identical to per-request ``submit``."""
        with self._lock:
            if self._closed:
                raise RuntimeError("session is closed")
            return self._pool.submit(self._run_many, list(requests))

    def run_batch(self, requests: Iterable[PartitionRequest]
                  ) -> List[PartitionResult]:
        """Serve a batch concurrently; results in request order.

        A mid-loop submit failure (e.g. the session closing under us)
        does not leak the already-submitted futures: they are cancelled
        where possible and awaited otherwise, so no orphaned work keeps
        running after the caller saw the raise."""
        futures: List[Future] = []
        try:
            for r in requests:
                futures.append(self.submit(r))
        except BaseException:
            for f in futures:
                f.cancel()
            for f in futures:
                if not f.cancelled():
                    try:
                        f.result()
                    except Exception:
                        pass  # the caller gets the submit failure
            raise
        return [f.result() for f in futures]

    def stats(self) -> Dict[str, float]:
        with self._lock:
            return {"served": self._served,
                    "devices": self.devices,
                    "total_partition_time_s": round(self._total_time_s, 6)}

    # -- lifecycle ---------------------------------------------------------

    def close(self, wait: bool = True) -> None:
        """``wait=False`` abandons in-flight work — the serving tier
        uses it for workers whose executor thread is known wedged.

        ``_closed`` flips under the same lock ``submit`` holds; the
        pool shutdown happens *outside* it (running requests take the
        lock for stats, so shutting down inside would deadlock
        ``wait=True``)."""
        with self._lock:
            self._closed = True
        self._pool.shutdown(wait=wait)

    def __enter__(self) -> "PartitionSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
