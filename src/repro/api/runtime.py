"""Runtime/device helpers for the partitioning facade.

Deliberately free of ``jax``/``repro`` imports at module level: CLIs call
``force_host_devices`` *before* anything that could initialize a jax
backend, and importing this module must never be the thing that does it.
"""
from __future__ import annotations

import os
import sys

_FLAG = "--xla_force_host_platform_device_count"


def jax_backend_initialized() -> bool:
    """True iff a jax backend has been created in this process (at which
    point the device count is locked and XLA_FLAGS edits are ignored)."""
    xb = sys.modules.get("jax._src.xla_bridge")
    if xb is None:
        return False
    if hasattr(xb, "_backends"):        # jax 0.4.x: dict filled at init
        return bool(xb._backends)
    # private layout changed (newer jax): report initialized so that
    # force_host_devices fails loudly instead of silently editing flags
    # that may never be read
    return True


def device_count() -> int:
    """Devices visible to jax (initializes the backend on first call)."""
    import jax
    return len(jax.devices())


def device_slices(num_slices: int, devices_per_slice: int):
    """Carve the host's devices into ``num_slices`` disjoint contiguous
    slices of ``devices_per_slice`` devices each (the serving tier's
    worker meshes — saxml-style: one model server per device group).

    Initializes jax. Raises ``RuntimeError`` when the host doesn't have
    ``num_slices * devices_per_slice`` devices — oversubscribing a
    device into two meshes would serialize their collectives against
    each other, which is exactly what a multi-mesh tier exists to avoid.
    """
    if num_slices < 1 or devices_per_slice < 1:
        raise ValueError(
            "need num_slices >= 1 and devices_per_slice >= 1, got "
            f"{num_slices} x {devices_per_slice}")
    import jax
    devs = jax.devices()
    need = num_slices * devices_per_slice
    if len(devs) < need:
        raise RuntimeError(
            f"cannot carve {num_slices} slices of {devices_per_slice} "
            f"device(s) from {len(devs)} visible device(s); force more "
            "with force_host_devices() before any jax computation")
    return [devs[i * devices_per_slice:(i + 1) * devices_per_slice]
            for i in range(num_slices)]


def force_host_devices(n: int) -> None:
    """Force ``n`` host (CPU) devices via XLA_FLAGS.

    Safe to call multiple times; replaces any earlier count in the flag.
    If jax is already *initialized* this cannot take effect any more:
    the call is a no-op when enough devices exist, and raises a clear
    ``RuntimeError`` otherwise (instead of the old silent reliance on
    import order).
    """
    if n <= 0:
        return
    if jax_backend_initialized():
        have = device_count()
        if have >= n:
            return
        raise RuntimeError(
            f"cannot force {n} host devices: jax is already initialized "
            f"with {have} device(s). Call force_host_devices() before any "
            "jax computation (e.g. first thing in main()), or run in a "
            "fresh subprocess.")
    kept = [t for t in os.environ.get("XLA_FLAGS", "").split()
            if not t.startswith(_FLAG)]
    kept.append(f"{_FLAG}={n}")
    os.environ["XLA_FLAGS"] = " ".join(kept)
