"""Runtime/device helpers for the partitioning facade.

Deliberately free of ``jax``/``repro`` imports at module level: CLIs call
``force_host_devices`` *before* anything that could initialize a jax
backend, and importing this module must never be the thing that does it.
"""
from __future__ import annotations

import os
import sys
from typing import Any, List, Optional, Sequence

_FLAG = "--xla_force_host_platform_device_count"


def jax_backend_initialized() -> bool:
    """True iff a jax backend has been created in this process (at which
    point the device count is locked and XLA_FLAGS edits are ignored)."""
    xb = sys.modules.get("jax._src.xla_bridge")
    if xb is None:
        return False
    if hasattr(xb, "_backends"):        # jax 0.4.x: dict filled at init
        return bool(xb._backends)
    # private layout changed (newer jax): report initialized so that
    # force_host_devices fails loudly instead of silently editing flags
    # that may never be read
    return True


def device_count() -> int:
    """Devices visible to jax (initializes the backend on first call)."""
    import jax
    return len(jax.devices())


def device_slices(num_slices: int,
                  devices_per_slice: int) -> List[List[Any]]:
    """Carve the host's devices into ``num_slices`` disjoint contiguous
    slices of ``devices_per_slice`` devices each (the serving tier's
    worker meshes — saxml-style: one model server per device group).

    Initializes jax. Raises ``RuntimeError`` when the host doesn't have
    ``num_slices * devices_per_slice`` devices — oversubscribing a
    device into two meshes would serialize their collectives against
    each other, which is exactly what a multi-mesh tier exists to avoid.
    """
    if num_slices < 1 or devices_per_slice < 1:
        raise ValueError(
            "need num_slices >= 1 and devices_per_slice >= 1, got "
            f"{num_slices} x {devices_per_slice}")
    import jax
    devs = jax.devices()
    need = num_slices * devices_per_slice
    if len(devs) < need:
        # name the shortfall AND the largest feasible carve, both ways
        # round — the caller decides whether to shrink the slice count
        # or the slices themselves
        feas_slices = len(devs) // devices_per_slice
        feas_per = len(devs) // num_slices
        if feas_slices >= 1:
            hint = (f"largest feasible: {feas_slices} slice(s) of "
                    f"{devices_per_slice}")
            if feas_per >= 1 and feas_per != devices_per_slice:
                hint += (f", or {num_slices} slice(s) of {feas_per} "
                         "device(s)")
        elif feas_per >= 1:
            hint = (f"largest feasible: {num_slices} slice(s) of "
                    f"{feas_per} device(s)")
        else:
            hint = "no carve of this shape is feasible"
        raise RuntimeError(
            f"cannot carve {num_slices} slice(s) of {devices_per_slice} "
            f"device(s) ({need} total): only {len(devs)} device(s) "
            f"available; {hint}. Force more host devices with "
            "force_host_devices() before any jax computation")
    return [devs[i * devices_per_slice:(i + 1) * devices_per_slice]
            for i in range(num_slices)]


def distributed_init(coordinator_address: Optional[str] = None,
                     num_processes: Optional[int] = None,
                     process_id: Optional[int] = None,
                     local_device_ids: Optional[Sequence[int]] = None
                     ) -> dict:
    """Multi-process jax runtime for the serving fabric's workers.

    Wraps ``jax.distributed.initialize`` so each fabric worker process
    owns its own mesh over *its* slice of a real multi-host topology —
    the mode that lets the ``dist`` / ``dist-grid`` backends stop
    depending on ``force_host_devices``-faked devices. Arguments fall
    back to the ``REPRO_COORDINATOR`` / ``REPRO_NUM_PROCESSES`` /
    ``REPRO_PROCESS_ID`` environment variables (and from there to jax's
    own cluster auto-detection inputs).

    ``num_processes`` of 1 (or unset with no coordinator) is the
    single-process mode: a deliberate no-op, so the same worker entry
    point runs unchanged on a laptop, in CI (with
    ``force_host_devices``) and on a cluster. Returns an info dict
    (``mode``, ``process_id``, ``num_processes``).

    Must run before any jax computation: like ``force_host_devices``,
    this raises ``RuntimeError`` once a backend exists rather than
    silently doing nothing.
    """
    coordinator_address = coordinator_address or \
        os.environ.get("REPRO_COORDINATOR") or None
    if num_processes is None:
        env_np = os.environ.get("REPRO_NUM_PROCESSES")
        num_processes = int(env_np) if env_np else None
    if process_id is None:
        env_pid = os.environ.get("REPRO_PROCESS_ID")
        process_id = int(env_pid) if env_pid else None
    if coordinator_address is None and (num_processes or 1) <= 1:
        return {"mode": "single-process", "process_id": 0,
                "num_processes": 1}
    if num_processes is not None and num_processes < 1:
        raise ValueError(
            f"num_processes must be >= 1, got {num_processes}")
    if process_id is not None and num_processes is not None and \
            not (0 <= process_id < num_processes):
        raise ValueError(
            f"process_id {process_id} out of range for "
            f"{num_processes} process(es)")
    if jax_backend_initialized():
        raise RuntimeError(
            "cannot initialize the multi-process runtime: jax already "
            "has a backend in this process. Call distributed_init() "
            "before any jax computation (first thing in main()).")
    import jax
    jax.distributed.initialize(
        coordinator_address=coordinator_address,
        num_processes=num_processes, process_id=process_id,
        local_device_ids=local_device_ids)
    return {"mode": "multi-process",
            "process_id": jax.process_index(),
            "num_processes": jax.process_count()}


def force_host_devices(n: int) -> None:
    """Force ``n`` host (CPU) devices via XLA_FLAGS.

    Safe to call multiple times; replaces any earlier count in the flag.
    If jax is already *initialized* this cannot take effect any more:
    the call is a no-op when enough devices exist, and raises a clear
    ``RuntimeError`` otherwise (instead of the old silent reliance on
    import order).
    """
    if n <= 0:
        return
    if jax_backend_initialized():
        have = device_count()
        if have >= n:
            return
        raise RuntimeError(
            f"cannot force {n} host devices: jax is already initialized "
            f"with {have} device(s). Call force_host_devices() before any "
            "jax computation (e.g. first thing in main()), or run in a "
            "fresh subprocess.")
    kept = [t for t in os.environ.get("XLA_FLAGS", "").split()
            if not t.startswith(_FLAG)]
    kept.append(f"{_FLAG}={n}")
    os.environ["XLA_FLAGS"] = " ".join(kept)
