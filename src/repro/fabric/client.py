"""Client for the fabric front door.

One persistent connection, many outstanding requests: ``submit``
returns a future immediately and a reader thread matches ``result``
frames back by id, so a client drives the whole fleet's concurrency
without threads of its own. Results decode to
:class:`protocol.FabricResult` — errors are data, and a dead
connection resolves every outstanding future with a structured
``connection_lost`` error instead of raising from a background thread.
"""

from __future__ import annotations

import json
import socket
import threading
from concurrent.futures import Future
from typing import Any, Dict, Iterable, List, Optional

from . import protocol
from .protocol import FabricResult, recv_msg, send_msg


class FabricClient:
    """Submit partition requests to a :class:`fabric.FrontDoor`."""

    def __init__(
        self,
        host: str,
        port: int,
        *,
        connect_timeout: float = 10.0,
    ):
        self.host, self.port = host, port
        self._sock = protocol.connect(host, port, timeout=connect_timeout)
        self._sock.settimeout(None)
        self._send_lock = threading.Lock()
        self._lock = threading.Lock()
        self._futures: Dict[int, "Future[FabricResult]"] = {}
        self._next_id = 0
        self._closed = False
        self._reader = threading.Thread(
            target=self._recv_loop,
            name="repro-fabric-client",
            daemon=True,
        )
        self._reader.start()

    def _recv_loop(self) -> None:
        err = "front door closed the connection"
        try:
            while True:
                msg = recv_msg(self._sock)
                if msg is None:
                    break
                if msg.get("op") != "result":
                    continue
                with self._lock:
                    fut = self._futures.pop(msg.get("id"), None)
                if fut is not None:
                    self._set(fut, protocol.decode_result(msg["result"]))
        except (OSError, protocol.ProtocolError, json.JSONDecodeError) as exc:
            err = f"{type(exc).__name__}: {exc}"
        with self._lock:
            orphans = list(self._futures.values())
            self._futures.clear()
        lost = protocol.decode_result(
            protocol.error_result(protocol.ERR_CONNECTION, err)
        )
        for fut in orphans:
            self._set(fut, lost)

    @staticmethod
    def _set(fut: Future, res: FabricResult) -> None:
        try:
            fut.set_result(res)
        except Exception:
            pass  # cancelled by the caller

    def submit(
        self,
        request,
        *,
        priority: int = 0,
        deadline_s: Optional[float] = None,
        timeout_s: Optional[float] = None,
    ) -> "Future[FabricResult]":
        """Admit one request; resolves to a :class:`FabricResult`."""
        fut: "Future[FabricResult]" = Future()
        with self._lock:
            if self._closed:
                res = protocol.error_result(
                    protocol.ERR_CONNECTION, "client closed"
                )
                self._set(fut, protocol.decode_result(res))
                return fut
            rid = self._next_id
            self._next_id += 1
            self._futures[rid] = fut
        frame = {
            "op": "partition",
            "id": rid,
            "request": protocol.encode_request(request),
            "priority": priority,
            "deadline_s": deadline_s,
            "timeout_s": timeout_s,
        }
        try:
            with self._send_lock:
                send_msg(self._sock, frame)
        except OSError as exc:
            with self._lock:
                self._futures.pop(rid, None)
            res = protocol.error_result(
                protocol.ERR_CONNECTION, f"send failed: {exc}"
            )
            self._set(fut, protocol.decode_result(res))
        return fut

    def serve(self, requests: Iterable, **submit_kw) -> List[FabricResult]:
        """Admit a batch and block for all results, in request order."""
        futures = [self.submit(r, **submit_kw) for r in requests]
        return [f.result() for f in futures]

    def status(self) -> Dict[str, Any]:
        """Front-door status snapshot (a fresh short-lived connection,
        so it works even while this client's pipe is saturated)."""
        return status_of(self.host, self.port)

    def close(self) -> None:
        with self._lock:
            self._closed = True
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass
        self._reader.join(timeout=2.0)

    def __enter__(self) -> "FabricClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def status_of(host: str, port: int, timeout: float = 10.0) -> Dict[str, Any]:
    """One-shot status query against a front door."""
    sock = protocol.connect(host, port, timeout=timeout)
    try:
        send_msg(sock, {"op": "status"})
        resp = recv_msg(sock)
        if resp is None:
            raise protocol.ProtocolError(
                "front door closed before replying to status"
            )
        return resp
    finally:
        sock.close()
