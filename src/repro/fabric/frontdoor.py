"""RPC front door: admission, routing and failover across worker
*processes*.

``FrontDoor`` is the cross-process analogue of ``PartitionServer``: a
TCP listener admitting ``partition`` frames into the same
``AdmissionQueue``/``Ticket`` machinery, a dispatcher routing each
ticket to the best-fitting *registered server* (``scheduler.pick_server``
— the in-process mesh policy lifted to server granularity), and the PR 5
failover contract at process scope: a lost work connection or an expired
lease orphans that server's in-flight tickets back into the queue with
the server excluded, so they retry elsewhere or surface a structured
error — an admitted ticket always resolves, even when the process that
owned it was SIGKILLed.

Workers announce themselves over heartbeat connections
(``register``/``renew``, see ``fabric.registry``); the front door dials
each registered server's work port and multiplexes ``partition`` frames
over that one connection, matching ``result`` frames back to tickets by
id. An optional :class:`fabric.autoscaler.AutoscalePolicy` watches the
front door's windowed metrics and grows/shrinks a ``ProcessScaler``
fleet of local worker processes.

The front door never initializes a jax backend (it owns no devices):
routing uses the same pure ``required_devices`` policy as the
in-process scheduler, and assignments cross it as opaque encoded
payloads — only worker processes ever run a partition.
"""

from __future__ import annotations

import dataclasses
import json
import socket
import threading
import time
from concurrent.futures import Future
from types import SimpleNamespace
from typing import Any, Dict, List, Optional, Sequence, Set

from ..api.backends import required_devices
from ..serve.metrics import ServeMetrics
from ..serve.queue import AdmissionQueue, Ticket
from ..serve.scheduler import pick_server
from ..serve.server import (
    ERR_CLOSED,
    ERR_DEADLINE,
    ERR_NO_WORKER,
    ERR_REJECTED,
    ERR_WORKER,
)
from . import protocol
from .autoscaler import AutoscaleConfig, AutoscalePolicy, ProcessScaler
from .protocol import recv_msg, send_msg
from .registry import ServerRegistry

# worker-reported structured errors that justify excluding the server
# and retrying elsewhere (vs. deadline_exceeded, which is the request's
# own fault and passes through)
_RETRYABLE = {ERR_WORKER, ERR_NO_WORKER, ERR_CLOSED, ERR_REJECTED}


class _ServerHandle:
    """One registered server's work connection plus its routing state
    (``inflight``/``pending`` guarded by the front door's condition)."""

    def __init__(self, record, sock: socket.socket):
        self.sid: str = record.server_id
        self.generation: int = record.generation
        self.devices: int = record.devices
        self.capacity: int = max(1, record.meshes)
        self.sock = sock
        self.send_lock = threading.Lock()
        self.inflight = 0
        self.pending: Dict[int, Ticket] = {}
        self.alive = True


class FrontDoor:
    """Cross-process serving front door.

    Parameters
    ----------
    host, port:
        Bind address (``port=0`` picks an ephemeral port; read it back
        from ``self.port``).
    lease_ttl_s:
        Server-lease TTL; a server missing renewals for this long is
        expired and its in-flight work fails over (see
        ``fabric.registry``).
    max_queue:
        Admission bound; beyond it submissions resolve ``rejected``.
    max_retries:
        Failed attempts per ticket before the error surfaces (default
        1: one retry on a *different* server — the PR 5 contract).
    autoscale:
        Optional :class:`AutoscaleConfig`; when set, the front door
        owns a fleet of local worker subprocesses sized by queue
        pressure (see ``fabric.autoscaler``).
    worker_args:
        Extra ``repro.launch.fabric worker`` CLI args for autoscaled
        workers (e.g. ``["--meshes", "2"]``); the front-door address is
        appended automatically.
    """

    def __init__(
        self,
        host: str = "127.0.0.1",
        port: int = 0,
        *,
        lease_ttl_s: float = 5.0,
        max_queue: int = 1024,
        max_retries: int = 1,
        autoscale: Optional[AutoscaleConfig] = None,
        worker_args: Optional[Sequence[str]] = None,
    ):
        self.registry = ServerRegistry(ttl_s=lease_ttl_s)
        self._queue = AdmissionQueue(capacity=max_queue)
        self._metrics = ServeMetrics(0)
        self._max_retries = max_retries
        self._handles: Dict[str, _ServerHandle] = {}
        self._sid_index: Dict[str, int] = {}  # sid -> metrics slot
        self._cond = threading.Condition()
        self._closing = threading.Event()
        self._seq = 0
        self._conns: Set[socket.socket] = set()
        self._conns_lock = threading.Lock()

        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, port))
        self._listener.listen(128)
        self.host, self.port = self._listener.getsockname()[:2]

        self._scaler: Optional[ProcessScaler] = None
        self._policy: Optional[AutoscalePolicy] = None
        if autoscale is not None:
            args = list(worker_args or [])
            args += ["--frontdoor", f"{self.host}:{self.port}"]
            self._policy = AutoscalePolicy(autoscale)
            self._scaler = ProcessScaler(worker_args=args)

        self._threads = [
            threading.Thread(
                target=self._accept_loop,
                name="repro-fabric-fd-accept",
                daemon=True,
            ),
            threading.Thread(
                target=self._dispatch_loop,
                name="repro-fabric-fd-dispatch",
                daemon=True,
            ),
            threading.Thread(
                target=self._expiry_loop,
                name="repro-fabric-fd-expiry",
                daemon=True,
            ),
        ]
        if self._policy is not None:
            scaler_thread = threading.Thread(
                target=self._autoscale_loop,
                name="repro-fabric-fd-autoscale",
                daemon=True,
            )
            self._threads.append(scaler_thread)
        for t in self._threads:
            t.start()

    # -- connections ---------------------------------------------------

    def _accept_loop(self) -> None:
        while not self._closing.is_set():
            try:
                conn, _addr = self._listener.accept()
            except OSError:
                return
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            with self._conns_lock:
                self._conns.add(conn)
            t = threading.Thread(
                target=self._conn_loop,
                args=(conn,),
                daemon=True,
            )
            t.start()

    def _conn_loop(self, conn: socket.socket) -> None:
        """One inbound connection: clients (partition/status) and worker
        heartbeats (register/renew/deregister) share the listener; the
        op stream tells them apart."""
        send_lock = threading.Lock()
        try:
            while True:
                msg = recv_msg(conn)
                if msg is None:
                    return
                self._handle(conn, send_lock, msg)
        except (OSError, protocol.ProtocolError, json.JSONDecodeError):
            return
        finally:
            with self._conns_lock:
                self._conns.discard(conn)
            try:
                conn.close()
            except OSError:
                pass

    def _handle(self, conn, send_lock, msg: Dict[str, Any]) -> None:
        op = msg.get("op")
        if op == "partition":
            self._admit(conn, send_lock, msg)
        elif op == "register":
            self._on_register(conn, send_lock, msg)
        elif op == "renew":
            sid = msg.get("server_id", "")
            if self.registry.renew(sid, metrics=msg.get("metrics")):
                resp = {
                    "op": "lease",
                    "server_id": sid,
                    "ttl_s": self.registry.ttl_s,
                }
            else:
                resp = {"op": "unknown_server", "server_id": sid}
            self._safe_send(conn, send_lock, resp)
        elif op == "deregister":
            sid = msg.get("server_id", "")
            self.registry.deregister(sid)
            with self._cond:
                handle = self._handles.get(sid)
            if handle is not None:
                # a graceful deregister already answered its pending
                # frames (the worker drains before saying goodbye);
                # anything still pending rides the failover path
                self._on_server_lost(handle, "server deregistered")
            self._safe_send(conn, send_lock, {"op": "bye", "server_id": sid})
        elif op == "status":
            self._safe_send(conn, send_lock, self.status())
        else:
            resp = {"op": "error", "detail": f"unknown op {op!r}"}
            self._safe_send(conn, send_lock, resp)

    @staticmethod
    def _safe_send(conn, send_lock, obj: Dict[str, Any]) -> None:
        try:
            with send_lock:
                send_msg(conn, obj)
        except OSError:
            pass

    # -- worker registration -------------------------------------------

    def _on_register(self, conn, send_lock, msg: Dict[str, Any]) -> None:
        info = msg.get("server") or {}
        try:
            record = self.registry.register(
                server_id=str(info["server_id"]),
                host=str(info["host"]),
                port=int(info["port"]),
                devices=int(info.get("devices", 1)),
                meshes=int(info.get("meshes", 1)),
                pid=info.get("pid"),
            )
        except (KeyError, TypeError, ValueError) as exc:
            resp = {"op": "error", "detail": f"bad register: {exc}"}
            self._safe_send(conn, send_lock, resp)
            return
        resp = {
            "op": "lease",
            "server_id": record.server_id,
            "ttl_s": self.registry.ttl_s,
        }
        self._safe_send(conn, send_lock, resp)
        # dial the work connection outside the registry lock; a
        # re-registration (restarted worker, new generation) replaces
        # any stale handle, failing its orphans over
        t = threading.Thread(
            target=self._ensure_handle,
            args=(record,),
            daemon=True,
        )
        t.start()

    def _ensure_handle(self, record) -> None:
        with self._cond:
            old = self._handles.get(record.server_id)
        if old is not None:
            if old.generation == record.generation and old.alive:
                return  # already connected to this incarnation
            self._on_server_lost(old, "replaced by re-registration")
        try:
            sock = protocol.connect(record.host, record.port, timeout=5.0)
        except OSError as exc:
            # unreachable worker: drop the lease so it re-registers
            # (and re-announces a reachable address) on its next beat
            self.registry.deregister(record.server_id)
            self._log_unreachable(record, exc)
            return
        handle = _ServerHandle(record, sock)
        with self._cond:
            if self._closing.is_set():
                handle.alive = False
            else:
                self._handles[record.server_id] = handle
                self._sid_index.setdefault(
                    record.server_id, len(self._sid_index)
                )
            self._cond.notify_all()
        if not handle.alive:
            sock.close()
            return
        t = threading.Thread(
            target=self._recv_loop,
            args=(handle,),
            daemon=True,
        )
        t.start()

    @staticmethod
    def _log_unreachable(record, exc) -> None:
        import logging

        logging.getLogger(__name__).warning(
            "fabric: server %s advertised %s:%d but is unreachable (%s)",
            record.server_id,
            record.host,
            record.port,
            exc,
        )

    def _recv_loop(self, handle: _ServerHandle) -> None:
        """Match ``result`` frames back to pending tickets; any
        connection failure fails the handle over."""
        try:
            while True:
                msg = recv_msg(handle.sock)
                if msg is None:
                    break
                if msg.get("op") == "result":
                    self._on_result(handle, msg)
        except (OSError, protocol.ProtocolError, json.JSONDecodeError):
            pass
        self._on_server_lost(handle, "work connection lost")

    # -- admission -----------------------------------------------------

    def submit(
        self,
        request,
        *,
        priority: int = 0,
        deadline_s: Optional[float] = None,
        timeout_s: Optional[float] = None,
    ) -> "Future[dict]":
        """Local (in-process) admission — the transport-free core the
        RPC ``partition`` op rides on. Resolves to a *wire dict* (see
        ``protocol.decode_result`` for the typed client view)."""
        if self._closing.is_set():
            raise RuntimeError("front door is closed")
        request.validate()
        need = required_devices(request, request.graph.n)
        now = time.monotonic()
        fut: "Future[dict]" = Future()
        with self._cond:
            seq = self._seq
            self._seq += 1
        ticket = Ticket(
            request=request,
            priority=priority,
            seq=seq,
            future=fut,
            submit_t=now,
            deadline=None if deadline_s is None else now + deadline_s,
            timeout_s=timeout_s,
            need=need,
        )
        if not self._queue.put(ticket):
            code = ERR_CLOSED if self._closing.is_set() else ERR_REJECTED
            if code == ERR_REJECTED:
                self._metrics.on_reject()
                cap = self._queue.capacity
                detail = f"admission queue full (capacity {cap})"
            else:
                detail = "front door closed during submit"
            fut.set_result(protocol.error_result(code, detail))
            return fut
        self._metrics.on_submit(self._queue.depth())
        with self._cond:
            self._cond.notify_all()
        return fut

    def _admit(self, conn, send_lock, msg: Dict[str, Any]) -> None:
        rid = msg.get("id")

        def reply(wire: Dict[str, Any]) -> None:
            frame = {"op": "result", "id": rid, "result": wire}
            self._safe_send(conn, send_lock, frame)

        try:
            req = protocol.decode_request(msg["request"])
            fut = self.submit(
                req,
                priority=int(msg.get("priority", 0)),
                deadline_s=msg.get("deadline_s"),
                timeout_s=msg.get("timeout_s"),
            )
        except protocol.ProtocolError as exc:  # bad frame is data
            reply(protocol.error_result(ERR_REJECTED, str(exc)))
            return
        except RuntimeError as exc:
            reply(protocol.error_result(ERR_CLOSED, str(exc)))
            return
        except Exception as exc:  # malformed request is data
            detail = f"{type(exc).__name__}: {exc}"
            reply(protocol.error_result(ERR_REJECTED, detail))
            return
        fut.add_done_callback(lambda f: reply(f.result()))

    # -- dispatch ------------------------------------------------------

    def _dispatch_loop(self) -> None:
        while not self._closing.is_set():
            if not self._dispatch_once():
                with self._cond:
                    self._cond.wait(0.05)

    def _dispatch_once(self) -> bool:
        """One dispatch action; False when there is nothing to do.
        Mirrors ``PartitionServer._dispatch_once`` at server
        granularity, with one deliberate difference: a *fresh* ticket
        with zero live servers waits in the queue (its deadline still
        enforced) instead of resolving ``no_worker`` — workers register
        asynchronously, and the autoscaler may be about to spawn one.
        Only a ticket that already failed somewhere and has no
        non-excluded live server left resolves ``no_worker``."""
        ticket = self._queue.pop_matching(Ticket.expired)
        if ticket is not None:
            self._metrics.on_dispatch(self._queue.depth())
            wire = protocol.error_result(
                ERR_DEADLINE,
                "expired in front-door queue",
                attempts=ticket.attempts,
            )
            self._resolve_wire(ticket, wire)
            return True
        with self._cond:
            handles = [h for h in self._handles.values() if h.alive]
            alive = {h.sid for h in handles}
            free = {h.sid for h in handles if h.inflight < h.capacity}
        ticket = self._queue.pop_matching(
            lambda t: bool(t.excluded) and not (alive - t.excluded)
        )
        if ticket is not None:
            detail = "; ".join(ticket.errors) or "no live server"
            wire = protocol.error_result(
                ERR_NO_WORKER,
                detail,
                attempts=ticket.attempts,
            )
            self._resolve_wire(ticket, wire)
            return True
        if not free:
            return False
        ticket = self._queue.pop_matching(lambda t: bool(free - t.excluded))
        if ticket is None:
            return False
        self._metrics.on_dispatch(self._queue.depth())
        if ticket.dispatch_t is None:
            ticket.dispatch_t = time.monotonic()
        self._assign_now(ticket)
        return True

    def _assign_now(self, ticket: Ticket) -> None:
        with self._cond:
            views = []
            for h in self._handles.values():
                if not h.alive or h.inflight >= h.capacity:
                    continue
                if h.sid in ticket.excluded:
                    continue
                view = SimpleNamespace(
                    sid=h.sid,
                    devices=h.devices,
                    inflight=h.inflight,
                    handle=h,
                )
                views.append(view)
            view = pick_server(ticket.need, views)
            if view is None:
                # the free set changed under us; requeue for re-routing
                if not self._queue.requeue(ticket):
                    wire = protocol.error_result(
                        ERR_CLOSED,
                        "front door closed during dispatch",
                        attempts=ticket.attempts,
                    )
                    self._resolve_wire(ticket, wire)
                return
            chosen: _ServerHandle = view.handle
            chosen.inflight += 1
            chosen.pending[ticket.seq] = ticket
        frame = {
            "op": "partition",
            "id": ticket.seq,
            "request": protocol.encode_request(ticket.request),
            "priority": ticket.priority,
            "deadline_s": ticket.remaining(),
            "timeout_s": ticket.timeout_s,
        }
        try:
            with chosen.send_lock:
                send_msg(chosen.sock, frame)
        except OSError:
            self._on_server_lost(chosen, "send failed")

    # -- results / failover --------------------------------------------

    def _on_result(self, handle: _ServerHandle, msg: Dict[str, Any]) -> None:
        with self._cond:
            ticket = handle.pending.pop(msg.get("id"), None)
            if ticket is not None:
                handle.inflight -= 1
            self._cond.notify_all()
        if ticket is None:
            return  # late result for a ticket that already failed over
        wire = msg.get("result") or {}
        if wire.get("ok") or wire.get("error") == ERR_DEADLINE:
            self._resolve_wire(ticket, wire)
        elif wire.get("error") in _RETRYABLE:
            detail = f"{wire.get('error')}: {wire.get('detail', '')}"
            self._attempt_failed(ticket, handle.sid, detail)
        else:  # unknown error code: surface it as-is, annotated
            self._resolve_wire(ticket, wire)

    def _on_server_lost(self, handle: _ServerHandle, reason: str) -> None:
        """A dead work connection (or expired lease): orphaned tickets
        fail over exactly like a killed in-process mesh worker."""
        with self._cond:
            if not handle.alive:
                return
            handle.alive = False
            orphans = list(handle.pending.values())
            handle.pending.clear()
            handle.inflight = 0
            cur = self._handles.get(handle.sid)
            if cur is handle:
                del self._handles[handle.sid]
            self._cond.notify_all()
        try:
            handle.sock.close()
        except OSError:
            pass
        self.registry.deregister(handle.sid)
        for t in orphans:
            self._attempt_failed(t, handle.sid, reason)

    def _attempt_failed(self, ticket: Ticket, sid: str, detail: str) -> None:
        """PR 5 supervision at server scope: record, exclude, retry
        while the budget allows — the queue's no-server rule surfaces
        ``no_worker`` if nowhere is left to go."""
        ticket.errors.append(f"server {sid}: {detail}")
        ticket.excluded.add(sid)
        ticket.attempts += 1
        can_retry = (
            ticket.attempts <= self._max_retries
            and not self._closing.is_set()
        )
        if can_retry and self._queue.requeue(ticket):
            self._metrics.on_retry()
            with self._cond:
                self._cond.notify_all()
            return
        wire = protocol.error_result(
            ERR_WORKER,
            "; ".join(ticket.errors),
            attempts=ticket.attempts,
        )
        self._resolve_wire(ticket, wire)

    def _resolve_wire(self, ticket: Ticket, wire: Dict[str, Any]) -> None:
        """Annotate with front-door timings/attempts and resolve."""
        now = time.monotonic()
        qw = (ticket.dispatch_t or now) - ticket.submit_t
        total = now - ticket.submit_t
        wire = dict(wire)
        wire["attempts"] = ticket.attempts + (1 if wire.get("ok") else 0)
        wire["queue_wait_s"] = round(qw, 6)
        wire["total_s"] = round(total, 6)
        sid = wire.get("server")
        widx = self._sid_index.get(sid) if sid is not None else None
        self._metrics.on_done(
            bool(wire.get("ok")),
            total,
            qw,
            widx,
            expired=wire.get("error") == ERR_DEADLINE,
        )
        try:
            ticket.future.set_result(wire)
        except Exception:
            pass  # double resolution (late result raced a failover)

    # -- lease expiry / autoscaling ------------------------------------

    def _expiry_loop(self) -> None:
        period = max(0.05, min(0.5, self.registry.ttl_s / 4.0))
        while not self._closing.wait(period):
            for record in self.registry.expire():
                with self._cond:
                    handle = self._handles.get(record.server_id)
                if handle is not None:
                    self._on_server_lost(
                        handle,
                        f"lease expired after {self.registry.ttl_s:.1f}s "
                        "without a heartbeat",
                    )

    def _autoscale_loop(self) -> None:
        policy, scaler = self._policy, self._scaler
        period = policy.cfg.eval_period_s
        while not self._closing.wait(period):
            win = self._metrics.snapshot_window()
            with self._cond:
                inflight = sum(
                    h.inflight for h in self._handles.values() if h.alive
                )
            workers = max(len(self.registry.alive()), scaler.count())
            act = policy.observe(
                workers=workers,
                queue_depth=self._queue.depth(),
                deadline_misses=win["expired"],
                submitted=win["submitted"],
                inflight=inflight,
            )
            if act > 0 or workers < policy.cfg.min_workers:
                scaler.scale_up()
            elif act < 0:
                scaler.scale_down()

    # -- introspection / lifecycle -------------------------------------

    def status(self) -> Dict[str, Any]:
        per_server: Dict[str, Dict[str, Any]] = {}
        with self._cond:
            for h in self._handles.values():
                per_server[h.sid] = {
                    "inflight": h.inflight,
                    "pending": len(h.pending),
                    "alive": h.alive,
                }
        servers: List[Dict[str, Any]] = []
        for rec in self.registry.alive():
            row = rec.summary()
            row.update(per_server.get(rec.server_id, {}))
            servers.append(row)
        out = {
            "op": "status",
            "host": self.host,
            "port": self.port,
            "servers": servers,
            "queue_depth": self._queue.depth(),
            "metrics": self._metrics.snapshot(),
        }
        if self._scaler is not None:
            out["autoscaler"] = {
                "procs": self._scaler.count(),
                "config": dataclasses.asdict(self._policy.cfg),
            }
        return out

    def close(self) -> None:
        """Stop admission, resolve queued tickets ``server_closed``,
        drop every server connection (their pending tickets resolve
        too) and reap autoscaled workers."""
        if self._closing.is_set():
            return
        self._closing.set()
        self._queue.close()
        for t in self._queue.drain():
            wire = protocol.error_result(
                ERR_CLOSED,
                "front door closed before dispatch",
                attempts=t.attempts,
            )
            self._resolve_wire(t, wire)
        try:
            self._listener.close()
        except OSError:
            pass
        with self._cond:
            handles = list(self._handles.values())
            self._cond.notify_all()
        for h in handles:
            with self._cond:
                orphans = list(h.pending.values())
                h.pending.clear()
                h.alive = False
                self._handles.pop(h.sid, None)
            for t in orphans:
                wire = protocol.error_result(
                    ERR_CLOSED,
                    "front door closed",
                    attempts=t.attempts,
                )
                self._resolve_wire(t, wire)
            try:
                h.sock.close()
            except OSError:
                pass
        if self._scaler is not None:
            self._scaler.close()
        with self._conns_lock:
            conns = list(self._conns)
        for c in conns:
            try:
                c.close()
            except OSError:
                pass
        for t in self._threads:
            t.join(timeout=2.0)

    def __enter__(self) -> "FrontDoor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
