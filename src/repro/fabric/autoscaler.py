"""Autoscaler: grow/shrink fabric worker processes from serve signals.

Two layers, split so the policy is a pure unit-testable object:

* :class:`AutoscalePolicy` — consumes one observation per evaluation
  period (front-door queue depth, windowed deadline misses and submit
  counts — the signals ``ServeMetrics.snapshot_window`` already
  produces) and answers grow/hold/shrink with hysteresis: pressure must
  persist for ``grow_windows`` consecutive windows before growing, and
  the fabric must be idle for ``shrink_windows`` consecutive windows
  before shrinking, so a single burst or a single quiet beat never
  flaps the fleet. Bounds are hard: never below ``min_workers``, never
  above ``max_workers``.

* :class:`ProcessScaler` — owns the worker subprocesses the front door
  spawned (and only those: externally launched workers are never
  killed). Scale-up spawns one worker from the command template;
  scale-down SIGTERMs the youngest spawned worker, which drains
  gracefully (finishes in-flight, resolves queued tickets as
  ``server_closed``, deregisters) before exiting.
"""

from __future__ import annotations

import dataclasses
import os
import signal
import subprocess
import sys
import threading
from typing import Dict, List, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class AutoscaleConfig:
    """Knobs (see docs/SERVING.md, "Autoscaler")."""

    min_workers: int = 1
    max_workers: int = 2
    # pressure: queued work per live server at/above which a window
    # counts as a breach; any windowed deadline miss is always a breach
    grow_queue_depth: float = 2.0
    grow_windows: int = 2  # consecutive breaches before growing
    shrink_windows: int = 4  # consecutive idle windows before shrinking
    eval_period_s: float = 0.5

    def validate(self) -> "AutoscaleConfig":
        if self.min_workers < 1:
            raise ValueError(
                f"min_workers must be >= 1, got {self.min_workers}"
            )
        if self.max_workers < self.min_workers:
            raise ValueError(
                f"max_workers ({self.max_workers}) must be >= "
                f"min_workers ({self.min_workers})"
            )
        if self.grow_windows < 1 or self.shrink_windows < 1:
            raise ValueError("grow_windows and shrink_windows must be >= 1")
        if self.eval_period_s <= 0:
            raise ValueError(
                f"eval_period_s must be > 0, got {self.eval_period_s}"
            )
        return self


class AutoscalePolicy:
    """Hysteresis-gated grow/hold/shrink decisions (pure)."""

    def __init__(self, cfg: AutoscaleConfig):
        self.cfg = cfg.validate()
        self._pressure_streak = 0
        self._idle_streak = 0

    def observe(
        self,
        *,
        workers: int,
        queue_depth: int,
        deadline_misses: int = 0,
        submitted: int = 0,
        inflight: int = 0,
    ) -> int:
        """One evaluation window -> +1 (grow), -1 (shrink) or 0.

        ``workers`` is the count the decision is bounded against (the
        processes the scaler owns, including ones still starting up —
        bounding against *registered* servers would spawn a second
        worker while the first is still importing jax).
        """
        per = queue_depth / max(1, workers)
        pressure = per >= self.cfg.grow_queue_depth or deadline_misses > 0
        idle = queue_depth == 0 and submitted == 0 and inflight == 0
        self._pressure_streak = self._pressure_streak + 1 if pressure else 0
        self._idle_streak = self._idle_streak + 1 if idle else 0
        if (
            self._pressure_streak >= self.cfg.grow_windows
            and workers < self.cfg.max_workers
        ):
            self._pressure_streak = 0
            self._idle_streak = 0
            return 1
        if (
            self._idle_streak >= self.cfg.shrink_windows
            and workers > self.cfg.min_workers
        ):
            self._idle_streak = 0
            self._pressure_streak = 0
            return -1
        return 0


class ProcessScaler:
    """Spawn/stop fabric worker processes for the front door.

    ``worker_args`` is everything after ``repro.launch.fabric worker``
    except ``--server-id`` (generated per spawn) — typically at least
    ``--frontdoor host:port``.
    """

    def __init__(
        self,
        worker_args: Sequence[str],
        env: Optional[Dict[str, str]] = None,
        id_prefix: str = "auto",
    ):
        self._worker_args = list(worker_args)
        self._env = dict(env) if env is not None else dict(os.environ)
        self._id_prefix = id_prefix
        self._lock = threading.Lock()
        self._procs: List[subprocess.Popen] = []
        self._spawned = 0

    def _reap_locked(self) -> None:
        self._procs = [p for p in self._procs if p.poll() is None]

    def count(self) -> int:
        """Live worker processes this scaler owns (spawned and not yet
        exited — a worker still importing jax counts)."""
        with self._lock:
            self._reap_locked()
            return len(self._procs)

    def scale_up(self) -> str:
        """Spawn one worker; returns its server id."""
        with self._lock:
            self._spawned += 1
            sid = f"{self._id_prefix}-{os.getpid()}-{self._spawned}"
            cmd = [
                sys.executable,
                "-m",
                "repro.launch.fabric",
                "worker",
                "--server-id",
                sid,
            ]
            cmd += self._worker_args
            proc = subprocess.Popen(
                cmd,
                env=self._env,
                stdout=subprocess.DEVNULL,
                stderr=subprocess.DEVNULL,
            )
            self._procs.append(proc)
            return sid

    def scale_down(self) -> Optional[int]:
        """SIGTERM the youngest spawned worker (graceful drain);
        returns its pid, or None when none are left."""
        with self._lock:
            self._reap_locked()
            if not self._procs:
                return None
            proc = self._procs[-1]
            try:
                proc.send_signal(signal.SIGTERM)
            except OSError:
                pass
            return proc.pid

    def close(self, timeout_s: float = 10.0) -> None:
        """SIGTERM every owned worker and wait for the drains."""
        with self._lock:
            procs = list(self._procs)
            self._procs = []
        for p in procs:
            if p.poll() is None:
                try:
                    p.send_signal(signal.SIGTERM)
                except OSError:
                    pass
        for p in procs:
            try:
                p.wait(timeout=timeout_s)
            except subprocess.TimeoutExpired:
                p.kill()
                p.wait(timeout=5.0)
