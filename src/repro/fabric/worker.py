"""Fabric worker: one ``PartitionServer`` process behind an RPC port.

A worker owns its own device state (its meshes, jit caches, graph
cache — on real clusters its own ``jax.distributed`` process slice via
``api.runtime.distributed_init``) and exposes the in-process serving
tier over the fabric protocol: ``partition`` ops map to
``PartitionServer.submit`` and stream back encoded ``ServeResult``
frames as they resolve. A heartbeat thread registers the worker with
the front door and renews its lease every few beats, attaching
``PartitionServer.metrics_window()`` — the health/pressure signal the
registry tracks.

Shutdown is graceful (the drain satellite): SIGTERM (or a ``drain``
op) stops admissions — new ``partition`` frames get an immediate
``server_closed`` result — lets in-flight attempts finish, resolves
still-queued tickets as ``server_closed`` (every admitted frame is
answered; a killed process no longer silently drops queued work),
deregisters from the front door, and exits.
"""

from __future__ import annotations

import json
import os
import signal
import socket
import threading
import time
from typing import Any, Dict, Optional, Tuple

from . import protocol
from .protocol import recv_msg, send_msg


class FabricWorker:
    """RPC shim over one in-process :class:`PartitionServer`.

    Parameters
    ----------
    frontdoor:
        ``(host, port)`` of the front door to register with, or None
        for a standalone worker (tests dial it directly).
    host, port:
        Bind address for the worker's own RPC listener (``port=0``
        picks an ephemeral port; read it back from ``self.port``).
    server:
        An already-built ``PartitionServer`` to serve (tests inject
        one); when None, one is constructed from ``meshes`` /
        ``devices_per_mesh`` / ``backend``.
    heartbeat_s:
        Lease-renewal cadence. Keep it a small fraction of the front
        door's lease TTL so one dropped beat doesn't expire the lease.
    """

    def __init__(
        self,
        frontdoor: Optional[Tuple[str, int]] = None,
        *,
        host: str = "127.0.0.1",
        port: int = 0,
        server_id: Optional[str] = None,
        meshes: int = 1,
        devices_per_mesh: int = 1,
        backend: Optional[str] = None,
        heartbeat_s: float = 1.0,
        server=None,
        max_queue: int = 1024,
    ):
        self.server_id = server_id or f"worker-{os.getpid()}"
        self._frontdoor = frontdoor
        self._heartbeat_s = heartbeat_s
        if server is None:
            from ..serve import PartitionServer

            server = PartitionServer(
                meshes=meshes,
                devices_per_mesh=devices_per_mesh,
                backend=backend,
                max_queue=max_queue,
            )
        self._server = server
        self.devices_per_mesh = getattr(server, "devices_per_mesh", 1)
        self.meshes = len(getattr(server, "workers", [])) or 1
        self._draining = threading.Event()
        self._drained = threading.Event()  # server closed, results sent
        self._done = threading.Event()
        self._drain_lock = threading.Lock()
        self._conns: set = set()
        self._conns_lock = threading.Lock()
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, port))
        self._listener.listen(64)
        self.host, self.port = self._listener.getsockname()[:2]
        self._accept_thread = threading.Thread(
            target=self._accept_loop,
            name="repro-fabric-accept",
            daemon=True,
        )
        self._accept_thread.start()
        self._hb_thread: Optional[threading.Thread] = None
        if frontdoor is not None:
            self._hb_thread = threading.Thread(
                target=self._heartbeat_loop,
                name="repro-fabric-heartbeat",
                daemon=True,
            )
            self._hb_thread.start()

    # -- RPC serving ---------------------------------------------------

    def _accept_loop(self) -> None:
        while not self._done.is_set():
            try:
                conn, _addr = self._listener.accept()
            except OSError:
                return  # listener closed by drain
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            with self._conns_lock:
                self._conns.add(conn)
            t = threading.Thread(
                target=self._conn_loop,
                args=(conn,),
                daemon=True,
            )
            t.start()

    def _conn_loop(self, conn: socket.socket) -> None:
        send_lock = threading.Lock()
        try:
            while True:
                msg = recv_msg(conn)
                if msg is None:
                    return
                self._handle(conn, send_lock, msg)
        except (OSError, protocol.ProtocolError, json.JSONDecodeError):
            return  # peer went away mid-frame; its futures die with it
        finally:
            with self._conns_lock:
                self._conns.discard(conn)
            try:
                conn.close()
            except OSError:
                pass

    def _handle(self, conn, send_lock, msg: Dict[str, Any]) -> None:
        op = msg.get("op")
        if op == "partition":
            self._handle_partition(conn, send_lock, msg)
        elif op in ("ping", "status"):
            resp = {
                "op": "pong",
                "server_id": self.server_id,
                "draining": self._draining.is_set(),
                "stats": self._server.stats(),
            }
            self._send(conn, send_lock, resp)
        elif op == "drain":
            resp = {"op": "draining", "server_id": self.server_id}
            self._send(conn, send_lock, resp)
            threading.Thread(target=self.drain, daemon=True).start()
        else:
            resp = {"op": "error", "detail": f"unknown op {op!r}"}
            self._send(conn, send_lock, resp)

    def _handle_partition(self, conn, send_lock, msg: Dict[str, Any]) -> None:
        rid = msg.get("id")

        def reply_error(code: str, detail: str) -> None:
            res = protocol.error_result(code, detail)
            frame = {"op": "result", "id": rid, "result": res}
            self._send(conn, send_lock, frame)

        if self._draining.is_set():
            reply_error(
                "server_closed", f"worker {self.server_id} is draining"
            )
            return
        try:
            req = protocol.decode_request(msg["request"])
            fut = self._server.submit(
                req,
                priority=int(msg.get("priority", 0)),
                deadline_s=msg.get("deadline_s"),
                timeout_s=msg.get("timeout_s"),
            )
        except protocol.ProtocolError as exc:  # bad frame is data
            reply_error("rejected", str(exc))
            return
        except RuntimeError as exc:  # server closed under us
            reply_error("server_closed", str(exc))
            return
        except Exception as exc:  # malformed request is data, not a crash
            reply_error("rejected", f"{type(exc).__name__}: {exc}")
            return

        def on_done(f) -> None:
            try:
                sr = f.result()
                wire = protocol.encode_serve_result(sr, self.server_id)
            except Exception as exc:
                wire = protocol.error_result(
                    "worker_failed", f"{type(exc).__name__}: {exc}"
                )
            frame = {"op": "result", "id": rid, "result": wire}
            self._send(conn, send_lock, frame)

        fut.add_done_callback(on_done)

    def _send(self, conn, send_lock, obj: Dict[str, Any]) -> None:
        try:
            with send_lock:
                send_msg(conn, obj)
        except OSError:
            pass  # peer gone; the front door re-routes on its side

    # -- heartbeats ----------------------------------------------------

    def _register_msg(self) -> Dict[str, Any]:
        server = {
            "server_id": self.server_id,
            "host": self.host,
            "port": self.port,
            "devices": self.devices_per_mesh,
            "meshes": self.meshes,
            "pid": os.getpid(),
        }
        return {"op": "register", "server": server}

    def _heartbeat_loop(self) -> None:
        """Register, then renew every beat; reconnect (and re-register)
        with backoff when the front door drops or restarts.

        A *draining* worker keeps its lease warm: deregistering early
        would make the front door orphan and fail over the very
        in-flight work the drain is finishing. The goodbye goes out
        only once ``_drained`` is set — every result frame has been
        sent by then, so the front door has nothing left to re-route.
        """
        backoff = 0.2
        while not self._done.is_set() and not self._drained.is_set():
            sock = None
            try:
                sock = protocol.connect(*self._frontdoor, timeout=5.0)
                send_msg(sock, self._register_msg())
                recv_msg(sock)  # lease ack
                backoff = 0.2
                while not self._drained.wait(self._heartbeat_s):
                    frame = {
                        "op": "renew",
                        "server_id": self.server_id,
                        "metrics": self._server.metrics_window(),
                    }
                    send_msg(sock, frame)
                    resp = recv_msg(sock)
                    if resp is None:
                        raise OSError("front door closed the connection")
                    if resp.get("op") == "unknown_server":
                        # our lease expired (e.g. a long GC pause or a
                        # front-door restart): re-register on the spot
                        send_msg(sock, self._register_msg())
                        recv_msg(sock)
                bye = {"op": "deregister", "server_id": self.server_id}
                send_msg(sock, bye)
                return
            except (OSError, protocol.ProtocolError):
                time.sleep(backoff)
                backoff = min(2.0, backoff * 2)
            finally:
                if sock is not None:
                    try:
                        sock.close()
                    except OSError:
                        pass

    # -- lifecycle -----------------------------------------------------

    def install_signal_handlers(self) -> None:
        """SIGTERM/SIGINT -> graceful drain (handler returns
        immediately; the drain runs on its own thread so in-flight jit
        programs finish off the signal stack)."""

        def _on_signal(signum, frame) -> None:
            threading.Thread(target=self.drain, daemon=True).start()

        signal.signal(signal.SIGTERM, _on_signal)
        signal.signal(signal.SIGINT, _on_signal)

    def drain(self) -> None:
        """Refuse new admissions, finish in-flight attempts, resolve
        still-queued tickets as ``server_closed`` (their result frames
        still flow back), deregister, then release ``wait()``."""
        with self._drain_lock:
            if self._draining.is_set():
                self._done.wait()
                return
            self._draining.set()
        # close(wait=True) resolves queued tickets with server_closed
        # and joins in-flight attempts; every resolution fires its
        # done-callback, which sends the result frame before we close
        # the connections below
        self._server.close(wait=True)
        self._drained.set()  # heartbeat thread now deregisters and exits
        try:
            self._listener.close()
        except OSError:
            pass
        if self._hb_thread is not None:
            self._hb_thread.join(timeout=2 * self._heartbeat_s + 5.0)
        with self._conns_lock:
            conns = list(self._conns)
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                c.close()
            except OSError:
                pass
        self._done.set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until a drain completes (the worker main loop)."""
        return self._done.wait(timeout)

    def __enter__(self) -> "FabricWorker":
        return self

    def __exit__(self, *exc) -> None:
        self.drain()
