"""Cross-process serving fabric — the tier above ``repro.serve``.

A :class:`FrontDoor` admits partition requests over a lightweight RPC
protocol (length-prefixed JSON over TCP, standard library only) and
routes them to registered :class:`FabricWorker` processes — each one a
whole ``PartitionServer`` with its own meshes, jit caches and (on real
clusters) its own ``jax.distributed`` process slice. Workers keep a
heartbeat lease warm in the front door's :class:`ServerRegistry`; a
killed worker's in-flight requests fail over to the survivors with the
same structured-error contract as the in-process tier, and an optional
autoscaler grows/shrinks the fleet from queue pressure:

    from repro.fabric import FrontDoor, FabricWorker, FabricClient

    fd = FrontDoor(port=0)
    w = FabricWorker(frontdoor=(fd.host, fd.port), meshes=2)
    with FabricClient(fd.host, fd.port) as c:
        res = c.submit(request).result()  # FabricResult
        res.ok, res.assignment, res.server

Results are bit-identical to solo ``repro.api.Partitioner.run`` for
the same request. See docs/SERVING.md ("Fabric") and
``repro.launch.fabric`` for the CLI.

Exports resolve lazily (PEP 562) so importing ``repro.fabric`` never
initializes a jax backend — the front door and client own no devices;
only worker processes ever run a partition.
"""

from importlib import import_module

_EXPORTS = {
    "FrontDoor": ".frontdoor",
    "FabricWorker": ".worker",
    "FabricClient": ".client",
    "status_of": ".client",
    "FabricResult": ".protocol",
    "ServerRegistry": ".registry",
    "ServerRecord": ".registry",
    "AutoscaleConfig": ".autoscaler",
    "AutoscalePolicy": ".autoscaler",
    "ProcessScaler": ".autoscaler",
    "pick_server": ".frontdoor",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    try:
        mod = _EXPORTS[name]
    except KeyError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}"
        ) from None
    return getattr(import_module(mod, __name__), name)


def __dir__():
    return __all__
