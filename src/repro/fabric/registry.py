"""Server-location registry with heartbeat leases.

The front door's source of truth for which ``PartitionServer``
processes are alive (the saxml ``location.go`` idea: servers announce
themselves and keep a lease warm; consumers only ever see the live
set). A worker ``register``s its address and shape, then ``renew``s its
lease every heartbeat, attaching a windowed ``ServeMetrics`` snapshot —
the health/pressure signal the autoscaler and the routing policy read.
A lease that misses renewals for ``ttl_s`` expires; the front door
treats expiry exactly like a dead connection (re-route-and-retry, PR 5
failover semantics).

Pure bookkeeping: no sockets, injectable clock, fully unit-testable.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional


@dataclasses.dataclass
class ServerRecord:
    """One registered ``PartitionServer`` process."""

    server_id: str
    host: str
    port: int
    devices: int = 1  # devices per worker mesh (routing fit)
    meshes: int = 1  # worker meshes -> concurrent capacity
    pid: Optional[int] = None
    lease_expiry: float = 0.0  # clock() time the lease lapses
    registered_t: float = 0.0
    renewals: int = 0
    generation: int = 0  # bumps when the same id re-registers
    metrics: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def summary(self) -> Dict[str, Any]:
        out = dataclasses.asdict(self)
        out.pop("metrics", None)
        out["queue_depth"] = self.metrics.get("queue_depth_last", 0)
        out["expired_misses"] = self.metrics.get("expired", 0)
        # attempts running on the server's own meshes right now — lags
        # one heartbeat behind the front door's dispatch-side inflight
        out["worker_inflight"] = self.metrics.get("inflight", 0)
        return out


class ServerRegistry:
    """Thread-safe lease table keyed by server id.

    ``ttl_s`` is the lease length granted at register/renew time;
    workers heartbeat a few times per TTL so one dropped heartbeat
    doesn't flap the server out of rotation.
    """

    def __init__(
        self,
        ttl_s: float = 5.0,
        clock: Callable[[], float] = time.monotonic,
    ):
        if ttl_s <= 0:
            raise ValueError(f"ttl_s must be > 0, got {ttl_s}")
        self.ttl_s = ttl_s
        self._clock = clock
        self._lock = threading.Lock()
        self._records: Dict[str, ServerRecord] = {}

    # -- lease lifecycle -----------------------------------------------

    def register(
        self,
        server_id: str,
        host: str,
        port: int,
        *,
        devices: int = 1,
        meshes: int = 1,
        pid: Optional[int] = None,
    ) -> ServerRecord:
        """Admit (or re-admit) a server; returns the new record (its
        lease runs ``ttl_s`` from now).

        Re-registering an existing id replaces the record and bumps its
        ``generation`` — the restart marker the front door uses to drop
        state (connections, inflight counts) tied to the old process.
        """
        if not server_id:
            raise ValueError("server_id must be a non-empty string")
        now = self._clock()
        with self._lock:
            old = self._records.get(server_id)
            rec = ServerRecord(
                server_id=server_id,
                host=host,
                port=int(port),
                devices=int(devices),
                meshes=int(meshes),
                pid=pid,
                lease_expiry=now + self.ttl_s,
                registered_t=now,
                generation=(old.generation + 1) if old else 0,
            )
            self._records[server_id] = rec
        return rec

    def renew(
        self,
        server_id: str,
        metrics: Optional[Dict[str, Any]] = None,
    ) -> bool:
        """Extend a live lease; False when the id is unknown or already
        expired — the worker's cue to re-register (its old record may
        have been expired and its tickets already re-routed)."""
        now = self._clock()
        with self._lock:
            rec = self._records.get(server_id)
            if rec is None or rec.lease_expiry <= now:
                return False
            rec.lease_expiry = now + self.ttl_s
            rec.renewals += 1
            if metrics is not None:
                rec.metrics = dict(metrics)
            return True

    def deregister(self, server_id: str) -> Optional[ServerRecord]:
        """Graceful exit (drain finished) — no failover needed."""
        with self._lock:
            return self._records.pop(server_id, None)

    def expire(self, now: Optional[float] = None) -> List[ServerRecord]:
        """Remove and return every record whose lease has lapsed. The
        front door calls this on a timer and fails the dead servers'
        in-flight tickets over, exactly like a dropped connection."""
        now = self._clock() if now is None else now
        with self._lock:
            dead = [
                r for r in self._records.values() if r.lease_expiry <= now
            ]
            for r in dead:
                del self._records[r.server_id]
            return dead

    # -- reading -------------------------------------------------------

    def alive(self) -> List[ServerRecord]:
        """Live records (leases still warm), stable id order. Does not
        expire — the owner's expiry sweep does that, so the failover
        path runs in exactly one place."""
        now = self._clock()
        with self._lock:
            recs = sorted(self._records.items())
            return [r for _, r in recs if r.lease_expiry > now]

    def get(self, server_id: str) -> Optional[ServerRecord]:
        with self._lock:
            return self._records.get(server_id)

    def __len__(self) -> int:
        return len(self.alive())

    def snapshot(self) -> List[Dict[str, Any]]:
        """JSON-safe view of the live set (the ``status`` op payload)."""
        return [r.summary() for r in self.alive()]
