"""Wire protocol for the cross-process serving fabric.

Length-prefixed JSON over TCP — a 4-byte big-endian length header
followed by a UTF-8 JSON object. No external dependencies beyond
numpy: framing and codecs are standard library, and nothing here can
initialize a jax backend — the front door routes without owning
devices.

Every frame is one JSON object carrying an ``"op"`` key:

  client -> front door   ``partition`` / ``status``
  front door -> client   ``result`` / ``status``
  worker -> front door   ``register`` / ``renew`` / ``deregister``
  front door -> worker   ``lease`` / ``unknown_server`` (heartbeats),
                         ``partition`` / ``drain`` (work connection)
  worker -> front door   ``result`` (work connection)

``PartitionRequest`` objects cross the wire losslessly:
``GraphSpec`` graphs as their (hashable) fields, in-memory ``Graph``
objects as base64-encoded raw arrays — so fabric results stay
bit-identical to solo ``Partitioner.run`` on the same request.
Assignments come back the same way (dtype + shape + base64 payload).
"""

from __future__ import annotations

import base64
import dataclasses
import json
import socket
import struct
from typing import Any, Dict, Optional

import numpy as np

MAX_FRAME = 1 << 30  # 1 GiB — sanity bound, not a protocol limit

# structured error the client synthesizes when a connection dies with
# requests still outstanding (the fabric analogue of a lost worker)
ERR_CONNECTION = "connection_lost"


class ProtocolError(RuntimeError):
    """A malformed or truncated frame."""


# ---------------------------------------------------------------------------
# framing
# ---------------------------------------------------------------------------


def send_msg(sock: socket.socket, obj: Dict[str, Any]) -> None:
    """Send one frame (atomic via a single ``sendall``)."""
    data = json.dumps(obj, separators=(",", ":")).encode("utf-8")
    if len(data) > MAX_FRAME:
        raise ProtocolError(f"frame of {len(data)} bytes exceeds MAX_FRAME")
    sock.sendall(struct.pack(">I", len(data)) + data)


def recv_msg(sock: socket.socket) -> Optional[Dict[str, Any]]:
    """Receive one frame; None on clean EOF at a frame boundary."""
    header = _recv_exact(sock, 4)
    if header is None:
        return None
    (length,) = struct.unpack(">I", header)
    if length > MAX_FRAME:
        raise ProtocolError(f"frame of {length} bytes exceeds MAX_FRAME")
    data = _recv_exact(sock, length)
    if data is None:
        raise ProtocolError("connection closed mid-frame")
    return json.loads(data.decode("utf-8"))


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    """Exactly ``n`` bytes; None on EOF before the first byte (a clean
    close at a frame boundary), ProtocolError on EOF mid-read."""
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            if not buf:
                return None
            raise ProtocolError("connection closed mid-frame")
        buf.extend(chunk)
    return bytes(buf)


def connect(
    host: str,
    port: int,
    timeout: Optional[float] = None,
) -> socket.socket:
    """Dial a fabric endpoint (TCP_NODELAY — frames are small and
    latency-sensitive; the payload b64 dominates large ones anyway)."""
    sock = socket.create_connection((host, port), timeout=timeout)
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    return sock


# ---------------------------------------------------------------------------
# array / request / result codecs
# ---------------------------------------------------------------------------


def encode_array(a: np.ndarray) -> Dict[str, Any]:
    a = np.ascontiguousarray(a)
    return {
        "b64": base64.b64encode(a.tobytes()).decode("ascii"),
        "dtype": str(a.dtype),
        "shape": list(a.shape),
    }


def decode_array(d: Dict[str, Any]) -> np.ndarray:
    raw = base64.b64decode(d["b64"])
    arr = np.frombuffer(raw, dtype=np.dtype(d["dtype"]))
    return arr.reshape(d["shape"]).copy()


def encode_request(req) -> Dict[str, Any]:
    """``PartitionRequest`` -> wire dict (lossless)."""
    from ..api.request import GraphSpec

    g = req.graph
    if isinstance(g, GraphSpec):
        graph = {
            "kind": "spec",
            "family": g.family,
            "n": g.n,
            "avg_deg": g.avg_deg,
            "seed": g.seed,
        }
    else:
        graph = {
            "kind": "graph",
            "indptr": encode_array(g.indptr),
            "adjncy": encode_array(g.adjncy),
            "eweights": encode_array(g.eweights),
            "vweights": encode_array(g.vweights),
        }
    cfg = None if req.config is None else dataclasses.asdict(req.config)
    return {
        "graph": graph,
        "k": req.k,
        "epsilon": req.epsilon,
        "preset": req.preset,
        "config": cfg,
        "seed": req.seed,
        "backend": req.backend,
        "devices": req.devices,
        "collect_trace": req.collect_trace,
        "contraction": req.contraction,
        "weights": req.weights,
        "balance": req.balance,
        "kernel": req.kernel,
        "refine": req.refine,
        "quality": req.quality,
    }


def decode_request(d: Dict[str, Any]):
    """Wire dict -> ``PartitionRequest`` (validated by the caller)."""
    from ..core.deep_mgp import PartitionerConfig
    from ..graphs.format import Graph
    from ..api.request import GraphSpec, PartitionRequest

    g = d["graph"]
    if g["kind"] == "spec":
        graph = GraphSpec(
            family=g["family"],
            n=int(g["n"]),
            avg_deg=float(g["avg_deg"]),
            seed=int(g["seed"]),
        )
    elif g["kind"] == "graph":
        graph = Graph(
            indptr=decode_array(g["indptr"]),
            adjncy=decode_array(g["adjncy"]),
            eweights=decode_array(g["eweights"]),
            vweights=decode_array(g["vweights"]),
        )
    else:
        raise ProtocolError(f"unknown graph kind {g.get('kind')!r}")
    cfg = d.get("config")
    return PartitionRequest(
        graph=graph,
        k=int(d["k"]),
        epsilon=float(d["epsilon"]),
        preset=d["preset"],
        config=None if cfg is None else PartitionerConfig(**cfg),
        seed=int(d["seed"]),
        backend=d["backend"],
        devices=int(d["devices"]),
        collect_trace=bool(d["collect_trace"]),
        contraction=d.get("contraction"),
        weights=d.get("weights"),
        balance=d.get("balance"),
        kernel=d.get("kernel"),
        refine=d.get("refine"),
        quality=d.get("quality"),
    )


def _jsonable(x):
    """Recursively strip numpy scalar types out of a metrics dict."""
    if isinstance(x, dict):
        return {k: _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if isinstance(x, np.bool_):
        return bool(x)
    if isinstance(x, np.integer):
        return int(x)
    if isinstance(x, np.floating):
        return float(x)
    return x


def encode_serve_result(sr, server_id: Optional[str] = None) -> Dict[str, Any]:
    """``repro.serve.ServeResult`` -> wire dict, carrying the assignment
    so clients can assert bit-identity against solo runs."""
    out: Dict[str, Any] = {
        "ok": bool(sr.ok),
        "error": sr.error,
        "detail": sr.detail,
        "server": server_id,
        "worker": sr.worker,
        "attempts": int(sr.attempts),
        "priority": int(sr.priority),
        "queue_wait_s": float(sr.queue_wait_s),
        "total_s": float(sr.total_s),
    }
    if sr.ok and sr.result is not None:
        r = sr.result
        out.update(
            {
                "assignment": encode_array(r.assignment),
                "cut": int(r.cut),
                "feasible": bool(r.feasible),
                "backend": r.backend,
                "time_s": float(r.time_s),
                "metrics": _jsonable(r.metrics),
            }
        )
    return out


@dataclasses.dataclass(frozen=True)
class FabricResult:
    """Client-side view of one fabric response — the cross-process
    analogue of ``ServeResult`` (errors are data, never exceptions)."""

    ok: bool
    error: Optional[str]
    detail: str
    server: Optional[str]  # server id that produced the result
    worker: Optional[int]  # mesh worker inside that server
    attempts: int  # front-door level attempts (servers tried)
    assignment: Optional[np.ndarray] = None
    cut: Optional[int] = None
    feasible: Optional[bool] = None
    backend: Optional[str] = None
    time_s: float = 0.0
    metrics: Optional[Dict[str, Any]] = None

    def summary(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "ok": self.ok,
            "server": self.server,
            "attempts": self.attempts,
        }
        if self.ok:
            out.update(
                {
                    "cut": self.cut,
                    "feasible": self.feasible,
                    "backend": self.backend,
                    "time_s": round(self.time_s, 4),
                }
            )
        else:
            out.update({"error": self.error, "detail": self.detail})
        return out


def decode_result(d: Dict[str, Any]) -> FabricResult:
    asg = d.get("assignment")
    return FabricResult(
        ok=bool(d["ok"]),
        error=d.get("error"),
        detail=d.get("detail", ""),
        server=d.get("server"),
        worker=d.get("worker"),
        attempts=int(d.get("attempts", 0)),
        assignment=None if asg is None else decode_array(asg),
        cut=d.get("cut"),
        feasible=d.get("feasible"),
        backend=d.get("backend"),
        time_s=float(d.get("time_s", 0.0)),
        metrics=d.get("metrics"),
    )


def error_result(code: str, detail: str, attempts: int = 0) -> Dict[str, Any]:
    """Wire dict for a front-door-synthesized structured error."""
    return {
        "ok": False,
        "error": code,
        "detail": detail,
        "server": None,
        "worker": None,
        "attempts": attempts,
        "priority": 0,
        "queue_wait_s": 0.0,
        "total_s": 0.0,
    }
