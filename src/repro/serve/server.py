"""Multi-mesh partition server: queued serving over device-mesh workers.

``PartitionServer`` is the traffic-shaped layer above the PR 2 facade
(saxml-style: an admission queue feeding several independent device
groups). It owns N *workers*, each bound to a disjoint slice of the
host's devices wrapped in its own ``PartitionSession`` (one mesh, one
ShardCtx, one jit cache per worker); a priority admission queue with
per-request deadlines; a dispatcher that routes each request to the
best-fitting mesh (``serve.scheduler``, reusing the ``auto`` policy's
``required_devices``); a ``GraphSpec`` cache shared across all workers;
and supervision — a failed or timed-out attempt is retried once on
another mesh, then surfaced as a structured :class:`ServeResult` error.

Results are bit-identical to solo ``Partitioner.run`` for the same
request: workers run the unmodified facade, and every request is a pure
function of its fields regardless of which device slice executes it.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future
from concurrent.futures import TimeoutError as _FutureTimeout
from queue import SimpleQueue
from typing import Any, Dict, Iterable, List, Optional

from ..api.backends import required_devices
from ..api.session import BucketCache, PartitionSession
from .metrics import ServeMetrics
from .queue import AdmissionQueue, Ticket
from .scheduler import pick_worker

_STOP = object()  # worker-inbox sentinel

# structured error codes a ServeResult can carry
ERR_DEADLINE = "deadline_exceeded"
ERR_WORKER = "worker_failed"
ERR_NO_WORKER = "no_worker"
ERR_REJECTED = "rejected"
ERR_CLOSED = "server_closed"


@dataclasses.dataclass(frozen=True)
class ServeResult:
    """Outcome of one served request: a ``PartitionResult`` on success,
    a structured error otherwise — queue failures are *data*, never
    exceptions leaking out of worker threads.
    """

    ok: bool
    result: Optional[object]  # PartitionResult when ok
    error: Optional[str]  # ERR_* code when not ok
    detail: str = ""
    worker: Optional[int] = None  # worker that produced the result
    attempts: int = 0  # run attempts consumed
    priority: int = 0
    queue_wait_s: float = 0.0  # admission -> first dispatch
    total_s: float = 0.0  # admission -> resolution

    def summary(self) -> Dict[str, Any]:
        """JSON-serializable one-liner (no assignment array)."""
        out: Dict[str, Any] = {
            "ok": self.ok,
            "worker": self.worker,
            "attempts": self.attempts,
            "priority": self.priority,
            "queue_wait_s": self.queue_wait_s,
            "total_s": self.total_s,
        }
        if self.ok and self.result is not None:
            out["cut"] = self.result.cut
            out["feasible"] = self.result.feasible
            out["backend"] = self.result.backend
        else:
            out["error"] = self.error
            out["detail"] = self.detail
        return out


class _Worker:
    """One mesh worker: a dedicated single-thread ``PartitionSession``
    (the executor) plus a supervisor loop (this thread) that enforces
    per-attempt timeouts and reports failures back to the server.

    ``hold()`` / ``release()`` gate the loop before each attempt — the
    supervision hook the selftest uses to kill a worker while it
    provably still owns a request.
    """

    def __init__(
        self,
        wid: int,
        devices: int,
        mesh,
        backend: Optional[str],
        server: "PartitionServer",
    ):
        self.wid = wid
        self.devices = devices
        self.mesh = mesh
        self.alive = True
        self.inflight = 0  # guarded by server._cap_cond
        self.session = PartitionSession(
            devices=devices,
            backend=backend,
            max_workers=1,
            mesh=mesh,
            graph_cache=server._graph_cache,
            graph_cache_lock=server._graph_cache_lock,
            stack=server._stack,
        )
        self.inbox: SimpleQueue = SimpleQueue()
        self._gate = threading.Event()
        self._gate.set()
        self._abandoned: Optional[Future] = None
        self._server = server
        self.thread = threading.Thread(
            target=self._loop,
            name=f"repro-serve-w{wid}",
            daemon=True,
        )

    def start(self) -> None:
        self.thread.start()

    def hold(self) -> None:
        self._gate.clear()

    def release(self) -> None:
        self._gate.set()

    @property
    def shard_ctx(self):
        return self.session.shard_ctx

    def _loop(self) -> None:
        while True:
            item = self.inbox.get()  # a List[Ticket] batch, or _STOP
            if item is _STOP:
                break
            try:
                if len(item) == 1:
                    self._serve_solo(item[0])
                else:
                    self._serve_batch(item)
            finally:
                self._server._attempt_finished(self)

    def _serve_solo(self, ticket: Ticket) -> None:
        srv = self._server
        self._gate.wait()
        if srv._closing.is_set():
            srv._resolve_error(
                ticket, ERR_CLOSED, "server closed before the attempt"
            )
            return
        if not self.alive:
            srv._attempt_failed(
                ticket, self.wid, "worker killed before the attempt"
            )
            return
        now = time.monotonic()
        if ticket.expired(now):
            srv._resolve_error(
                ticket,
                ERR_DEADLINE,
                f"deadline passed before the attempt on worker {self.wid}",
            )
            return
        timeout = ticket.timeout_s
        rem = ticket.remaining(now)
        deadline_bound = False
        if rem is not None and (timeout is None or rem < timeout):
            # the request's own deadline is the binding constraint: if
            # it fires, the *request* ran out of time — the worker is
            # slow for this job, not wedged, and must stay in rotation
            timeout = rem
            deadline_bound = True
        if not self._drain_abandoned([ticket], timeout):
            return
        fut = self.session.submit(ticket.request)
        try:
            res = fut.result(timeout=timeout)
        except _FutureTimeout:
            if deadline_bound:
                self._abandoned = fut
                srv._resolve_error(
                    ticket,
                    ERR_DEADLINE,
                    f"deadline passed mid-attempt on worker {self.wid}",
                )
                return
            # a timeout_s overrun means the session's executor thread
            # is wedged; take this worker out of rotation and fail over
            self.alive = False
            srv._attempt_failed(
                ticket,
                self.wid,
                f"attempt timed out after {timeout:.3f}s"
                " (worker marked dead)",
            )
            return
        except Exception as exc:  # any failure must become data
            srv._attempt_failed(
                ticket, self.wid, f"{type(exc).__name__}: {exc}"
            )
            return
        srv._resolve_ok(ticket, res, self.wid)

    def _serve_batch(self, tickets: List[Ticket]) -> None:
        """One batched attempt: every ticket shares one submit_many
        future (coalescing + optional stacked level-0 happen inside the
        session), each resolving to its own bit-identical result."""
        srv = self._server
        self._gate.wait()
        if srv._closing.is_set():
            for t in tickets:
                srv._resolve_error(
                    t, ERR_CLOSED, "server closed before the attempt"
                )
            return
        if not self.alive:
            for t in tickets:
                srv._attempt_failed(
                    t, self.wid, "worker killed before the attempt"
                )
            return
        now = time.monotonic()
        live = []
        for t in tickets:
            if t.expired(now):
                srv._resolve_error(
                    t,
                    ERR_DEADLINE,
                    f"deadline passed before the attempt on worker "
                    f"{self.wid}",
                )
            else:
                live.append(t)
        if not live:
            return
        if len(live) == 1:
            # fall back to the solo path and its exact attempt semantics
            return self._serve_solo(live[0])
        # the batch attempt's bound is the loosest member budget (None
        # when any member is unbounded). A timeout only counts as a
        # wedged-worker signal when some member's own timeout_s was the
        # binding constraint; all-deadline-bound overruns abandon the
        # attempt and keep the worker in rotation, as in the solo path.
        bounds: List[float] = []
        unbounded = False
        deadline_bound = True
        for t in live:
            rem = t.remaining(now)
            to = t.timeout_s
            if rem is not None and (to is None or rem < to):
                bounds.append(rem)
            elif to is not None:
                bounds.append(to)
                deadline_bound = False
            else:
                unbounded = True
        timeout = None if unbounded else max(bounds)
        if not self._drain_abandoned(live, timeout):
            return
        fut = self.session.submit_many([t.request for t in live])
        try:
            results = fut.result(timeout=timeout)
        except _FutureTimeout:
            if deadline_bound:
                self._abandoned = fut
                for t in live:
                    srv._resolve_error(
                        t,
                        ERR_DEADLINE,
                        f"deadline passed mid-attempt on worker {self.wid}",
                    )
                return
            self.alive = False
            for t in live:
                srv._attempt_failed(
                    t,
                    self.wid,
                    f"attempt timed out after {timeout:.3f}s"
                    " (worker marked dead)",
                )
            return
        except Exception as exc:  # any failure must become data
            for t in live:
                srv._attempt_failed(
                    t, self.wid, f"{type(exc).__name__}: {exc}"
                )
            return
        from .batching import distinct_count

        srv._metrics.on_batch(
            len(live), distinct_count([t.request for t in live])
        )
        now = time.monotonic()
        for t, res in zip(live, results):
            if t.expired(now):
                # the batch outlived this member's deadline: the solo
                # contract (a result only counts inside the deadline)
                # wins over the computed-anyway result
                srv._resolve_error(
                    t,
                    ERR_DEADLINE,
                    f"deadline passed mid-attempt on worker {self.wid}",
                )
            else:
                srv._resolve_ok(t, res, self.wid)

    def _drain_abandoned(self, tickets: List[Ticket], budget) -> bool:
        """A deadline-abandoned attempt keeps the session's executor
        thread busy after its ticket resolved. Its runtime is *this
        worker's backlog*, not the next attempt's cost — so drain it
        before starting (and timing) a fresh attempt. If the drain
        exceeds the new tickets' budget the mesh simply can't take the
        job in time: fail over WITHOUT marking the worker dead (the
        executor is making progress on real work, not wedged). Returns
        False when the tickets were already resolved/failed over."""
        if self._abandoned is None:
            return True
        try:
            self._abandoned.result(timeout=budget)
        except _FutureTimeout:
            for t in tickets:
                self._server._attempt_failed(
                    t,
                    self.wid,
                    "worker busy draining a deadline-abandoned attempt",
                )
            return False
        except Exception:
            pass  # the abandoned job failed; the executor is free
        self._abandoned = None
        return True


class PartitionServer:
    """Queued multi-mesh serving tier over the ``repro.api`` facade.

    Parameters
    ----------
    meshes:
        Number of worker meshes. With ``devices_per_mesh > 1`` the
        host's devices are carved into that many *disjoint* contiguous
        slices (``api.runtime.device_slices``; raises when the host is
        too small). With ``devices_per_mesh == 1`` workers are meshless
        single-device sessions — any host, no carving.
    devices_per_mesh:
        PE count of every worker mesh. Requests whose resolved backend
        wants exactly this many PEs reuse the worker's shared mesh;
        anything else still runs correctly, as a solo run would.
    backend:
        Optional registry name replacing each request's ``"auto"``.
    max_queue:
        Admission-queue capacity; submissions beyond it resolve to a
        structured ``rejected`` error instead of blocking the caller.
    max_retries:
        Failed/timed-out attempts per request before the error is
        surfaced (default 1: one retry on a *different* mesh).
    max_inflight_per_worker:
        Attempts a worker may own at once (assigned + running). The
        default of 1 keeps requests in the priority queue — where
        scheduling decisions are still possible — rather than in
        per-worker inboxes. A batch counts as one attempt.
    batch_max:
        Most tickets one dispatch may serve as a single batched attempt
        (same shape bucket, see ``serve.batching``); 1 disables
        batching entirely.
    batch_window_ms:
        How long the dispatcher lingers for same-bucket stragglers once
        a batch leader popped and fewer than ``batch_max`` companions
        are queued. Small on purpose: the window trades that much p50
        latency for batch fill under bursty admission.
    graph_cache_size:
        LRU bound of the server-shared ``GraphSpec -> Graph`` cache
        (bounded so diverse long-lived traffic cannot leak memory).
    stack:
        Stacked level-0 execution knob threaded to every worker session
        (``"auto"`` | ``"on"`` | ``"off"``, see ``serve.batching``).
    """

    def __init__(
        self,
        meshes: int = 2,
        devices_per_mesh: int = 1,
        backend: Optional[str] = None,
        max_queue: int = 1024,
        max_retries: int = 1,
        max_inflight_per_worker: int = 1,
        batch_max: int = 8,
        batch_window_ms: float = 2.0,
        graph_cache_size: int = 64,
        stack: str = "auto",
    ):
        if meshes < 1:
            raise ValueError(f"meshes must be >= 1, got {meshes}")
        if devices_per_mesh < 1:
            raise ValueError(
                f"devices_per_mesh must be >= 1, got {devices_per_mesh}"
            )
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        if max_inflight_per_worker < 1:
            raise ValueError(
                "max_inflight_per_worker must be >= 1, got "
                f"{max_inflight_per_worker}"
            )
        if batch_max < 1:
            raise ValueError(f"batch_max must be >= 1, got {batch_max}")
        if batch_window_ms < 0:
            raise ValueError(
                f"batch_window_ms must be >= 0, got {batch_window_ms}"
            )
        self.devices_per_mesh = devices_per_mesh
        self._backend = backend
        self._max_retries = max_retries
        self._max_inflight = max_inflight_per_worker
        self._batch_max = batch_max
        self._batch_window_s = batch_window_ms / 1000.0
        self._stack = stack
        self._graph_cache = BucketCache(graph_cache_size)
        self._graph_cache_lock = threading.Lock()
        if devices_per_mesh > 1:
            # disjoint contiguous device slices, one 1D 'pe' mesh each
            import numpy as np
            from jax.sharding import Mesh

            from ..api.runtime import device_slices

            slices = device_slices(meshes, devices_per_mesh)
            mesh_objs = [Mesh(np.array(s), ("pe",)) for s in slices]
        else:
            mesh_objs = [None] * meshes
        self._workers = [
            _Worker(i, devices_per_mesh, mesh_objs[i], backend, self)
            for i in range(meshes)
        ]
        self._queue = AdmissionQueue(capacity=max_queue)
        self._metrics = ServeMetrics(meshes)
        self._cap_cond = threading.Condition()
        self._seq_lock = threading.Lock()
        self._seq = 0
        self._closing = threading.Event()
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop,
            name="repro-serve-dispatch",
            daemon=True,
        )
        for w in self._workers:
            w.start()
        self._dispatcher.start()

    # -- admission -----------------------------------------------------

    def submit(
        self,
        request: Any,
        *,
        priority: int = 0,
        deadline_s: Optional[float] = None,
        timeout_s: Optional[float] = None,
    ) -> "Future[ServeResult]":
        """Admit one request; returns a future resolving to a
        :class:`ServeResult` (admission overload resolves it
        immediately with a ``rejected`` error). Lower ``priority``
        dispatches first; ``deadline_s``/``timeout_s`` are relative
        seconds from now (see :class:`Ticket`)."""
        if self._closing.is_set():
            raise RuntimeError("server is closed")
        request.validate()
        # quality routing (docs/SERVING.md): a deadline-bearing ticket
        # that asked for quality="best" is downgraded to the fast tier
        # at admission — the unconstrained refinement spends extra
        # wall time on cut quality that a deadline-tight caller cannot
        # use. Deterministic (pure function of the submit arguments),
        # and an explicit refine= override is always honored.
        if deadline_s is not None and getattr(request, "quality", None) \
                == "best" and getattr(request, "refine", None) is None:
            request = dataclasses.replace(request, quality="fast")
            self._metrics.on_downgrade()
        # route on the backend that will actually run: the server-level
        # override replaces "auto" exactly as the worker sessions do.
        # Graph and GraphSpec both expose n — no materialization here.
        eff = request
        if self._backend is not None and request.backend == "auto":
            eff = dataclasses.replace(request, backend=self._backend)
        need = required_devices(eff, request.graph.n)
        bucket = None
        if self._batch_max > 1 and need == 1:
            from .batching import bucket_of

            bucket = bucket_of(eff)
        now = time.monotonic()
        fut: "Future[ServeResult]" = Future()
        with self._seq_lock:
            seq = self._seq
            self._seq += 1
        ticket = Ticket(
            request=request,
            priority=priority,
            seq=seq,
            future=fut,
            submit_t=now,
            deadline=None if deadline_s is None else now + deadline_s,
            timeout_s=timeout_s,
            need=need,
            bucket=bucket,
        )
        if not self._queue.put(ticket):
            if self._closing.is_set():
                # lost the race against close(): the queue refused the
                # ticket because it is closed, not because it is full
                fut.set_result(
                    ServeResult(
                        ok=False,
                        result=None,
                        error=ERR_CLOSED,
                        detail="server closed during submit",
                        priority=priority,
                    )
                )
                return fut
            self._metrics.on_reject()
            cap = self._queue.capacity
            fut.set_result(
                ServeResult(
                    ok=False,
                    result=None,
                    error=ERR_REJECTED,
                    detail=f"admission queue full (capacity {cap})",
                    priority=priority,
                )
            )
            return fut
        self._metrics.on_submit(self._queue.depth())
        with self._cap_cond:
            self._cap_cond.notify_all()
        return fut

    def serve(self, requests: Iterable, **submit_kw) -> List[ServeResult]:
        """Admit a batch and block for all results, in request order."""
        futures = [self.submit(r, **submit_kw) for r in requests]
        return [f.result() for f in futures]

    # -- dispatch ------------------------------------------------------

    def _dispatch_loop(self) -> None:
        # the dispatcher never blocks on a single ticket: each pass
        # pops the best ticket that *some free eligible mesh* can take
        # right now (pop_matching), so a retried ticket whose only
        # remaining mesh is busy cannot head-of-line block work that
        # an idle mesh could serve
        while not self._closing.is_set():
            if not self._dispatch_once():
                with self._cap_cond:
                    self._cap_cond.wait(0.05)

    def _dispatch_once(self) -> bool:
        """One dispatch action; False when there is nothing to do."""
        # deadlines first: an expired ticket resolves without a mesh
        ticket = self._queue.pop_matching(Ticket.expired)
        if ticket is not None:
            self._metrics.on_dispatch(self._queue.depth())
            self._resolve_error(ticket, ERR_DEADLINE, "expired in queue")
            return True
        with self._cap_cond:
            alive = {w.wid for w in self._workers if w.alive}
            free = {
                w.wid
                for w in self._workers
                if w.alive and w.inflight < self._max_inflight
            }
        # tickets whose every eligible mesh is dead can never be served
        ticket = self._queue.pop_matching(lambda t: not (alive - t.excluded))
        if ticket is not None:
            detail = "; ".join(ticket.errors) or "no live worker"
            self._resolve_error(ticket, ERR_NO_WORKER, detail)
            return True
        if not free:
            return False
        ticket = self._queue.pop_matching(lambda t: bool(free - t.excluded))
        if ticket is None:
            return False
        self._metrics.on_dispatch(self._queue.depth())
        if ticket.dispatch_t is None:
            ticket.dispatch_t = time.monotonic()
        batch = [ticket]
        if ticket.bucket is not None and self._batch_max > 1:
            batch += self._collect_batch(ticket)
        self._assign_now(batch)
        return True

    def _collect_batch(self, leader: Ticket) -> List[Ticket]:
        """Same-bucket companions for a popped batch leader, lingering
        ``batch_window_ms`` for stragglers. Companions must be
        first-attempt tickets (a retry carries an exclusion set and its
        own attempt accounting — it keeps the solo path)."""
        companions = self._queue.pop_batch(
            lambda t: t.bucket == leader.bucket and not t.excluded,
            limit=self._batch_max - 1,
            window_s=self._batch_window_s,
        )
        if companions:
            now = time.monotonic()
            for t in companions:
                if t.dispatch_t is None:
                    t.dispatch_t = now
            self._metrics.on_dispatch(self._queue.depth())
        return companions

    def _assign_now(self, batch: List[Ticket]) -> None:
        """Hand the batch to the best free eligible worker; if the
        free set changed under us (a concurrent kill), requeue — the
        next pass re-routes it. Eligibility is the leader's: companions
        are first-attempt tickets with no exclusions."""
        ticket = batch[0]
        with self._cap_cond:
            cands = [
                w
                for w in self._workers
                if w.alive and w.inflight < self._max_inflight
            ]
            cands = [w for w in cands if w.wid not in ticket.excluded]
            chosen = pick_worker(ticket.need, cands)
            if chosen is not None:
                chosen.inflight += 1
        if chosen is None:
            for t in batch:
                if not self._queue.requeue(t):
                    self._resolve_error(
                        t, ERR_CLOSED, "server closed during dispatch"
                    )
            return
        for t in batch:
            t.worker = chosen.wid
        chosen.inbox.put(batch)

    # -- worker callbacks ----------------------------------------------

    def _attempt_finished(self, worker: _Worker) -> None:
        with self._cap_cond:
            worker.inflight -= 1
            self._cap_cond.notify_all()

    def _attempt_failed(self, ticket: Ticket, wid: int, detail: str) -> None:
        """Supervision: record the failure, retry on another mesh when
        the budget and the fleet allow it, else surface the error."""
        ticket.errors.append(f"worker {wid}: {detail}")
        ticket.excluded.add(wid)
        ticket.attempts += 1
        can_retry = (
            ticket.attempts <= self._max_retries
            and not self._closing.is_set()
        )
        if can_retry:
            with self._cap_cond:
                elsewhere = any(
                    w.alive and w.wid not in ticket.excluded
                    for w in self._workers
                )
            can_retry = elsewhere
        if can_retry and self._queue.requeue(ticket):
            self._metrics.on_retry()
            return
        self._resolve_error(ticket, ERR_WORKER, "; ".join(ticket.errors))

    # -- resolution ----------------------------------------------------

    def _resolve_ok(self, ticket: Ticket, result, wid: int) -> None:
        now = time.monotonic()
        qw = (ticket.dispatch_t or now) - ticket.submit_t
        total = now - ticket.submit_t
        self._metrics.on_done(True, total, qw, wid)
        self._set(
            ticket.future,
            ServeResult(
                ok=True,
                result=result,
                error=None,
                worker=wid,
                attempts=ticket.attempts + 1,
                priority=ticket.priority,
                queue_wait_s=round(qw, 6),
                total_s=round(total, 6),
            ),
        )

    def _resolve_error(self, ticket: Ticket, code: str, detail: str) -> None:
        now = time.monotonic()
        qw = (ticket.dispatch_t or now) - ticket.submit_t
        total = now - ticket.submit_t
        self._metrics.on_done(
            False, total, qw, None, expired=code == ERR_DEADLINE
        )
        self._set(
            ticket.future,
            ServeResult(
                ok=False,
                result=None,
                error=code,
                detail=detail,
                worker=None,
                attempts=ticket.attempts,
                priority=ticket.priority,
                queue_wait_s=round(qw, 6),
                total_s=round(total, 6),
            ),
        )

    @staticmethod
    def _set(fut: Future, res: ServeResult) -> None:
        try:
            fut.set_result(res)
        except Exception:  # cancelled by the caller — drop the result
            pass

    # -- introspection / supervision -----------------------------------

    def metrics_window(self) -> Dict[str, Any]:
        """Windowed metrics deltas since the last call (see
        ``ServeMetrics.snapshot_window``) plus the live queue depth —
        the rate signal a fabric worker heartbeats to the front door
        and the autoscaler consumes."""
        win = self._metrics.snapshot_window()
        win["queue_depth_last"] = self._queue.depth()
        win["inflight"] = sum(w.inflight for w in self._workers)
        win["alive_workers"] = sum(1 for w in self._workers if w.alive)
        return win

    def stats(self) -> Dict[str, Any]:
        snap = self._metrics.snapshot()
        served = snap["per_worker_served"]
        snap.update(
            {
                "meshes": len(self._workers),
                "devices_per_mesh": self.devices_per_mesh,
                "queue_depth": self._queue.depth(),
                "workers": [
                    {
                        "wid": w.wid,
                        "devices": w.devices,
                        "alive": w.alive,
                        "inflight": w.inflight,
                        "served": served[w.wid],
                    }
                    for w in self._workers
                ],
            }
        )
        return snap

    @property
    def workers(self) -> List[_Worker]:
        return list(self._workers)

    def kill_worker(self, wid: int) -> None:
        """Take worker ``wid`` out of rotation. Attempts it still owns
        (and any it would have started) fail over to other meshes via
        the normal retry path — takes effect before the worker's next
        attempt starts; it cannot interrupt a running jit program."""
        w = self._workers[wid]
        with self._cap_cond:
            w.alive = False
            self._cap_cond.notify_all()
        w.release()  # free a held worker so its ticket can fail over

    # -- lifecycle -----------------------------------------------------

    def close(self, wait: bool = True) -> None:
        """Stop admission, resolve every queued ticket with a
        ``server_closed`` error, and shut workers down. Attempts already
        running complete normally when ``wait`` is True (wedged/timed-out
        workers are never waited on)."""
        if self._closing.is_set():
            return
        self._closing.set()
        self._queue.close()
        for t in self._queue.drain():
            self._resolve_error(t, ERR_CLOSED, "server closed before dispatch")
        with self._cap_cond:
            self._cap_cond.notify_all()
        self._dispatcher.join(timeout=5.0)
        for w in self._workers:
            w.inbox.put(_STOP)
            w.release()
        if wait:
            for w in self._workers:
                if w.alive:
                    w.thread.join(timeout=30.0)
        for w in self._workers:
            w.session.close(wait=wait and w.alive)

    def __enter__(self) -> "PartitionServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
