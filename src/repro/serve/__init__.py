"""Multi-mesh serving tier — traffic, not single jobs.

``PartitionServer`` layers an admission queue (priorities, deadlines),
a best-fit mesh scheduler, a shared graph cache and worker supervision
(one retry on another mesh, then a structured error) over N disjoint
device-mesh ``PartitionSession`` workers:

    from repro.serve import PartitionServer

    with PartitionServer(meshes=2, devices_per_mesh=4) as srv:
        fut = srv.submit(request, priority=0, deadline_s=30.0)
        res = fut.result()  # ServeResult
        res.ok, res.result, res.error, res.worker

Results are bit-identical to solo ``repro.api.Partitioner.run`` for
the same request. See docs/SERVING.md.

Exports resolve lazily (PEP 562) so importing ``repro.serve`` never
initializes jax — device carving happens at server construction.
"""

from importlib import import_module

_EXPORTS = {
    "PartitionServer": ".server",
    "ServeResult": ".server",
    "AdmissionQueue": ".queue",
    "Ticket": ".queue",
    "ServeMetrics": ".metrics",
    "pick_worker": ".scheduler",
    "rank": ".scheduler",
    "BucketKey": ".batching",
    "bucket_of": ".batching",
    "pad_graph": ".batching",
    "remove_padding": ".batching",
    "run_coalesced": ".batching",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    try:
        mod = _EXPORTS[name]
    except KeyError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}"
        ) from None
    return getattr(import_module(mod, __name__), name)


def __dir__():
    return __all__
