"""Admission queue for the multi-mesh serving tier.

A bounded, thread-safe priority queue of :class:`Ticket` objects. Lower
``priority`` values dispatch first; ties dispatch in admission order
(the sequence number doubles as the tiebreak, so a *retried* ticket —
which keeps its original sequence number — goes back to the front of
its priority class instead of behind newer work).

Deadlines are carried on the ticket and *checked by the consumers*
(dispatcher and workers), not enforced here: expiry must produce a
structured error result, which only the server can resolve.
"""

from __future__ import annotations

import dataclasses
import heapq
import threading
import time
from concurrent.futures import Future
from typing import List, Optional, Set


@dataclasses.dataclass
class Ticket:
    """One admitted request plus its serving state.

    ``deadline`` and ``timeout_s`` are distinct knobs: the deadline is
    an absolute completion bound (expired tickets resolve to a
    structured ``deadline_exceeded`` error without running), while
    ``timeout_s`` bounds one *attempt* on one worker (a timed-out
    attempt marks that worker wedged and retries elsewhere).
    """

    request: object  # PartitionRequest
    priority: int
    seq: int
    future: Future
    submit_t: float  # monotonic admission time
    deadline: Optional[float] = None  # absolute monotonic deadline
    timeout_s: Optional[float] = None  # per-attempt run timeout
    need: int = 1  # PE count the resolved backend wants
    bucket: Optional[tuple] = None  # shape bucket (batchable) or None
    attempts: int = 0  # failed attempts so far
    # mesh ids (in-process server) or server-id strings (fabric front
    # door) this ticket must not be routed to again
    excluded: Set = dataclasses.field(default_factory=set)
    worker: Optional[int] = None  # worker currently assigned
    dispatch_t: Optional[float] = None  # first leave-the-queue time
    errors: List[str] = dataclasses.field(default_factory=list)

    def expired(self, now: Optional[float] = None) -> bool:
        if self.deadline is None:
            return False
        return (time.monotonic() if now is None else now) >= self.deadline

    def remaining(self, now: Optional[float] = None) -> Optional[float]:
        """Seconds until the deadline (None when unbounded)."""
        if self.deadline is None:
            return None
        now = time.monotonic() if now is None else now
        return max(0.0, self.deadline - now)


class AdmissionQueue:
    """Bounded priority queue; ``put`` returns False when full/closed.

    ``requeue`` bypasses the capacity bound: a retried ticket was
    already admitted once, and dropping it on a full queue would turn
    the retry guarantee into a coin flip under load.
    """

    def __init__(self, capacity: int = 1024):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._heap: list = []  # (priority, seq, ticket)
        self._cond = threading.Condition()
        self._closed = False

    def put(self, ticket: Ticket) -> bool:
        with self._cond:
            if self._closed or len(self._heap) >= self.capacity:
                return False
            heapq.heappush(self._heap, (ticket.priority, ticket.seq, ticket))
            self._cond.notify()
            return True

    def requeue(self, ticket: Ticket) -> bool:
        with self._cond:
            if self._closed:
                return False
            heapq.heappush(self._heap, (ticket.priority, ticket.seq, ticket))
            self._cond.notify()
            return True

    def pop(self, timeout: Optional[float] = None) -> Optional[Ticket]:
        """Highest-priority ticket, or None on timeout — or immediately
        once the queue is closed *and* drained.

        Waits in a deadline loop: a spurious wakeup, or a competing
        consumer winning the notify, puts this caller back to sleep for
        the time actually remaining instead of returning None with time
        still on the clock (the lost-wakeup bug under two consumers)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while not self._heap:
                if self._closed:
                    return None
                if deadline is None:
                    self._cond.wait()
                    continue
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    return None
                self._cond.wait(remaining)
            return heapq.heappop(self._heap)[2]

    def pop_matching(self, pred) -> Optional[Ticket]:
        """Remove and return the highest-priority ticket satisfying
        ``pred``, or None (without blocking). This is what lets the
        dispatcher skip a ticket whose eligible meshes are all busy and
        serve the next one — instead of head-of-line blocking the whole
        queue behind it.

        One linear scan over the heap array tracking the best match
        (the heap is unordered beyond its invariant, so every entry is
        visited once), then an O(log n) index removal — the dispatcher's
        hot loop must not pay the old sort-the-whole-heap O(n log n)."""
        with self._cond:
            return self._pop_matching_locked(pred)

    def pop_batch(
        self, pred, limit: int, window_s: float = 0.0
    ) -> List[Ticket]:
        """Remove up to ``limit`` tickets satisfying ``pred``, best
        (priority, seq) first. When fewer than ``limit`` are queued,
        linger up to ``window_s`` for more matching admissions — the
        dispatcher's batch-collection window. Returns immediately with
        whatever matched once the queue is closed."""
        out: List[Ticket] = []
        if limit <= 0:
            return out
        deadline = time.monotonic() + max(0.0, window_s)
        with self._cond:
            while len(out) < limit:
                t = self._pop_matching_locked(pred)
                if t is not None:
                    out.append(t)
                    continue
                if self._closed:
                    break
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                self._cond.wait(remaining)
        return out

    def _pop_matching_locked(self, pred) -> Optional[Ticket]:
        heap = self._heap
        best = -1
        for i, (prio, seq, t) in enumerate(heap):
            if best >= 0 and (prio, seq) >= heap[best][:2]:
                continue
            if pred(t):
                best = i
        if best < 0:
            return None
        return self._remove_at(best)

    def _remove_at(self, i: int) -> Ticket:
        """Remove the entry at heap index ``i``: swap in the last entry
        and restore the invariant around the hole (float up, else sink
        below the smaller child). (priority, seq) keys are unique, so
        entry comparisons never reach the unorderable ticket payload."""
        heap = self._heap
        entry = heap[i]
        last = heap.pop()
        if i < len(heap):
            heap[i] = last
            while i > 0 and heap[i] < heap[(i - 1) >> 1]:
                parent = (i - 1) >> 1
                heap[i], heap[parent] = heap[parent], heap[i]
                i = parent
            n = len(heap)
            while True:
                child = 2 * i + 1
                if child >= n:
                    break
                if child + 1 < n and heap[child + 1] < heap[child]:
                    child += 1
                if heap[child] < heap[i]:
                    heap[i], heap[child] = heap[child], heap[i]
                    i = child
                else:
                    break
        return entry[2]

    def drain(self) -> List[Ticket]:
        """Remove and return every queued ticket (close-time cleanup)."""
        with self._cond:
            out = [t for _, _, t in self._heap]
            self._heap.clear()
            return out

    def depth(self) -> int:
        with self._cond:
            return len(self._heap)

    def close(self) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify_all()
