"""Admission queue for the multi-mesh serving tier.

A bounded, thread-safe priority queue of :class:`Ticket` objects. Lower
``priority`` values dispatch first; ties dispatch in admission order
(the sequence number doubles as the tiebreak, so a *retried* ticket —
which keeps its original sequence number — goes back to the front of
its priority class instead of behind newer work).

Deadlines are carried on the ticket and *checked by the consumers*
(dispatcher and workers), not enforced here: expiry must produce a
structured error result, which only the server can resolve.
"""

from __future__ import annotations

import dataclasses
import heapq
import threading
import time
from concurrent.futures import Future
from typing import List, Optional, Set


@dataclasses.dataclass
class Ticket:
    """One admitted request plus its serving state.

    ``deadline`` and ``timeout_s`` are distinct knobs: the deadline is
    an absolute completion bound (expired tickets resolve to a
    structured ``deadline_exceeded`` error without running), while
    ``timeout_s`` bounds one *attempt* on one worker (a timed-out
    attempt marks that worker wedged and retries elsewhere).
    """

    request: object  # PartitionRequest
    priority: int
    seq: int
    future: Future
    submit_t: float  # monotonic admission time
    deadline: Optional[float] = None  # absolute monotonic deadline
    timeout_s: Optional[float] = None  # per-attempt run timeout
    need: int = 1  # PE count the resolved backend wants
    attempts: int = 0  # failed attempts so far
    excluded: Set[int] = dataclasses.field(default_factory=set)
    worker: Optional[int] = None  # worker currently assigned
    dispatch_t: Optional[float] = None  # first leave-the-queue time
    errors: List[str] = dataclasses.field(default_factory=list)

    def expired(self, now: Optional[float] = None) -> bool:
        if self.deadline is None:
            return False
        return (time.monotonic() if now is None else now) >= self.deadline

    def remaining(self, now: Optional[float] = None) -> Optional[float]:
        """Seconds until the deadline (None when unbounded)."""
        if self.deadline is None:
            return None
        now = time.monotonic() if now is None else now
        return max(0.0, self.deadline - now)


class AdmissionQueue:
    """Bounded priority queue; ``put`` returns False when full/closed.

    ``requeue`` bypasses the capacity bound: a retried ticket was
    already admitted once, and dropping it on a full queue would turn
    the retry guarantee into a coin flip under load.
    """

    def __init__(self, capacity: int = 1024):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._heap: list = []  # (priority, seq, ticket)
        self._cond = threading.Condition()
        self._closed = False

    def put(self, ticket: Ticket) -> bool:
        with self._cond:
            if self._closed or len(self._heap) >= self.capacity:
                return False
            heapq.heappush(self._heap, (ticket.priority, ticket.seq, ticket))
            self._cond.notify()
            return True

    def requeue(self, ticket: Ticket) -> bool:
        with self._cond:
            if self._closed:
                return False
            heapq.heappush(self._heap, (ticket.priority, ticket.seq, ticket))
            self._cond.notify()
            return True

    def pop(self, timeout: Optional[float] = None) -> Optional[Ticket]:
        """Highest-priority ticket, or None on timeout / empty queue."""
        with self._cond:
            if not self._heap:
                self._cond.wait(timeout)
            if not self._heap:
                return None
            return heapq.heappop(self._heap)[2]

    def pop_matching(self, pred) -> Optional[Ticket]:
        """Remove and return the highest-priority ticket satisfying
        ``pred``, or None (without blocking). This is what lets the
        dispatcher skip a ticket whose eligible meshes are all busy and
        serve the next one — instead of head-of-line blocking the whole
        queue behind it."""
        with self._cond:
            for entry in sorted(self._heap):
                if pred(entry[2]):
                    self._heap.remove(entry)
                    heapq.heapify(self._heap)
                    return entry[2]
            return None

    def drain(self) -> List[Ticket]:
        """Remove and return every queued ticket (close-time cleanup)."""
        with self._cond:
            out = [t for _, _, t in self._heap]
            self._heap.clear()
            return out

    def depth(self) -> int:
        with self._cond:
            return len(self._heap)

    def close(self) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify_all()
