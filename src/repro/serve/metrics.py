"""Serving-tier metrics: counters, latency percentiles, queue depth.

One :class:`ServeMetrics` per server, updated from the dispatcher and
every worker thread, snapshotted into plain dicts. Latencies keep a
bounded sample (admission -> resolution, i.e. queue wait plus every
attempt) so p50/p99 stay O(1) memory under sustained load; queue depth
is sampled at every admission and dispatch, giving the
depth-vs-offered-load curve the serve benchmark tracks.
"""

from __future__ import annotations

import math
import threading
from typing import Dict, List, Optional

_MAX_SAMPLES = 8192


def percentile(sorted_xs: List[float], p: float) -> float:
    """Nearest-rank percentile of an already-sorted sample (0 when
    empty) — enough fidelity for serving dashboards, no numpy needed
    on the hot path.

    Nearest-rank is the value at 1-indexed rank ``ceil(p/100 * n)``.
    The old ``int(round(p/100 * (n-1)))`` form used banker's rounding,
    which on small samples (n < 100 — the CI bench regime) selects a
    *lower* rank than the definition and under-reports tail latency."""
    if not sorted_xs:
        return 0.0
    n = len(sorted_xs)
    idx = max(0, math.ceil(p / 100.0 * n) - 1)
    return sorted_xs[min(n - 1, idx)]


class ServeMetrics:
    """Thread-safe counters + bounded reservoirs for one server."""

    _COUNTERS = ("submitted", "completed", "failed", "expired",
                 "rejected", "retried", "batches", "coalesced",
                 "downgraded")

    def __init__(self, num_workers: int):
        self._lock = threading.Lock()
        self.submitted = 0
        self.completed = 0  # resolved ok
        self.failed = 0  # resolved with a structured error
        self.expired = 0  # failed specifically on the deadline
        self.rejected = 0  # refused at admission (queue full/closed)
        self.retried = 0  # attempts re-routed to another mesh
        self.batches = 0  # multi-ticket attempts dispatched
        self.coalesced = 0  # tickets served off another ticket's run
        self.downgraded = 0  # quality="best" dropped to "fast" (deadline)
        self.batch_size_max = 0
        self.per_worker_served = [0] * num_workers
        self._latencies: List[float] = []
        self._queue_waits: List[float] = []
        self._depth_samples: List[int] = []
        # windowed state: counter values at the last snapshot_window()
        # call plus since-then reservoirs, so consumers (autoscaler,
        # front door health) see *rates*, not lifetime totals
        self._win_base: Dict[str, int] = {k: 0 for k in self._COUNTERS}
        self._win_latencies: List[float] = []
        self._win_waits: List[float] = []
        self._win_depths: List[int] = []

    def resize_workers(self, num_workers: int) -> None:
        """Grow ``per_worker_served`` when the worker/server count
        changes at runtime (autoscaling). Growth only — counts for
        departed workers are history, not garbage."""
        with self._lock:
            if num_workers > len(self.per_worker_served):
                self.per_worker_served.extend(
                    [0] * (num_workers - len(self.per_worker_served)))

    # -- recording (called by server/dispatcher/workers) ---------------

    def on_submit(self, depth: int) -> None:
        with self._lock:
            self.submitted += 1
            self._sample(self._depth_samples, depth)
            self._sample(self._win_depths, depth)

    def on_dispatch(self, depth: int) -> None:
        with self._lock:
            self._sample(self._depth_samples, depth)
            self._sample(self._win_depths, depth)

    def on_retry(self) -> None:
        with self._lock:
            self.retried += 1

    def on_reject(self) -> None:
        with self._lock:
            self.rejected += 1

    def on_downgrade(self) -> None:
        with self._lock:
            self.downgraded += 1

    def on_batch(self, size: int, distinct: int) -> None:
        """One multi-ticket attempt: ``size`` tickets ran as one batch,
        of which only ``distinct`` needed their own partition run."""
        with self._lock:
            self.batches += 1
            self.coalesced += max(0, size - distinct)
            self.batch_size_max = max(self.batch_size_max, size)

    def on_done(
        self,
        ok: bool,
        latency_s: float,
        queue_wait_s: float,
        worker: Optional[int],
        expired: bool = False,
    ) -> None:
        with self._lock:
            if ok:
                self.completed += 1
            else:
                self.failed += 1
                if expired:
                    self.expired += 1
            if worker is not None and worker >= 0:
                # auto-grow: the fabric front door indexes servers that
                # join at runtime, so a fixed-size list would drop them
                if worker >= len(self.per_worker_served):
                    self.per_worker_served.extend(
                        [0] * (worker + 1 - len(self.per_worker_served)))
                self.per_worker_served[worker] += 1
            self._sample(self._latencies, latency_s)
            self._sample(self._queue_waits, queue_wait_s)
            self._sample(self._win_latencies, latency_s)
            self._sample(self._win_waits, queue_wait_s)

    def _sample(self, reservoir: list, x) -> None:
        if len(reservoir) >= _MAX_SAMPLES:
            # drop the oldest half: cheap, keeps recent behaviour
            del reservoir[: _MAX_SAMPLES // 2]
        reservoir.append(x)

    # -- reading -------------------------------------------------------

    def snapshot(self) -> Dict[str, object]:
        # one lock span: counters and reservoirs must come from the
        # same instant, or completed=N could pair with N-1 samples
        with self._lock:
            lat = sorted(self._latencies)
            wait = sorted(self._queue_waits)
            depth = list(self._depth_samples)
            out = {
                "submitted": self.submitted,
                "completed": self.completed,
                "failed": self.failed,
                "expired": self.expired,
                "rejected": self.rejected,
                "retried": self.retried,
                "batches": self.batches,
                "coalesced": self.coalesced,
                "downgraded": self.downgraded,
                "batch_size_max": self.batch_size_max,
                "per_worker_served": list(self.per_worker_served),
            }
        mean_depth = sum(depth) / len(depth) if depth else 0.0
        out.update(
            {
                "latency_p50_s": round(percentile(lat, 50), 6),
                "latency_p99_s": round(percentile(lat, 99), 6),
                "queue_wait_p50_s": round(percentile(wait, 50), 6),
                "queue_wait_p99_s": round(percentile(wait, 99), 6),
                "queue_depth_max": max(depth, default=0),
                "queue_depth_mean": round(mean_depth, 3),
            }
        )
        return out

    def snapshot_window(self) -> Dict[str, object]:
        """Deltas since the last ``snapshot_window()`` call (rates, not
        lifetime totals): counter increments, latency/queue-wait
        percentiles over the window's own samples, and the window's
        queue-depth profile. Resets the window — callers own the
        cadence (the autoscaler's evaluation period, a fabric worker's
        heartbeat). First call returns everything since construction."""
        with self._lock:
            counts = {k: getattr(self, k) for k in self._COUNTERS}
            out: Dict[str, object] = {
                k: counts[k] - self._win_base[k] for k in self._COUNTERS
            }
            self._win_base = counts
            lat = sorted(self._win_latencies)
            wait = sorted(self._win_waits)
            depth = self._win_depths
            out.update(
                {
                    "latency_p50_s": round(percentile(lat, 50), 6),
                    "latency_p99_s": round(percentile(lat, 99), 6),
                    "queue_wait_p99_s": round(percentile(wait, 99), 6),
                    "queue_depth_max": max(depth, default=0),
                    "queue_depth_mean": round(
                        sum(depth) / len(depth), 3) if depth else 0.0,
                    "queue_depth_last": depth[-1] if depth else 0,
                }
            )
            self._win_latencies = []
            self._win_waits = []
            self._win_depths = []
            return out
