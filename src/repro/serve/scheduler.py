"""Mesh-routing policy for the multi-mesh serving tier.

The scheduler answers one question: given a ticket that needs ``need``
PEs (``repro.api.backends.required_devices`` — the same pure policy
``backend="auto"`` uses), which live worker mesh should run it?

Ranking, best first:

1. exact PE-count match — the worker's shared mesh (and its jit cache,
   keyed on the mesh) is reused directly;
2. smallest mesh with at least ``need`` PEs — the request still runs,
   leaving bigger meshes free for bigger jobs;
3. any remaining mesh — an undersized mesh can always serve a request
   without the shared mesh, so correctness never depends on fit;

ties broken by lighter load, then by worker id for determinism. The
policy is a pure function over (need, candidates) so it unit-tests
without a server or a device.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

# a fit penalty larger than any realistic mesh-size gap, so undersized
# meshes always rank behind every mesh that actually fits
_UNDERSIZED = 1 << 20


def rank(
    need: int,
    devices: int,
    inflight: int,
    worker_id: int,
) -> Tuple[int, int, int, int]:
    """Sort key for one candidate mesh; lower is better."""
    exact = 0 if devices == need else 1
    if devices >= need:
        fit = devices - need
    else:
        fit = _UNDERSIZED + (need - devices)
    return (exact, fit, inflight, worker_id)


def pick_worker(need: int, candidates: Sequence) -> Optional[object]:
    """Best-fitting worker from ``candidates`` (objects exposing
    ``devices``, ``inflight`` and ``wid``), or None when empty."""
    best = None
    best_key = None
    for w in candidates:
        key = rank(need, w.devices, w.inflight, w.wid)
        if best_key is None or key < best_key:
            best, best_key = w, key
    return best


def pick_server(need: int, candidates: Sequence) -> Optional[object]:
    """Best-fitting fabric *server* from ``candidates`` (objects
    exposing ``devices``, ``inflight`` and a string ``sid``) — the same
    exact-match / smallest-fit / any ranking as :func:`pick_worker`,
    with the deterministic tiebreak on the server id string. Pure, so
    the cross-process front door routes with the in-process policy."""
    best = None
    best_key = None
    for s in candidates:
        key = rank(need, s.devices, s.inflight, 0)[:3] + (s.sid,)
        if best_key is None or key < best_key:
            best, best_key = s, key
    return best
