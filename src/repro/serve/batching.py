"""Shape-bucketed batched dispatch for the serving tier.

The saxml servable-model idiom (padded batch shapes + ``remove_padding``)
adapted to graph partitioning: requests land in **shape buckets** keyed
by ``(padded_n, padded_m, k, backend)`` on geometric padding ladders, the
dispatcher pops up to ``batch_max`` same-bucket tickets (lingering up to
``batch_window_ms`` for stragglers), and a worker serves the whole batch
as ONE unit of work. Two mechanisms amortize cost inside a batch, both
bit-identical to solo ``Partitioner.run``:

1. **Coalescing** — a ``PartitionRequest`` is a pure function of its
   fields (graph spec, k, config, *seed* — seeds are per-request, never
   derived from the batch), so identical requests in a batch share one
   partition run. This is exact by construction and is the dominant
   saving on hot traffic mixes.

2. **Stacked level-0 clustering** — distinct requests whose padded chunk
   slabs share a jit shape run their (dominant) level-0 LP clustering as
   one vmapped program (``lp.cluster_iteration_stacked``), the result
   re-entering each request's solo driver via ``level0_labels``. Rows
   are padded to a common ``(n_pad, m_pad)``; padding is provably inert:

     * padded vertices are weight-0 singletons with no arcs — they can
       never move (their best connection is 0, and moves require a
       strictly positive gain), and no real vertex can adopt them as a
       target (sentinel arcs carry weight 0, so their label groups
       score 0);
     * per-request slab construction (seeded degree-bucket reorder,
       chunk boundaries) stays on the host exactly as in a solo run —
       only the already-shape-padded jit operands are stacked;
     * the kernels are integer-only, and vmap of integer ops is exactly
       semantics-preserving — no float reassociation exists to break
       bit-identity.

   Stacking is gated by ``stack``: ``"auto"`` enables it only off-CPU
   (the XLA CPU per-row sort is compute-bound, so vmap amortizes
   nothing there), ``"on"``/``"off"`` force it.

``pad_graph`` / ``remove_padding`` are the graph-level analogues of the
saxml helpers — padded vertices are weight-0 and isolated, so any
assignment's cut and block weights are untouched. They canonicalize
graphs onto the bucket ladder for cache keys and tests; the execution
path pads at the chunk-slab level instead, because whole-graph padding
would shift the host-side reorder RNG and break solo bit-identity.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from ..api.backends import is_batchable, resolve_backend
from ..api.request import GraphSpec, PartitionRequest
from ..graphs.format import Graph

# ladder floors: tiny requests share one bucket instead of fragmenting
# the cache across near-identical shapes
_MIN_PAD_N = 256
_MIN_PAD_M = 1024


def pad_dim(x: int, floor: int = 1) -> int:
    """Geometric (power-of-two) padding ladder, mirroring the rung the
    jit shape-bucket cache uses (``lp.build_chunks`` pads to powers of
    two): the smallest power of two >= max(x, floor)."""
    x = max(int(x), int(floor), 1)
    return 1 << (x - 1).bit_length()


class BucketKey(NamedTuple):
    """Dispatch bucket of a batchable request. Requests in one bucket
    pad to the same rung of the shape ladder, so batching them trades
    no extra padding and their stacked slabs share one jit program."""

    padded_n: int
    padded_m: int
    k: int
    backend: str


def _graph_dims(graph) -> Tuple[int, int]:
    if isinstance(graph, GraphSpec):
        # directed arc count of the materialized graph is ~n * avg_deg;
        # the ladder only needs the rung, not the exact count
        return graph.n, int(graph.n * graph.avg_deg)
    return graph.n, graph.m


def bucket_of(req: PartitionRequest) -> Optional[BucketKey]:
    """The request's dispatch bucket, or None when it must stay on the
    solo serve path (non-batchable backend, or a multi-device ask)."""
    n, m = _graph_dims(req.graph)
    backend = resolve_backend(req, n)
    if not is_batchable(backend) or req.devices != 1:
        return None
    return BucketKey(
        padded_n=pad_dim(n, _MIN_PAD_N),
        padded_m=pad_dim(m, _MIN_PAD_M),
        k=req.k,
        backend=backend,
    )


def request_fingerprint(req: PartitionRequest) -> tuple:
    """Hashable identity of a request's *result*: equal fingerprints are
    guaranteed equal results (requests are pure functions of their
    fields). Raw ``Graph`` payloads key by object identity — a
    conservative stand-in for content equality."""
    key = []
    for f in dataclasses.fields(req):
        v = getattr(req, f.name)
        if f.name == "graph" and not isinstance(v, GraphSpec):
            v = ("graph-id", id(v))
        key.append((f.name, v))
    return tuple(key)


# ---------------------------------------------------------------------------
# Graph-level padding (saxml remove_padding idiom)
# ---------------------------------------------------------------------------


def pad_graph(g: Graph, n_pad: int) -> Graph:
    """Pad ``g`` to ``n_pad`` vertices with weight-0 isolated vertices.

    The padding is inert for partitioning metrics: isolated vertices
    contribute no arcs (cut unchanged) and zero weight (block weights
    unchanged) whatever block an assignment puts them in. The padded
    graph intentionally fails ``validate()`` (which requires vweights
    >= 1) — it is a batching artifact, not a model input."""
    if n_pad < g.n:
        raise ValueError(f"n_pad ({n_pad}) < graph n ({g.n})")
    if n_pad == g.n:
        return g
    extra = n_pad - g.n
    pad_ptr = np.full(extra, g.indptr[-1], dtype=g.indptr.dtype)
    pad_w = np.zeros(extra, dtype=g.vweights.dtype)
    return Graph(
        indptr=np.concatenate([g.indptr, pad_ptr]),
        adjncy=g.adjncy,
        eweights=g.eweights,
        vweights=np.concatenate([g.vweights, pad_w]),
    )


def remove_padding(assignment: np.ndarray, n: int) -> np.ndarray:
    """Slice a padded-graph assignment back to the real vertices."""
    return np.asarray(assignment)[:n]


# ---------------------------------------------------------------------------
# Stacked level-0 clustering
# ---------------------------------------------------------------------------


def stack_enabled(stack: str) -> bool:
    """Resolve the ``stack`` knob. ``"auto"`` is on only off-CPU: the
    measured CPU reality is that the per-row sort dominates and a
    vmapped batch costs as much as the rows run back to back."""
    if stack == "on":
        return True
    if stack == "off":
        return False
    import jax

    return jax.default_backend() != "cpu"


def stacked_level0_labels(
    graphs: Sequence[Graph], plans: Sequence[Dict]
) -> List[np.ndarray]:
    """Level-0 clustering labels for several (graph, plan) pairs via one
    stacked jitted program per shared slab shape, bit-identical to
    ``coarsening.cluster(g, plan["W"], ...)`` per entry.

    ``plans`` entries come from ``deep_mgp.level0_cluster_plan``. Host
    preparation (seeded reorder, chunking) runs per request; only the
    padded jit operands stack. Entries whose chunk slabs do not share a
    (num_chunks, iterations) signature fall into separate stacks."""
    import jax.numpy as jnp

    from ..core import lp
    from ..core.coarsening import cluster_finish, cluster_prepare
    from ..core.coarsening import cluster_seed

    prepped = []
    for g, plan in zip(graphs, plans):
        nc = plan["num_chunks"]
        perm, g2, chunks = cluster_prepare(g, nc, plan["seed"])
        prepped.append((g, plan, perm, g2, chunks))

    groups: Dict[tuple, List[int]] = {}
    for i, (_, plan, _, _, chunks) in enumerate(prepped):
        sig = (chunks.num_chunks, plan["num_iterations"])
        groups.setdefault(sig, []).append(i)

    out: List[Optional[np.ndarray]] = [None] * len(prepped)
    for (num_chunks, num_iterations), idxs in groups.items():
        n_pad = max(prepped[i][4].n_pad for i in idxs)
        m_pad = max(prepped[i][4].w.shape[1] for i in idxs)
        src_rows: List[np.ndarray] = []
        dst_rows: List[np.ndarray] = []
        w_rows: List[np.ndarray] = []
        vw_rows: List[np.ndarray] = []
        w_bound: List[int] = []
        seeds: List[int] = []
        for i in idxs:
            _, plan, _, g2, chunks = prepped[i]
            src = np.full((num_chunks, m_pad), n_pad, dtype=np.int32)
            dst = np.full((num_chunks, m_pad), n_pad, dtype=np.int32)
            w = np.zeros((num_chunks, m_pad), dtype=np.int32)
            mp = chunks.w.shape[1]
            # a row's own sentinel id (its n_pad) becomes a *real* slot
            # under the stack's larger n_pad — remap it (real vertex
            # ids are < n <= row n_pad, so only sentinels match)
            src_sentinel = chunks.src == chunks.n_pad
            dst_sentinel = chunks.dst == chunks.n_pad
            src[:, :mp] = np.where(src_sentinel, n_pad, chunks.src)
            dst[:, :mp] = np.where(dst_sentinel, n_pad, chunks.dst)
            w[:, :mp] = chunks.w
            vw = np.zeros(n_pad + 1, dtype=np.int32)
            vw[: g2.n] = g2.vweights
            src_rows.append(src)
            dst_rows.append(dst)
            w_rows.append(w)
            vw_rows.append(vw)
            w_bound.append(max(1, plan["W"]))
            seeds.append(plan["seed"])
        R = len(idxs)
        labels = jnp.broadcast_to(
            jnp.arange(n_pad + 1, dtype=jnp.int32),
            (R, n_pad + 1),
        )
        vw = jnp.asarray(np.stack(vw_rows))
        cluster_w = vw
        src = jnp.asarray(np.stack(src_rows))
        dst = jnp.asarray(np.stack(dst_rows))
        w = jnp.asarray(np.stack(w_rows))
        W = jnp.asarray(np.asarray(w_bound, dtype=np.int32))
        for it in range(num_iterations):
            salts = [cluster_seed(s, it) for s in seeds]
            it_seeds = jnp.asarray(np.asarray(salts, dtype=np.uint32))
            labels, cluster_w = lp.cluster_iteration_stacked(
                labels, cluster_w, src, dst, w, vw, W, it_seeds, n=n_pad
            )
        labels_np = np.asarray(labels)
        for row, i in enumerate(idxs):
            _, plan, perm, g2, _ = prepped[i]
            out[i] = cluster_finish(
                labels_np[row], g2, perm, max(1, plan["W"])
            )
    return out  # type: ignore[return-value]


def _level0_hints(
    session, requests: Sequence[PartitionRequest], stack: str
) -> List[Optional[np.ndarray]]:
    """Precomputed level-0 labels for the stack-eligible requests of a
    deduplicated batch (None entries keep the solo path)."""
    hints: List[Optional[np.ndarray]] = [None] * len(requests)
    if len(requests) < 2 or not stack_enabled(stack):
        return hints
    from ..core.deep_mgp import level0_cluster_plan

    eligible: List[int] = []
    graphs: List[Graph] = []
    plans: List[Dict] = []
    for i, req in enumerate(requests):
        eff = session._resolve_graph(req)
        override = session._engine.backend
        if override is not None and eff.backend == "auto":
            eff = dataclasses.replace(eff, backend=override)
        # only the "single" driver consumes the hint
        if resolve_backend(eff, eff.graph.n) != "single":
            continue
        plan = level0_cluster_plan(eff.graph, eff.k, eff.resolve_config())
        if plan is None:
            continue
        eligible.append(i)
        graphs.append(eff.graph)
        plans.append(plan)
    if len(eligible) < 2:
        return hints
    labels = stacked_level0_labels(graphs, plans)
    for i, lab in zip(eligible, labels):
        hints[i] = lab
    return hints


# ---------------------------------------------------------------------------
# Batch execution
# ---------------------------------------------------------------------------


def run_coalesced(
    session, requests: Sequence[PartitionRequest], stack: str = "auto"
) -> List[object]:
    """Serve a same-bucket batch through ``session``, returning
    ``PartitionResult``s in request order, each bit-identical to a solo
    ``Partitioner.run`` of its request.

    Identical requests (by :func:`request_fingerprint`) share one run;
    distinct stack-eligible requests share one stacked level-0
    clustering program. Runs on the session's executor thread — callers
    go through ``PartitionSession.submit_many``."""
    groups: Dict[tuple, List[int]] = {}
    order: List[tuple] = []
    for i, req in enumerate(requests):
        fp = request_fingerprint(req)
        if fp not in groups:
            groups[fp] = []
            order.append(fp)
        groups[fp].append(i)
    distinct = [requests[groups[fp][0]] for fp in order]
    hints = _level0_hints(session, distinct, stack)
    out: List[object] = [None] * len(requests)
    for fp, req, hint in zip(order, distinct, hints):
        res = session._run_one(req, level0_labels=hint)
        for i in groups[fp]:
            out[i] = res
    return out


def distinct_count(requests: Sequence[PartitionRequest]) -> int:
    """Number of distinct results a batch needs (metrics accounting)."""
    return len({request_fingerprint(r) for r in requests})
