import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# NOTE: the two lines above MUST run before any other import — jax locks
# the device count at first init (see system design constraints).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell
with ShapeDtypeStruct inputs (no allocation), print memory/cost analysis,
and extract the collective schedule for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
  python -m repro.launch.dryrun --all --out artifacts/dryrun
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax
import numpy as np

from repro.configs import load_all
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Bytes of one HLO shape string like 'bf16[16,128]' or a tuple."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Sum per-device result bytes of every collective op in the compiled
    (SPMD-partitioned) HLO. Result bytes are the wire-volume proxy:
    all-gather receives its result, reduce-scatter/all-reduce move
    ~operand bytes (== result for all-reduce)."""
    stats = {}
    for line in hlo_text.splitlines():
        s = line.strip()
        eq = s.find("= ")
        if eq < 0:
            continue
        # match '<name> = TYPE op-name(...' — search the op marker AFTER
        # '= ' (the variable name itself often contains the op name)
        for op in COLLECTIVE_OPS:
            pos = -1
            for marker in (f" {op}(", f" {op}-start("):
                pos = s.find(marker, eq)
                if pos >= 0:
                    break
            if pos < 0:
                continue
            type_part = s[eq + 2: pos + 1]
            b = _shape_bytes(type_part)
            ent = stats.setdefault(op, {"count": 0, "bytes": 0})
            ent["count"] += 1
            ent["bytes"] += b
            break
    return stats


def _probe_flops(entry, shape_name: str, mesh, n_layers: int) -> float:
    """Per-device HLO flops of an unrolled n_layers variant (LM cells:
    lax.scan hides the per-layer cost from cost_analysis, so the real
    total is reconstructed as f1 + (L-1)*(f2-f1))."""
    import dataclasses as dc
    e = dc.replace(entry, config=dc.replace(
        entry.config, n_layers=n_layers, scan_layers=False))
    built = build_step(e, shape_name, mesh)
    compiled = jax.jit(built.fn, in_shardings=built.in_shardings) \
        .lower(*built.args).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca["flops"])


def run_cell(entry, shape_name: str, multi_pod: bool, verbose: bool = True,
             probe: bool = True):
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(mesh.devices.shape))
    built = build_step(entry, shape_name, mesh)
    t0 = time.time()
    jitted = jax.jit(built.fn, in_shardings=built.in_shardings)
    lowered = jitted.lower(*built.args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_size_bytes": int(getattr(ma, "argument_size_in_bytes",
                                               0)),
            "output_size_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_size_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "generated_code_size_bytes":
                int(getattr(ma, "generated_code_size_in_bytes", 0)),
        }
    except Exception as e:  # pragma: no cover
        mem = {"error": str(e)}

    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        cost = {k: float(v) for k, v in ca.items()
                if k in ("flops", "bytes accessed", "optimal_seconds")
                or k.startswith("bytes accessed")}
    except Exception as e:  # pragma: no cover
        cost = {"error": str(e)}

    txt = compiled.as_text()
    coll = collective_stats(txt)

    flops_corrected = None
    # probes (scan-corrected flops) only on the single-pod mesh — the
    # §Roofline table is single-pod; the multi-pod pass proves sharding
    if probe and not multi_pod and entry.kind == "lm" and "flops" in cost:
        try:
            t0 = time.time()
            f1 = _probe_flops(entry, shape_name, mesh, 1)
            f2 = _probe_flops(entry, shape_name, mesh, 2)
            L = entry.config.n_layers
            flops_corrected = f1 + (L - 1) * (f2 - f1)
            cost["probe_s"] = round(time.time() - t0, 2)
            cost["flops_l1_probe"] = f1
            cost["flops_l2_probe"] = f2
        except Exception as e:  # pragma: no cover
            cost["probe_error"] = repr(e)

    result = {
        "arch": entry.arch_id,
        "shape": shape_name,
        "mesh": list(mesh.devices.shape),
        "mesh_axes": list(mesh.axis_names),
        "n_devices": n_dev,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": mem,
        "cost_analysis": cost,
        "collectives": coll,
        "model_flops": built.model_flops,
        "hlo_flops_per_device": cost.get("flops"),
        "hlo_flops_per_device_corrected": flops_corrected,
        "optimizer": built.opt_name,
    }
    if verbose:
        print(json.dumps(result), flush=True)
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2x16x16 multi-pod mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="artifact dir for JSONs")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    registry = load_all()
    cells = []
    if args.all:
        for entry in registry.values():
            for s in entry.shapes:
                cells.append((entry, s.name))
    else:
        entry = registry[args.arch]
        names = [args.shape] if args.shape else [s.name
                                                 for s in entry.shapes]
        cells = [(entry, n) for n in names]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = []
    for entry, shape_name in cells:
        for mp in meshes:
            tag = f"{entry.arch_id}/{shape_name}/" + \
                ("pod2x16x16" if mp else "pod16x16")
            fn = tag.replace("/", "__") + ".json"
            if args.skip_existing and args.out and \
                    os.path.exists(os.path.join(args.out, fn)):
                continue
            try:
                res = run_cell(entry, shape_name, mp)
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    with open(os.path.join(args.out, fn), "w") as f:
                        json.dump(res, f, indent=1)
            except Exception as e:
                failures.append((tag, repr(e)))
                print(json.dumps({"cell": tag, "error": repr(e)}),
                      flush=True)
                traceback.print_exc()
    if failures:
        print(f"FAILED {len(failures)} cells", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
