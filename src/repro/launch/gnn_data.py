"""Synthetic GraphBatch builders shared by the train CLI and examples."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.graphs import generators
from repro.models.gnn.common import GraphBatch


def build_gnn_batch(arch_id: str, cfg, n: int = 400, seed: int = 0
                    ) -> GraphBatch:
    rng = np.random.default_rng(seed)
    g = generators.make("rgg2d", n, 8.0, seed=seed)
    snd = g.arc_tails().astype(np.int32)
    rcv = np.asarray(g.adjncy, dtype=np.int32)
    N = g.n + 1
    mask = np.arange(N) < g.n
    kw = {}
    if arch_id == "gat-cora":
        feat = rng.standard_normal((N, cfg.d_in)).astype(np.float32)
        labels = rng.integers(0, cfg.n_classes, N)
        return GraphBatch(
            senders=jnp.asarray(snd), receivers=jnp.asarray(rcv), n_node=N,
            node_feat=jnp.asarray(feat), labels=jnp.asarray(labels),
            node_mask=jnp.asarray(mask))
    pos = rng.standard_normal((N, 3)).astype(np.float32) * 2.0
    species = rng.integers(0, 10, N)
    if arch_id == "dimenet":
        from repro.models.gnn.dimenet import build_triplets
        kj, ji = build_triplets(snd, rcv, N, cap=6 * snd.shape[0])
        kw = dict(trip_kj=jnp.asarray(kj), trip_ji=jnp.asarray(ji))
    return GraphBatch(
        senders=jnp.asarray(snd), receivers=jnp.asarray(rcv), n_node=N,
        species=jnp.asarray(species), positions=jnp.asarray(pos),
        graph_id=jnp.zeros(N, jnp.int32), n_graphs=1,
        labels=jnp.asarray(rng.standard_normal(1).astype(np.float32)),
        node_mask=jnp.asarray(mask), **kw)
