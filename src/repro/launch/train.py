"""End-to-end training CLI.

  python -m repro.launch.train --arch gat-cora --steps 200
  python -m repro.launch.train --arch gemma-2b --smoke --steps 50 \
      --ckpt-dir /tmp/ckpt

Runs the *smoke-scale* config on local devices (CPU here, TPU on a real
pod — same code path: mesh + shardings come from launch/steps.py). The
full-scale configs are exercised via the dry-run; training them requires
the real pod this launcher would be pointed at.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import load_all
from repro.models import dlrm as DL
from repro.models import transformer as T
from repro.models.common import init_params
from repro.train import data
from repro.train.optimizer import OptConfig
from repro.train.trainer import TrainLoopConfig, make_train_step, run_loop


def make_lm_pipeline(cfg, batch: int, seq: int, seed: int):
    def mk(step):
        return {k: jnp.asarray(v) for k, v in
                data.lm_batch(step, batch, seq, cfg.vocab, seed).items()}
    return (lambda p, b: T.loss_fn(p, b, cfg)), T.build_specs(cfg), mk


def make_dlrm_pipeline(cfg, batch: int, seed: int):
    def mk(step):
        return {k: jnp.asarray(v) for k, v in
                data.dlrm_batch(step, batch, cfg.n_dense, cfg.n_sparse,
                                cfg.vocab_per_table, cfg.bag_size,
                                seed).items()}
    return (lambda p, b: DL.loss_fn(p, b, cfg)), DL.build_specs(cfg), mk


def make_gnn_pipeline(entry, cfg, seed: int):
    from repro.launch.gnn_data import build_gnn_batch
    batch = build_gnn_batch(entry.arch_id, cfg, n=400, seed=seed)
    mod = __import__(f"repro.models.gnn.{_mod_name(entry.arch_id)}",
                     fromlist=["loss_fn", "build_specs"])
    return (lambda p, b: mod.loss_fn(p, b, cfg)), mod.build_specs(cfg), \
        (lambda step: batch)


def _mod_name(arch_id: str) -> str:
    return {"gat-cora": "gat", "schnet": "schnet", "nequip": "nequip",
            "dimenet": "dimenet"}[arch_id]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor"])
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    registry = load_all()
    entry = registry[args.arch]
    cfg = entry.smoke_config
    if entry.kind == "lm":
        loss, specs, mk = make_lm_pipeline(cfg, args.batch, args.seq,
                                           args.seed)
    elif entry.kind == "recsys":
        loss, specs, mk = make_dlrm_pipeline(cfg, max(args.batch, 64),
                                             args.seed)
    else:
        loss, specs, mk = make_gnn_pipeline(entry, cfg, args.seed)

    params = init_params(specs, jax.random.key(args.seed))
    init_state, step = make_train_step(
        loss, OptConfig(name=args.optimizer, lr=args.lr),
        microbatches=args.microbatches)
    loop = TrainLoopConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                           ckpt_every=args.ckpt_every,
                           log_every=max(1, args.steps // 10))
    t0 = time.time()
    state, hist = run_loop(init_state, step, mk, params, loop)
    dt = time.time() - t0
    print(f"arch={args.arch} steps={args.steps} wall={dt:.1f}s")
    for s, l in hist["loss"]:
        print(f"  step {s:5d}  loss {l:.4f}")
    first, last = hist["loss"][0][1], hist["loss"][-1][1]
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
