"""Partitioner CLI — the paper's tool as a command.

  python -m repro.launch.partition --family rgg2d --n 20000 --k 16
  python -m repro.launch.partition --family rhg --n 10000 --k 64 \
      --preset strong --compare
  python -m repro.launch.partition ... --devices 8      # distributed
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--family", default="rgg2d")
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--avg-deg", type=float, default=8.0)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--epsilon", type=float, default=0.03)
    ap.add_argument("--preset", default="fast", choices=["fast", "strong"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compare", action="store_true",
                    help="also run plain-MGP and single-level baselines")
    ap.add_argument("--devices", type=int, default=0,
                    help=">0: distributed over forced host devices")
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={args.devices}")

    from repro.core import baselines, metrics
    from repro.core.partitioner import fast_config, partition, strong_config
    from repro.graphs import generators

    g = generators.make(args.family, args.n, args.avg_deg, seed=args.seed)
    cfg = (strong_config if args.preset == "strong" else fast_config)(
        seed=args.seed, epsilon=args.epsilon)
    t0 = time.time()
    if args.devices:
        from repro.dist.dist_partitioner import dist_partition
        part = dist_partition(g, args.k, args.devices, cfg=cfg)
    else:
        part = partition(g, args.k, config=cfg)
    dt = time.time() - t0
    s = metrics.summarize(g, part, args.k, args.epsilon)
    s.update({"algo": f"dkaminpar-{args.preset}", "time_s": round(dt, 3),
              "n": g.n, "m": g.m, "devices": args.devices or 1})
    print(json.dumps(s))
    if args.compare:
        for name, fn in [
                ("plain_mgp", lambda: baselines.plain_mgp(g, args.k)),
                ("single_level_lp",
                 lambda: baselines.single_level_lp(g, args.k))]:
            t0 = time.time()
            p2 = fn()
            s2 = metrics.summarize(g, p2, args.k, args.epsilon)
            s2.update({"algo": name, "time_s": round(time.time() - t0, 3)})
            print(json.dumps(s2))
    return 0 if s["feasible"] else 1


if __name__ == "__main__":
    sys.exit(main())
