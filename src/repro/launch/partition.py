"""Partitioner CLI — the paper's tool as a command, on the `repro.api`
facade.

  python -m repro.launch.partition --family rgg2d --n 20000 --k 16
  python -m repro.launch.partition --family rhg --n 10000 --k 64 \
      --preset strong --compare
  python -m repro.launch.partition ... --devices 8      # distributed
  python -m repro.launch.partition ... --backend dist-grid

Prints one JSON summary line per backend run; exit 0 iff the primary
run is feasible.
"""
from __future__ import annotations

import argparse
import json
import sys

COMPARE_BACKENDS = ["plain_mgp", "single_level_lp"]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--family", default="rgg2d")
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--avg-deg", type=float, default=8.0)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--epsilon", type=float, default=0.03)
    ap.add_argument("--preset", default="fast", choices=["fast", "strong"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="auto",
                    help="registry name (single | dist | dist-grid | "
                         "plain_mgp | single_level_lp) or 'auto'")
    ap.add_argument("--compare", action="store_true",
                    help="also run plain-MGP and single-level baselines "
                         "as backends of the same request")
    ap.add_argument("--devices", type=int, default=0,
                    help=">0: force that many host devices (must happen "
                         "before jax initializes)")
    ap.add_argument("--contraction", default=None,
                    choices=["host", "sharded"],
                    help="dist-backend memory model: gather each level "
                         "(host) or contract in place (sharded) — "
                         "docs/DIST.md")
    ap.add_argument("--weights", default=None,
                    choices=["replicated", "owner"],
                    help="dist-backend weight tables: psum-replicated or "
                         "owner-sharded (O(n/P + k) per PE)")
    ap.add_argument("--balance", default=None,
                    choices=["host", "dist"],
                    help="dist-backend balancer: gather each uncoarsening "
                         "level to the host (host) or run the pooled "
                         "greedy balancer over the level's shards (dist) "
                         "— docs/DIST.md")
    ap.add_argument("--kernel", default=None,
                    choices=["auto", "fused", "composed"],
                    help="hot-loop implementation on any backend: fused "
                         "Pallas kernels or the composed XLA pipeline "
                         "(bit-identical results) — docs/KERNELS.md")
    ap.add_argument("--refine", default=None,
                    choices=["lp", "unconstrained"],
                    help="refinement algorithm on any backend: "
                         "size-constrained LP (default) or the Jet-style "
                         "unconstrained search with afterburner repair "
                         "(better cuts, always feasible) — "
                         "docs/REFINEMENT.md")
    ap.add_argument("--quality", default=None,
                    choices=["fast", "best"],
                    help="serving-facing spelling of --refine (fast=lp, "
                         "best=unconstrained); an explicit --refine wins "
                         "— docs/SERVING.md")
    ap.add_argument("--trace", action="store_true",
                    help="also print the per-level trace records")
    args = ap.parse_args()

    # device forcing first — repro.api.runtime errors cleanly if some
    # earlier import already initialized jax, instead of silently serving
    # a stale device count.
    from repro.api import runtime
    if args.devices:
        runtime.force_host_devices(args.devices)

    from repro.api import GraphSpec, PartitionRequest, Partitioner

    req = PartitionRequest(
        graph=GraphSpec(args.family, args.n, args.avg_deg, seed=args.seed),
        k=args.k, epsilon=args.epsilon, preset=args.preset,
        seed=args.seed, backend=args.backend,
        devices=args.devices or 1,
        contraction=args.contraction, weights=args.weights,
        balance=args.balance, kernel=args.kernel, refine=args.refine,
        quality=args.quality)
    engine = Partitioner()
    res = engine.run(req)
    print(json.dumps(res.summary()))
    if args.trace:
        for rec in res.trace:
            print(json.dumps(rec))
    if args.compare:
        for r in engine.compare(req, COMPARE_BACKENDS):
            print(json.dumps(r.summary()))
    return 0 if res.feasible else 1


if __name__ == "__main__":
    sys.exit(main())
