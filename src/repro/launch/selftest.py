"""Multi-device selftest — run in a subprocess with a forced device count.

Usage:  python -m repro.launch.selftest --devices 8 --test all

Forces the device count through ``repro.api.runtime`` *before* any jax
init (the count locks at first backend creation; the helper raises
instead of silently misconfiguring), then validates the distributed
implementation against the single-process reference: collectives
round-trip, distributed clustering validity (replicated and
owner-sharded weight tables), sharded contraction invariants
(``--test contract``), distributed partition feasibility + quality
under both memory models, both refinement tiers (``--test refine``:
size-constrained LP plus the Jet-style unconstrained pass, which must
end feasible after afterburner repair and be bit-identical across
weight-table layouts), the distributed balancer (``--test balance``:
P=1 bit-identity with the host balancer, adversarial-start feasibility,
sharded cluster-weight enforcement, and the no-host-gather trace
assertion for ``balance="dist"``), grid vs direct all-to-all
equivalence, the ``repro.api`` facade (driver equality, batched
sessions), and the ``repro.serve`` multi-mesh tier (``--test serve``:
a 2-mesh server drains concurrent mixed-size requests bit-identically
to solo runs, a killed worker's request completes via retry on the
other mesh, and deadline expiry surfaces a structured error), and the
shape-bucketed batched dispatch (``--test batch``: a duplicate-heavy
hot mix is served in batches bit-identically to solo runs with
coalescing observed in the metrics, and the stacked level-0 clustering
path — forced on even on CPU hosts — reproduces solo results bit for
bit), and the fused Pallas hot-loop kernels (``--test kernels``, *not* part
of ``all`` — off-TPU they run interpret mode, so the step carries its
own reduced instance: the ``kernel="fused"`` pipeline must reproduce
``"composed"`` labels and cut bit for bit on the host path and under
both distributed memory models), and the cross-process fabric
(``--test fabric``, *not* part of
``all`` because it spawns real worker subprocesses: a front door plus
two worker processes serve bit-identically to solo runs, a SIGKILLed
worker's admitted requests fail over to the survivor, and a SIGTERM
drain finishes in-flight work and answers queued tickets with
structured errors — nothing hangs). Prints one JSON line per test;
exit code 0 iff all pass.
"""
import argparse
import json
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--test", default="all",
                    choices=["all", "collectives", "halo", "cluster",
                             "contract", "partition", "refine", "balance",
                             "smoke", "api", "serve", "batch", "fabric",
                             "kernels", "analysis"])
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--family", default="rgg2d")
    args = ap.parse_args()

    from repro.api import runtime
    runtime.force_host_devices(args.devices)

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as PS

    from repro.core import PartitionerConfig, metrics
    from repro.core.deep_mgp import partition
    from repro.dist.collectives import (direct_all_to_all, grid_all_to_all,
                                        halo_exchange)
    from repro.dist.compat import shard_map
    from repro.dist.dist_lp import dist_cluster, make_mesh_1d
    from repro.dist.dist_partitioner import (dist_partition_impl,
                                             dist_refine_and_balance)
    from repro.graphs import generators
    from repro.graphs.distribute import distribute_graph

    P = args.devices
    assert len(jax.devices()) >= P, jax.devices()
    ok = True

    def report(name, passed, **kw):
        nonlocal ok
        ok &= bool(passed)
        print(json.dumps({"test": name, "pass": bool(passed), **kw}),
              flush=True)

    cfg = PartitionerConfig(contraction_limit=128, ip_repetitions=2,
                            num_chunks=4)
    g = generators.make(args.family, args.n, 8.0, seed=5)

    if args.test in ("all", "collectives", "smoke"):
        mesh = make_mesh_1d(P)
        rng = np.random.default_rng(0)
        slab = rng.integers(0, 1000, size=(P, P, 3)).astype(np.int32)

        def run(fn):
            f = shard_map(lambda s: fn(s[0])[None], mesh=mesh,
                          in_specs=PS("pe"), out_specs=PS("pe"),
                          check_rep=True)
            return np.asarray(jax.jit(f)(jnp.asarray(slab)))

        out_direct = run(lambda s: direct_all_to_all(s, "pe"))
        out_grid = run(lambda s: grid_all_to_all(s, "pe", P))
        # ground truth: out[p, q] == in[q, p]
        want = np.swapaxes(slab, 0, 1)
        report("collectives.direct", np.array_equal(out_direct, want))
        report("collectives.grid", np.array_equal(out_grid, want))

    if args.test in ("all", "halo", "smoke"):
        mesh = make_mesh_1d(P)
        shards = distribute_graph(g, P)
        n, n_loc, n_ghost = g.n, shards.n_loc, shards.n_ghost
        # per-vertex payload: an injective hash of the global id, so a
        # wrong routing cannot collide into a false pass
        f_gid = lambda x: ((x.astype(np.int64) * 40503 + 7) % 65521) \
            .astype(np.int32)
        vals = np.where(shards.local_gid < n, f_gid(shards.local_gid), 0)

        def run_halo(use_grid):
            fn = shard_map(
                lambda v, si, rs: halo_exchange(
                    v[0], si[0], rs[0], n_ghost, "pe", P,
                    use_grid=use_grid)[None],
                mesh=mesh, in_specs=(PS("pe"),) * 3, out_specs=PS("pe"),
                check_rep=True)
            return np.asarray(jax.jit(fn)(
                jnp.asarray(vals), jnp.asarray(shards.send_idx),
                jnp.asarray(shards.recv_slot)))

        got_d = run_halo(False)
        got_g = run_halo(True)
        valid = shards.ghost_gid < n
        want_ghost = f_gid(np.where(valid, shards.ghost_gid, 0))
        ok_d = np.array_equal(got_d[valid], want_ghost[valid])
        ok_g = np.array_equal(got_g[valid], want_ghost[valid])
        report("halo.direct", ok_d, ghosts=int(valid.sum()),
               payload_bytes=shards.comm_bytes_per_halo())
        report("halo.grid_vs_direct", ok_g and
               np.array_equal(got_d, got_g))

    if args.test in ("all", "cluster"):
        from repro.core.coarsening import enforce_cluster_weights
        shards = distribute_graph(g, P)
        W = max(1, int(0.03 * g.total_vweight / args.k))
        labels = dist_cluster(shards, W, num_iterations=3, num_chunks=4,
                              seed=1, use_grid=True)
        raw = labels.copy()
        # driver behaviour: distributed revert is approximate (paper §4 —
        # races bounce weight back); exact enforcement happens before
        # contraction
        labels = enforce_cluster_weights(labels, np.asarray(g.vweights), W)
        cw = np.zeros(g.n + 1, dtype=np.int64)
        np.add.at(cw, labels, g.vweights)
        members = np.bincount(labels, minlength=g.n + 1)
        shrunk = np.unique(labels).size < 0.7 * g.n
        multi_ok = np.all(cw[members > 1] <= W)
        report("cluster.dist", shrunk and multi_ok,
               clusters=int(np.unique(labels).size), n=g.n, W=W,
               max_multi_cw=int(cw[members > 1].max() if
                                (members > 1).any() else 0))
        labels2 = dist_cluster(shards, W, num_iterations=3, num_chunks=4,
                               seed=1, use_grid=False)
        report("cluster.grid_vs_direct",
               np.array_equal(raw, labels2))
        # owner-sharded weight tables apply the same integer arithmetic in
        # the same order as the replicated psum path -> identical labels
        labels3 = dist_cluster(shards, W, num_iterations=3, num_chunks=4,
                               seed=1, use_grid=True, weights="owner")
        report("cluster.owner_vs_replicated",
               np.array_equal(raw, labels3))

    if args.test in ("all", "contract"):
        from repro.core.coarsening import enforce_cluster_weights
        from repro.core.contraction import contract
        from repro.dist.dist_contraction import dist_contract
        shards = distribute_graph(g, P)
        W = max(1, int(0.03 * g.total_vweight / args.k))
        labels = enforce_cluster_weights(
            dist_cluster(shards, W, num_iterations=3, num_chunks=4,
                         seed=1, use_grid=True),
            np.asarray(g.vweights), W)
        res = dist_contract(shards, labels, use_grid=True)
        gc_h, map_h = contract(g, labels)
        gc_d, map_d = res.graph, res.mapping
        # invariants: weight conservation, no self loops, symmetry
        src = gc_d.arc_tails()
        inv_ok = (gc_d.total_vweight == g.total_vweight
                  and bool(np.all(src != gc_d.adjncy)))
        try:
            gc_d.validate()
        except AssertionError:
            inv_ok = False
        # host and sharded contraction agree up to a coarse-id bijection
        pairs = np.unique(np.stack([map_h, map_d], 1), axis=0)
        iso_ok = (gc_d.n == gc_h.n and gc_d.m == gc_h.m
                  and pairs.shape[0] == gc_h.n
                  and np.unique(pairs[:, 0]).size == gc_h.n
                  and np.unique(pairs[:, 1]).size == gc_h.n)
        # cut of any coarse partition == cut of its fine projection
        rng = np.random.default_rng(4)
        pc = rng.integers(0, args.k, size=gc_d.n)
        cut_ok = metrics.edge_cut(gc_d, pc) == \
            metrics.edge_cut(g, pc[map_d])
        report("contract.sharded", inv_ok and iso_ok and cut_ok,
               coarse_m=gc_d.m, **res.stats)
        # grid and direct routing ship identical coarse graphs
        res2 = dist_contract(shards, labels, use_grid=False)
        report("contract.grid_vs_direct",
               np.array_equal(res2.mapping, res.mapping) and
               np.array_equal(res2.graph.indptr, res.graph.indptr) and
               np.array_equal(res2.graph.adjncy, res.graph.adjncy) and
               np.array_equal(res2.graph.eweights, res.graph.eweights))

    if args.test in ("all", "refine"):
        rng = np.random.default_rng(2)
        part0 = rng.integers(0, args.k, size=g.n)
        lmax = np.full(args.k, metrics.l_max(
            g.total_vweight, args.k, 0.03, int(g.vweights.max())),
            dtype=np.int64)
        cut0 = metrics.edge_cut(g, part0)
        part1 = dist_refine_and_balance(g, part0, lmax, P, num_iterations=3,
                                        num_chunks=4, seed=3)
        cut1 = metrics.edge_cut(g, part1)
        feas = metrics.is_feasible(g, part1, args.k, 0.03)
        report("refine.dist", feas and cut1 < cut0, cut_before=cut0,
               cut_after=cut1, feasible=feas)

        # unconstrained tier: penalty-weighted moves + afterburner repair
        # must end feasible and improve the same random start
        part_u = dist_refine_and_balance(g, part0, lmax, P,
                                         num_iterations=3, num_chunks=4,
                                         seed=3, refine="unconstrained")
        cut_u = metrics.edge_cut(g, part_u)
        feas_u = metrics.is_feasible(g, part_u, args.k, 0.03)
        report("refine.unconstrained", feas_u and cut_u < cut0,
               cut_before=cut0, cut_after=cut_u, cut_lp=cut1,
               feasible=feas_u)

        # owner-sharded and replicated weight tables are bit-identical
        # for the unconstrained pass (same dense table at every chunk top)
        from repro.dist.dist_lp import dist_ulp_refine
        shards_r = distribute_graph(g, P)
        u_rep = dist_ulp_refine(shards_r, part0, lmax, num_iterations=3,
                                num_chunks=4, seed=3,
                                weights="replicated")
        u_own = dist_ulp_refine(shards_r, part0, lmax, num_iterations=3,
                                num_chunks=4, seed=3, weights="owner")
        report("refine.unconstrained.owner_vs_replicated",
               np.array_equal(u_rep, u_own))

    if args.test in ("all", "balance"):
        import dataclasses
        from repro.core.balance import rebalance
        from repro.core.coarsening import (ejection_candidates,
                                           enforce_cluster_weights)
        from repro.dist import dist_partitioner as dp
        from repro.dist.dist_balance import (dist_enforce_cluster_weights,
                                             dist_rebalance)

        lmax = np.full(args.k, metrics.l_max(
            g.total_vweight, args.k, 0.03, int(g.vweights.max())),
            dtype=np.int64)
        part0 = np.zeros(g.n, dtype=np.int64)   # adversarial: one block

        # distributed balancer == host balancer, bit for bit, at P=1
        sh1 = distribute_graph(g, 1)
        want = rebalance(g, part0.copy(), lmax, seed=11)
        got = dist_rebalance(sh1, part0.copy(), lmax, seed=11,
                             use_grid=False)
        report("balance.p1_bit_identical", np.array_equal(want, got))

        # P devices: feasibility from the adversarial start, identical
        # labels across routing and weight-table layouts
        shP = distribute_graph(g, P)
        bstats = {}
        fixed = dist_rebalance(shP, part0.copy(), lmax, seed=11,
                               use_grid=True, stats=bstats)
        bw = np.zeros(args.k, dtype=np.int64)
        np.add.at(bw, fixed, g.vweights)
        report("balance.dist_adversarial", bool(np.all(bw <= lmax)),
               rounds=bstats["rounds"], pool_bytes=bstats["pool_bytes"])
        fixed_d = dist_rebalance(shP, part0.copy(), lmax, seed=11,
                                 use_grid=False)
        fixed_o = dist_rebalance(shP, part0.copy(), lmax, seed=11,
                                 use_grid=True, weights="owner")
        report("balance.grid_owner_equal",
               np.array_equal(fixed, fixed_d) and
               np.array_equal(fixed, fixed_o))

        # heterogeneous per-block budgets stay exactly enforced
        lvec = lmax * (1 + (np.arange(args.k) % 2))
        fixed_h = dist_rebalance(shP, part0.copy(), lvec, seed=13,
                                 use_grid=True)
        bwh = np.zeros(args.k, dtype=np.int64)
        np.add.at(bwh, fixed_h, g.vweights)
        report("balance.heterogeneous_lmax", bool(np.all(bwh <= lvec)))

        # sharded cluster-weight enforcement ejects the same vertex set
        # as the host sweep and yields the same clustering up to a
        # relabeling of the fresh singletons
        rng = np.random.default_rng(7)
        labels = rng.integers(0, max(2, args.k), g.n).astype(np.int64)
        W = max(1, int(g.total_vweight / (4 * args.k)))
        lab_d = dist_enforce_cluster_weights(shP, labels, W, use_grid=True)
        ej = ejection_candidates(labels, np.asarray(g.vweights), W)
        same_set = np.array_equal(np.sort(np.flatnonzero(lab_d != labels)),
                                  np.sort(ej))

        def canon(lab):
            _, inv = np.unique(lab, return_inverse=True)
            first = np.full(int(inv.max()) + 1, g.n, dtype=np.int64)
            np.minimum.at(first, inv, np.arange(g.n))
            return first[inv]

        lab_h = enforce_cluster_weights(labels.copy(),
                                        np.asarray(g.vweights), W)
        report("balance.enforce_sharded", same_set and
               np.array_equal(canon(lab_d), canon(lab_h)),
               ejected=int(ej.size))

        # full uncoarsening path with balance="dist": *no* host-side
        # rebalance gather (trace assertion via an instrumented counter),
        # feasible, and within the 1.5x quality bound — both weight-table
        # layouts
        ref_cut = metrics.edge_cut(g, partition(g, args.k, cfg))
        calls = {"n": 0}
        orig_rebalance = dp.rebalance

        def counting_rebalance(*a, **kw):
            calls["n"] += 1
            return orig_rebalance(*a, **kw)

        dp.rebalance = counting_rebalance
        try:
            for wmode in ("replicated", "owner"):
                calls["n"] = 0
                cfg_b = dataclasses.replace(
                    cfg, balance="dist", weights=wmode,
                    contraction="sharded" if wmode == "owner" else "host")
                tr = []
                part_b = dp.dist_partition_impl(g, args.k, P, cfg=cfg_b,
                                                trace=tr)
                s_b = metrics.summarize(g, part_b, args.k, 0.03)
                seeds = [t["seed"] for t in tr
                         if t["phase"] == "dist-uncoarsen"]
                levels = len(seeds)
                report(f"balance.no_host_gather_{wmode}",
                       s_b["feasible"] and calls["n"] == 0 and
                       levels >= 1 and len(set(seeds)) == levels and
                       s_b["cut"] <= max(1.5 * ref_cut, ref_cut + 50),
                       cut=s_b["cut"], ref_cut=ref_cut, levels=levels,
                       host_rebalance_calls=calls["n"])
            # instrumentation sanity: the host mode *does* hit the counter
            calls["n"] = 0
            dp.dist_partition_impl(g, args.k, P, cfg=cfg)
            report("balance.host_gather_counter_sane", calls["n"] >= 1,
                   host_rebalance_calls=calls["n"])
        finally:
            dp.rebalance = orig_rebalance

    if args.test in ("all", "partition"):
        import dataclasses
        part = dist_partition_impl(g, args.k, P, cfg=cfg)
        s = metrics.summarize(g, part, args.k, 0.03)
        ref = partition(g, args.k, cfg)
        cut_ref = metrics.edge_cut(g, ref)
        # distributed quality within 1.5x of the single-process reference
        report("partition.dist", s["feasible"] and
               s["cut"] <= max(1.5 * cut_ref, cut_ref + 50),
               dist=s, ref_cut=cut_ref)
        # fully sharded memory model: in-place contraction + owner-sharded
        # weight tables must stay feasible within the same quality bound
        cfg_sh = dataclasses.replace(cfg, contraction="sharded",
                                     weights="owner")
        part_sh = dist_partition_impl(g, args.k, P, cfg=cfg_sh)
        s_sh = metrics.summarize(g, part_sh, args.k, 0.03)
        report("partition.dist_sharded_owner", s_sh["feasible"] and
               s_sh["cut"] <= max(1.5 * cut_ref, cut_ref + 50),
               dist=s_sh, ref_cut=cut_ref)

    if args.test in ("all", "api"):
        from repro.api import (PartitionRequest, Partitioner,
                               PartitionSession)
        engine = Partitioner()

        # facade(dist-grid) must reproduce the direct driver bit-exactly
        req = PartitionRequest(graph=g, k=args.k, config=cfg,
                               backend="dist-grid", devices=P)
        res = engine.run(req)
        want = dist_partition_impl(g, args.k, P, cfg=cfg, use_grid=True)
        report("api.dist_matches_driver",
               res.feasible and np.array_equal(res.assignment, want),
               cut=res.cut, levels=len(res.trace))

        # feasibility flag must agree with the metrics module
        report("api.feasible_flag",
               res.feasible == metrics.is_feasible(g, res.assignment,
                                                   args.k, 0.03))

        # auto policy routes this (large-enough) graph to a dist backend
        auto = engine.run(PartitionRequest(graph=g, k=args.k, config=cfg,
                                           backend="auto", devices=P))
        report("api.auto_backend", auto.backend in ("dist", "dist-grid"),
               backend=auto.backend)

        # batched session == per-request results, mesh reused across both
        reqs = [PartitionRequest(graph=g, k=kk, config=cfg, backend="dist",
                                 devices=P)
                for kk in (args.k, max(1, args.k // 2))]
        with PartitionSession(devices=P, max_workers=2) as sess:
            batch = sess.run_batch(reqs)
            served = sess.stats()["served"]
        solo = [engine.run(r) for r in reqs]
        same = all(np.array_equal(b.assignment, s.assignment)
                   for b, s in zip(batch, solo))
        report("api.session_batch", same and served == len(reqs),
               served=served,
               cuts=[b.cut for b in batch])

    if args.test in ("all", "serve"):
        import time
        from repro.api import (GraphSpec, PartitionRequest, Partitioner)
        from repro.serve import PartitionServer

        dpm = max(1, P // 2)
        engine = Partitioner()
        # >= 8 concurrent mixed-size requests: three sizes, two k
        # values, and (on multi-device hosts) distributed requests that
        # exercise the second mesh's device slice
        mixed = []
        for i in range(8):
            nn = max(600, args.n // 4) * (1 + i % 3)
            kk = max(2, args.k // 2) * (1 + i % 2)
            dev = dpm if (i % 4 == 3 and dpm > 1) else 1
            mixed.append(PartitionRequest(
                graph=GraphSpec(args.family, nn, 8.0, seed=23 + i % 3),
                k=kk, config=cfg, devices=dev))
        solo = [engine.run(r) for r in mixed]

        # 2-mesh server over disjoint device slices drains the batch
        # bit-identically to solo runs, using both meshes
        with PartitionServer(meshes=2, devices_per_mesh=dpm) as srv:
            results = srv.serve(mixed)
            st = srv.stats()
        same = all(r.ok and np.array_equal(r.result.assignment,
                                           s.assignment)
                   for r, s in zip(results, solo))
        report("serve.bit_identical_mixed",
               same and st["completed"] == len(mixed),
               served=st["per_worker_served"],
               queue_depth_max=st["queue_depth_max"])
        report("serve.both_meshes_used",
               all(c > 0 for c in st["per_worker_served"]),
               served=st["per_worker_served"])

        # a killed worker's requests complete via retry on the other
        # mesh — hold worker 1 at its gate so it provably owns work
        with PartitionServer(meshes=2, devices_per_mesh=dpm) as srv:
            srv.workers[1].hold()
            futs = [srv.submit(r) for r in mixed[:4]]
            t_end = time.monotonic() + 30
            while time.monotonic() < t_end and \
                    srv.workers[1].inflight == 0:
                time.sleep(0.01)
            had_work = srv.workers[1].inflight > 0
            srv.kill_worker(1)
            rs = [f.result(timeout=600) for f in futs]
            st = srv.stats()
        same_k = all(r.ok and np.array_equal(r.result.assignment,
                                             s.assignment)
                     for r, s in zip(rs, solo[:4]))
        report("serve.killed_worker_retry",
               had_work and same_k and st["retried"] >= 1 and
               st["per_worker_served"][1] == 0,
               retried=st["retried"], served=st["per_worker_served"])

        # deadline expiry surfaces a structured error, not a hang
        with PartitionServer(meshes=2, devices_per_mesh=1) as srv:
            for w in srv.workers:
                w.hold()
            fut = srv.submit(mixed[0], deadline_s=0.05)
            time.sleep(0.2)
            for w in srv.workers:
                w.release()
            r = fut.result(timeout=60)
            st = srv.stats()
        report("serve.deadline_error",
               (not r.ok) and r.error == "deadline_exceeded" and
               st["expired"] == 1, error=r.error)

    if args.test in ("all", "batch"):
        import time
        from repro.api import (GraphSpec, PartitionRequest, Partitioner,
                               PartitionSession)
        from repro.serve import PartitionServer, run_coalesced

        engine = Partitioner()
        nn = max(400, args.n // 4)
        distinct = [PartitionRequest(
            graph=GraphSpec(args.family, nn, 8.0, seed=31 + i),
            k=max(2, args.k // 2), config=cfg, backend="single")
            for i in range(4)]
        solo = [engine.run(r) for r in distinct]

        # a duplicate-heavy hot mix piles up behind a held worker, then
        # drains as batches: bit-identical results, coalescing observed
        mix = [distinct[i % 4] for i in range(12)]
        with PartitionServer(meshes=1, batch_max=8,
                             batch_window_ms=50.0) as srv:
            srv.workers[0].hold()
            futs = [srv.submit(r) for r in mix]
            t_end = time.monotonic() + 30
            while time.monotonic() < t_end and \
                    srv.workers[0].inflight == 0:
                time.sleep(0.01)
            srv.workers[0].release()
            rs = [f.result(timeout=600) for f in futs]
            st = srv.stats()
        same = all(r.ok and np.array_equal(r.result.assignment,
                                           solo[i % 4].assignment)
                   for i, r in enumerate(rs))
        report("batch.coalesced_bit_identical",
               same and st["completed"] == len(mix) and
               st["batches"] >= 1 and st["coalesced"] >= 1,
               batches=st["batches"], coalesced=st["coalesced"],
               batch_size_max=st["batch_size_max"])

        # the stacked level-0 kernel path, forced on (the CPU auto-gate
        # would skip it), reproduces solo results bit for bit
        with PartitionSession(devices=1, stack="on") as sess:
            out = run_coalesced(sess, distinct, stack="on")
        report("batch.stacked_bit_identical",
               all(np.array_equal(o.assignment, s.assignment) and
                   o.cut == s.cut for o, s in zip(out, solo)),
               cuts=[o.cut for o in out])

    if args.test == "kernels":
        # fused Pallas hot loops vs the composed XLA pipeline: one knob
        # (PartitionerConfig.kernel), every kernel (lp_move, seg_merge,
        # bal_round), labels AND cut bit-identical — host path and both
        # distributed memory models. Not part of "all": off-TPU the
        # fused path runs Pallas interpret mode, so it gets its own CI
        # step with a reduced instance (docs/KERNELS.md).
        import dataclasses
        nn = max(400, args.n // 4)
        gk = generators.make(args.family, nn, 8.0, seed=13)
        kk = max(2, args.k // 2)
        cfg_k = PartitionerConfig(contraction_limit=80, ip_repetitions=1,
                                  num_chunks=4, seed=3)
        parts = {}
        for mode in ("composed", "fused"):
            parts[mode] = partition(
                gk, kk, dataclasses.replace(cfg_k, kernel=mode))
        cut_f = metrics.edge_cut(gk, parts["fused"])
        report("kernels.host_bit_identical",
               np.array_equal(parts["fused"], parts["composed"]) and
               cut_f == metrics.edge_cut(gk, parts["composed"]),
               cut=cut_f, n=gk.n)
        for name, contraction, weights, balance in (
                ("host_replicated", "host", "replicated", "host"),
                ("sharded_owner", "sharded", "owner", "dist")):
            got = {}
            for mode in ("composed", "fused"):
                cfg_d = dataclasses.replace(
                    cfg_k, contraction=contraction, weights=weights,
                    balance=balance, kernel=mode)
                got[mode] = dist_partition_impl(gk, kk, P, cfg=cfg_d)
            feas = metrics.is_feasible(gk, got["fused"], kk, 0.03)
            report(f"kernels.dist_bit_identical_{name}",
                   np.array_equal(got["fused"], got["composed"]) and feas,
                   cut=metrics.edge_cut(gk, got["fused"]), P=P,
                   feasible=feas)

    if args.test == "analysis":
        # not part of "all": each direction re-imports jax in a fresh
        # subprocess (the verifier forces its own host device count).
        # The static verifier must pass on the repo as committed AND
        # fail on every seeded-violation fixture — both directions, or
        # the CI gate is vacuous (docs/ANALYSIS.md).
        import os
        import subprocess

        import repro

        src_dir = os.path.dirname(os.path.dirname(repro.__file__))
        env = dict(os.environ)
        env["PYTHONPATH"] = src_dir + os.pathsep + \
            env.get("PYTHONPATH", "")
        env.pop("XLA_FLAGS", None)  # verifier forces its own devices

        def run_analysis(*extra):
            return subprocess.run(
                [sys.executable, "-m", "repro.analysis", *extra],
                capture_output=True, text=True, env=env)

        proc = run_analysis()
        report("analysis.repo_clean", proc.returncode == 0,
               tail=proc.stdout.strip().splitlines()[-1:])
        for fx in ("collective", "overflow", "lint", "vmem"):
            proc = run_analysis("--fixture", fx)
            report(f"analysis.fixture_{fx}_fires",
                   proc.returncode != 0,
                   tail=proc.stdout.strip().splitlines()[-1:])

    if args.test == "fabric":
        # not part of "all": spawns real worker subprocesses (each
        # imports jax), so it runs as its own CI step
        import os
        import signal as _signal
        import subprocess
        import time

        import repro
        from repro.api import GraphSpec, PartitionRequest, Partitioner
        from repro.fabric import FabricClient, status_of

        src_dir = os.path.dirname(os.path.dirname(repro.__file__))
        env = dict(os.environ)
        env["PYTHONPATH"] = src_dir + os.pathsep + \
            env.get("PYTHONPATH", "")
        env.pop("XLA_FLAGS", None)  # workers pick their own device count

        def spawn(role, *extra):
            proc = subprocess.Popen(
                [sys.executable, "-m", "repro.launch.fabric", role,
                 *extra],
                stdout=subprocess.PIPE, env=env, text=True)
            ready = json.loads(proc.stdout.readline())
            return proc, ready

        fd_proc, fd_ready = spawn("frontdoor", "--lease-ttl-s", "3.0")
        host, port = fd_ready["host"], fd_ready["port"]
        w_procs = {}
        for i in range(2):
            proc, _ = spawn("worker", "--frontdoor", f"{host}:{port}",
                            "--server-id", f"selftest-w{i}",
                            "--heartbeat-s", "0.3")
            w_procs[f"selftest-w{i}"] = proc
        t_end = time.monotonic() + 60
        while time.monotonic() < t_end and \
                len(status_of(host, port)["servers"]) < 2:
            time.sleep(0.1)
        regs = [s["server_id"] for s in status_of(host, port)["servers"]]
        report("fabric.registered", sorted(regs) ==
               ["selftest-w0", "selftest-w1"], servers=regs)

        engine = Partitioner()
        nn = max(600, args.n // 4)
        mixed = [PartitionRequest(
            graph=GraphSpec(args.family, nn * (1 + i % 2), 8.0,
                            seed=41 + i % 3),
            k=max(2, args.k // 2) * (1 + i % 2), config=cfg)
            for i in range(6)]
        solo = [engine.run(r) for r in mixed]
        try:
            with FabricClient(host, port) as client:
                rs = client.serve(mixed)
                same = all(r.ok and np.array_equal(r.assignment,
                                                   s.assignment)
                           for r, s in zip(rs, solo))
                report("fabric.bit_identical_2proc",
                       same and {r.server for r in rs} ==
                       set(w_procs), servers=sorted(
                           {str(r.server) for r in rs}))

                # SIGKILL one worker while it provably owns a request:
                # every admitted ticket must still resolve ok via
                # failover to the survivor — none may hang
                slow = [PartitionRequest(
                    graph=GraphSpec(args.family, max(2000, args.n // 2),
                                    8.0, seed=51 + i % 2),
                    k=args.k, config=cfg) for i in range(6)]
                slow_solo = [engine.run(r) for r in slow]
                futs = [client.submit(r) for r in slow]
                victim = None
                t_end = time.monotonic() + 60
                while victim is None and time.monotonic() < t_end:
                    for s in status_of(host, port)["servers"]:
                        if s.get("inflight", 0) > 0:
                            victim = s["server_id"]
                            break
                    time.sleep(0.02)
                report("fabric.victim_had_work", victim is not None,
                       victim=victim)
                w_procs[victim].send_signal(_signal.SIGKILL)
                rs = [f.result(timeout=600) for f in futs]
                survivor = next(s for s in w_procs if s != victim)
                same = all(r.ok and np.array_equal(r.assignment,
                                                   s.assignment)
                           for r, s in zip(rs, slow_solo))
                retried = sum(1 for r in rs if r.attempts > 1)
                report("fabric.sigkill_failover",
                       same and retried >= 1 and
                       all(r.server == survivor for r in rs),
                       retried=retried,
                       attempts=[r.attempts for r in rs])

                # SIGTERM drain of the survivor: the in-flight request
                # finishes ok, queued ones resolve with a structured
                # error (deadline at the latest) — nothing hangs
                # let the survivor heartbeat an idle window first:
                # worker_inflight below must come from *our* submissions,
                # not a stale renewal from the failover phase
                time.sleep(0.8)
                futs = [client.submit(r, deadline_s=20.0)
                        for r in slow[:4]]
                # wait for the attempt to be running on the worker's
                # own mesh (heartbeated back), not merely dispatched —
                # a merely-queued ticket legitimately drains to a
                # server_closed error instead of finishing
                t_end = time.monotonic() + 60
                while time.monotonic() < t_end and not any(
                        s.get("worker_inflight", 0) > 0
                        for s in status_of(host, port)["servers"]):
                    time.sleep(0.02)
                w_procs[survivor].send_signal(_signal.SIGTERM)
                rs = [f.result(timeout=600) for f in futs]
                w_procs[survivor].wait(timeout=120)
                n_ok = sum(1 for r in rs if r.ok)
                structured = all(
                    r.ok or r.error in ("server_closed", "worker_failed",
                                        "no_worker", "deadline_exceeded")
                    for r in rs)
                report("fabric.sigterm_drain",
                       n_ok >= 1 and structured,
                       ok=n_ok, errors=[r.error for r in rs if not r.ok])
        finally:
            for proc in w_procs.values():
                if proc.poll() is None:
                    proc.kill()
            fd_proc.send_signal(_signal.SIGTERM)
            try:
                fd_proc.wait(timeout=30)
            except subprocess.TimeoutExpired:
                fd_proc.kill()

    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
