"""Serving-tier CLI — drive a multi-mesh ``PartitionServer``.

  python -m repro.launch.serve --meshes 2 --devices-per-mesh 2 \
      --requests 12 --n 4000 --k 8
  python -m repro.launch.serve --meshes 2 --requests 16 --verify
  python -m repro.launch.serve ... --offered-rate 8   # paced admission

Generates a mixed request set (sizes, k, single + distributed), serves
it through the admission queue, prints one JSON summary line per
result and a final stats line. ``--verify`` re-runs every request solo
through ``repro.api.Partitioner`` and asserts bit-identical
assignments. Exit 0 iff every request succeeded (and verified).
"""

from __future__ import annotations

import argparse
import json
import sys
import time


def build_requests(args):
    """A deterministic mixed workload: three sizes, two k values,
    single-device and (when the server has multi-device meshes)
    distributed requests."""
    from repro.api import GraphSpec, PartitionRequest
    from repro.core import PartitionerConfig

    cfg = PartitionerConfig(
        contraction_limit=128, ip_repetitions=2, num_chunks=4)
    reqs = []
    for i in range(args.requests):
        n = args.n // 2 * (1 + i % 3)           # n/2, n, 3n/2
        k = args.k * (1 + i % 2)                # k, 2k
        devices = args.devices_per_mesh if i % 4 == 3 else 1
        reqs.append(PartitionRequest(
            graph=GraphSpec(args.family, n, 8.0, seed=11 + i % 5),
            k=k, config=cfg, devices=devices, collect_trace=False))
    return reqs


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--meshes", type=int, default=2)
    ap.add_argument("--devices-per-mesh", type=int, default=1)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--family", default="rgg2d")
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--offered-rate", type=float, default=0.0,
                    help="requests/s admission pacing (0 = burst)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request completion deadline")
    ap.add_argument("--verify", action="store_true",
                    help="assert bit-identity against solo runs")
    args = ap.parse_args()

    # device forcing first, before any jax init (errors cleanly if an
    # earlier import already initialized a backend)
    from repro.api import runtime
    if args.devices_per_mesh > 1:
        runtime.force_host_devices(args.meshes * args.devices_per_mesh)

    from repro.serve import PartitionServer

    reqs = build_requests(args)
    t0 = time.perf_counter()
    with PartitionServer(meshes=args.meshes,
                         devices_per_mesh=args.devices_per_mesh) as srv:
        futures = []
        for i, r in enumerate(reqs):
            futures.append(srv.submit(r, priority=i % 2,
                                      deadline_s=args.deadline_s))
            if args.offered_rate > 0:
                time.sleep(1.0 / args.offered_rate)
        results = [f.result() for f in futures]
        stats = srv.stats()
    wall = time.perf_counter() - t0

    ok = all(r.ok for r in results)
    for r in results:
        print(json.dumps(r.summary()), flush=True)

    if args.verify:
        import numpy as np
        from repro.api import Partitioner
        engine = Partitioner()
        for r, req in zip(results, reqs):
            if not r.ok:
                continue
            solo = engine.run(req)
            if not np.array_equal(r.result.assignment, solo.assignment):
                print(json.dumps({"verify": "MISMATCH",
                                  "k": req.k, "n": req.graph.n}))
                ok = False
        print(json.dumps({"verify": "bit-identical" if ok else "failed"}))

    stats["wall_s"] = round(wall, 3)
    stats["throughput_rps"] = round(len(results) / max(wall, 1e-9), 3)
    print(json.dumps({"stats": stats}), flush=True)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
