"""Step builders: (ArchEntry, ShapeSpec, mesh) -> jit-able step function +
abstract inputs + input shardings. Shared by dryrun, train and serve CLIs.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as PS

from ..configs import ArchEntry, ShapeSpec
from ..dist.sharding import DEFAULT_RULES, ShardCtx, resolve_axes, \
    spec_shardings
from ..models import dlrm as DL
from ..models import transformer as T
from ..models.common import abstract_params, param_count
from ..models.gnn import dimenet as DN
from ..models.gnn import gat as GT
from ..models.gnn import nequip as NQ
from ..models.gnn import schnet as SN
from ..models.gnn.common import GraphBatch
from ..train.optimizer import OptConfig
from ..train.trainer import make_train_step

GNN_MODULES = {"gat-cora": GT, "schnet": SN, "nequip": NQ, "dimenet": DN}


@dataclasses.dataclass
class BuiltStep:
    name: str
    fn: Callable
    args: Tuple            # abstract (ShapeDtypeStruct) args
    in_shardings: Tuple
    model_flops: float     # analytic MODEL_FLOPS for §Roofline
    opt_name: str = ""


def _repl(mesh):
    return NamedSharding(mesh, PS())


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _shard_like(mesh, shape, *axes):
    return NamedSharding(mesh, resolve_axes(shape, axes, mesh,
                                            DEFAULT_RULES))


def _tree_repl(mesh, tree):
    return jax.tree_util.tree_map(lambda _: _repl(mesh), tree)


def _opt_shardings(opt_name: str, specs, param_sh, mesh,
                   min_dim_factored: int = 128):
    from ..models.common import is_spec
    if opt_name == "adamw":
        return {"m": param_sh, "v": param_sh,
                "step": _repl(mesh)}

    # adafactor: factored slots drop one dim — shard with the remaining
    # logical axes of the ParamSpec (a replicated vr for a 480B MoE stack
    # is ~1 GB/device of waste)
    def one(s):
        if len(s.shape) >= 2 and s.shape[-1] >= min_dim_factored \
                and s.shape[-2] >= min_dim_factored:
            return {"vr": _shard_like(mesh, s.shape[:-1], *s.axes[:-1]),
                    "vc": _shard_like(mesh, s.shape[:-2] + s.shape[-1:],
                                      *(s.axes[:-2] + s.axes[-1:]))}
        return {"v": _shard_like(mesh, s.shape, *s.axes)}
    slots = jax.tree_util.tree_map(one, specs, is_leaf=is_spec)
    return {"slots": slots, "step": _repl(mesh)}


def _state_pack(mesh, specs, loss, opt_name: str, microbatches: int = 1,
                accum_dtype=None):
    opt_cfg = OptConfig(name=opt_name, lr=1e-3)
    init_state, train_step = make_train_step(loss, opt_cfg,
                                             microbatches=microbatches,
                                             accum_dtype=accum_dtype)
    params_abs = abstract_params(specs)
    state_abs = jax.eval_shape(init_state, params_abs)
    param_sh = spec_shardings(specs, mesh)
    state_sh = {"params": param_sh,
                "opt": _opt_shardings(opt_name, specs, param_sh, mesh),
                "step": _repl(mesh), "nan_skips": _repl(mesh)}
    return train_step, state_abs, state_sh


# ---------------------------------------------------------------------------
# LM steps
# ---------------------------------------------------------------------------

def _lm_model_flops(cfg: T.TransformerConfig, tokens: int,
                    decode: bool = False, ctx_len: int = 0) -> float:
    """6·N_active·D (+ attention KV term for decode)."""
    d, L = cfg.d_model, cfg.n_layers
    ffn_mult = 3 if cfg.glu else 2
    dense = ffn_mult * d * cfg.d_ff if (cfg.moe_dense_residual or
                                        not cfg.moe) else 0
    moe = ffn_mult * d * cfg.expert_ff * cfg.top_k if cfg.moe else 0
    n_active = L * (d * (cfg.q_dim + 2 * cfg.kv_dim) + cfg.q_dim * d
                    + dense + moe) + 2 * cfg.vocab * d
    flops = 6.0 * n_active * tokens
    if decode:
        # attention reads: 2 * L * ctx * (q_dim + ...) MACs per token
        flops += tokens * L * 4.0 * ctx_len * cfg.kv_dim \
            * (cfg.n_heads // cfg.n_kv_heads + 1)
    return flops


def build_lm_train(entry: ArchEntry, shape: ShapeSpec, mesh) -> BuiltStep:
    cfg: T.TransformerConfig = entry.config
    B, S = shape.params["global_batch"], shape.params["seq_len"]
    ctx = ShardCtx(mesh)
    specs = T.build_specs(cfg)
    n_params = param_count(specs)
    big = n_params > 5e9
    opt_name = "adafactor" if big else "adamw"
    # gradient accumulation keeps activation transients inside HBM
    # (EXPERIMENTS.md §Perf); FSDP-sharded f32 accumulators are cheap
    accum_dtype = None
    if n_params > 1e11:
        microbatches = 8
        accum_dtype = jnp.bfloat16   # halves the FSDP accumulator slab
    elif n_params > 1e9 or B * S > 2**21:
        microbatches = 2
    else:
        microbatches = 1
    loss = lambda p, b: T.loss_fn(p, b, cfg, ctx)
    train_step, state_abs, state_sh = _state_pack(
        mesh, specs, loss, opt_name, microbatches, accum_dtype)
    batch = {"tokens": _sds((B, S), jnp.int32)}
    batch_sh = {"tokens": _shard_like(mesh, (B, S), "batch", "seq")}
    return BuiltStep(
        name=f"{entry.arch_id}/{shape.name}", fn=train_step,
        args=(state_abs, batch), in_shardings=(state_sh, batch_sh),
        model_flops=_lm_model_flops(cfg, B * S),  # 6·N·D (fwd+bwd)
        opt_name=opt_name)


def build_lm_prefill(entry: ArchEntry, shape: ShapeSpec, mesh) -> BuiltStep:
    cfg: T.TransformerConfig = entry.config
    B, S = shape.params["global_batch"], shape.params["seq_len"]
    ctx = ShardCtx(mesh)
    specs = T.build_specs(cfg)
    params_abs = abstract_params(specs)
    param_sh = spec_shardings(specs, mesh)

    def prefill(params, tokens):
        logits, _ = T.forward(params, tokens, cfg, ctx)
        return logits[:, -1]

    tokens = _sds((B, S), jnp.int32)
    tok_sh = _shard_like(mesh, (B, S), "batch", "seq")
    return BuiltStep(
        name=f"{entry.arch_id}/{shape.name}", fn=prefill,
        args=(params_abs, tokens), in_shardings=(param_sh, tok_sh),
        model_flops=_lm_model_flops(cfg, B * S) / 3.0 * 1.0)


def build_lm_decode(entry: ArchEntry, shape: ShapeSpec, mesh,
                    long_context: bool = False) -> BuiltStep:
    cfg: T.TransformerConfig = entry.config
    B, S_ctx = shape.params["global_batch"], shape.params["seq_len"]
    ctx = ShardCtx(mesh)
    specs = T.build_specs(cfg)
    params_abs = abstract_params(specs)
    param_sh = spec_shardings(specs, mesh)
    cspecs = T.cache_specs(cfg, B, S_ctx, long_context=long_context)
    cache_abs = abstract_params(cspecs)
    cache_sh = spec_shardings(cspecs, mesh)

    def step(params, cache, tokens, cache_len):
        return T.decode_step(params, cache, tokens, cache_len, cfg, ctx)

    args = (params_abs, cache_abs, _sds((B,), jnp.int32),
            _sds((B,), jnp.int32))
    in_sh = (param_sh, cache_sh,
             _shard_like(mesh, (B,), "batch"),
             _shard_like(mesh, (B,), "batch"))
    return BuiltStep(
        name=f"{entry.arch_id}/{shape.name}", fn=step, args=args,
        in_shardings=in_sh,
        model_flops=_lm_model_flops(cfg, B, decode=True, ctx_len=S_ctx)
        / 3.0)


# ---------------------------------------------------------------------------
# GNN steps
# ---------------------------------------------------------------------------

def _gnn_batch_abs(entry, cfg, shape: ShapeSpec, mesh):
    p = shape.params
    n_pad, e_pad = p["n_pad"], p["e_pad"]
    n_graphs = p.get("batch", 1)
    batch = {
        "senders": _sds((e_pad,), jnp.int32),
        "receivers": _sds((e_pad,), jnp.int32),
        "node_mask": _sds((n_pad,), jnp.bool_),
    }
    sh = {
        "senders": _shard_like(mesh, (e_pad,), "edges"),
        "receivers": _shard_like(mesh, (e_pad,), "edges"),
        "node_mask": _shard_like(mesh, (n_pad,), "nodes"),
    }
    if entry.arch_id == "gat-cora":
        d_feat = p.get("d_feat", 602 if shape.kind == "gnn_minibatch"
                       else 16)
        batch["node_feat"] = _sds((n_pad, d_feat), jnp.float32)
        batch["labels"] = _sds((n_pad,), jnp.int32)
        sh["node_feat"] = _shard_like(mesh, (n_pad, d_feat), "nodes", "feat")
        sh["labels"] = _shard_like(mesh, (n_pad,), "nodes")
    else:
        batch["species"] = _sds((n_pad,), jnp.int32)
        batch["positions"] = _sds((n_pad, 3), jnp.float32)
        batch["graph_id"] = _sds((n_pad,), jnp.int32)
        batch["labels"] = _sds((n_graphs,), jnp.float32)
        sh["species"] = _shard_like(mesh, (n_pad,), "nodes")
        sh["positions"] = _shard_like(mesh, (n_pad, 3), "nodes", None)
        sh["graph_id"] = _shard_like(mesh, (n_pad,), "nodes")
        sh["labels"] = _repl(mesh)
    if entry.arch_id == "dimenet":
        t_pad = 2 * e_pad
        batch["trip_kj"] = _sds((t_pad,), jnp.int32)
        batch["trip_ji"] = _sds((t_pad,), jnp.int32)
        sh["trip_kj"] = _shard_like(mesh, (t_pad,), "edges")
        sh["trip_ji"] = _shard_like(mesh, (t_pad,), "edges")
    return batch, sh, n_pad, n_graphs


def _gnn_loss(entry, cfg, n_pad, n_graphs, ctx):
    mod = GNN_MODULES[entry.arch_id]

    def loss(params, batch):
        gb = GraphBatch(
            senders=batch["senders"], receivers=batch["receivers"],
            n_node=n_pad, node_feat=batch.get("node_feat"),
            species=batch.get("species"), positions=batch.get("positions"),
            graph_id=batch.get("graph_id"), n_graphs=n_graphs,
            labels=batch["labels"], node_mask=batch["node_mask"],
            trip_kj=batch.get("trip_kj"), trip_ji=batch.get("trip_ji"))
        return mod.loss_fn(params, gb, cfg, ctx)
    return loss


def _gnn_model_flops(entry, cfg, shape: ShapeSpec) -> float:
    p = shape.params
    e = p["e_pad"]
    n = p["n_pad"]
    if entry.arch_id == "gat-cora":
        d = p.get("d_feat", 16)
        per_edge = 4 * cfg.n_heads * cfg.d_hidden
        per_node = 2 * d * cfg.n_heads * cfg.d_hidden
        return 3.0 * cfg.n_layers * (e * per_edge + n * per_node)
    if entry.arch_id == "schnet":
        per_edge = 2 * cfg.n_rbf * cfg.d_hidden + 2 * cfg.d_hidden ** 2 \
            + 2 * cfg.d_hidden
        per_node = 6 * cfg.d_hidden ** 2
        return 3.0 * cfg.n_interactions * (e * per_edge + n * per_node)
    if entry.arch_id == "nequip":
        C = cfg.d_hidden
        per_edge = 50 * C * 9        # ~paths x cartesian contraction cost
        per_node = 6 * C * C * 9
        return 3.0 * cfg.n_layers * (e * per_edge + n * per_node)
    if entry.arch_id == "dimenet":
        t = 2 * e
        d = cfg.d_hidden
        per_t = 2 * d * cfg.n_bilinear
        per_e = 8 * d * d
        return 3.0 * cfg.n_blocks * (t * per_t + e * per_e)
    return 0.0


def build_gnn_train(entry: ArchEntry, shape: ShapeSpec, mesh) -> BuiltStep:
    cfg = entry.config
    if entry.arch_id == "gat-cora":
        d_feat = shape.params.get("d_feat",
                                  602 if shape.kind == "gnn_minibatch"
                                  else 16)
        cfg = dataclasses.replace(cfg, d_in=d_feat)
    mod = GNN_MODULES[entry.arch_id]
    specs = mod.build_specs(cfg)
    batch, batch_sh, n_pad, n_graphs = _gnn_batch_abs(entry, cfg, shape,
                                                      mesh)
    loss = _gnn_loss(entry, cfg, n_pad, n_graphs, ShardCtx(mesh))
    train_step, state_abs, state_sh = _state_pack(mesh, specs, loss,
                                                  "adamw")
    return BuiltStep(
        name=f"{entry.arch_id}/{shape.name}", fn=train_step,
        args=(state_abs, batch), in_shardings=(state_sh, batch_sh),
        model_flops=_gnn_model_flops(entry, cfg, shape), opt_name="adamw")


# ---------------------------------------------------------------------------
# RecSys steps
# ---------------------------------------------------------------------------

def _dlrm_batch_abs(cfg: DL.DLRMConfig, B: int, mesh):
    batch = {"dense": _sds((B, cfg.n_dense), jnp.float32),
             "sparse": _sds((B, cfg.n_sparse, cfg.bag_size), jnp.int32),
             "labels": _sds((B,), jnp.float32)}
    sh = {"dense": _shard_like(mesh, (B, cfg.n_dense), "batch", None),
          "sparse": _shard_like(mesh, (B, cfg.n_sparse, cfg.bag_size),
                                "batch", None, None),
          "labels": _shard_like(mesh, (B,), "batch")}
    return batch, sh


def _dlrm_model_flops(cfg: DL.DLRMConfig, B: int, train: bool) -> float:
    mlp = 0
    dims = [cfg.n_dense] + list(cfg.bot_mlp)
    mlp += sum(2 * a * b for a, b in zip(dims[:-1], dims[1:]))
    dims = [cfg.n_interact + cfg.bot_mlp[-1]] + list(cfg.top_mlp)
    mlp += sum(2 * a * b for a, b in zip(dims[:-1], dims[1:]))
    inter = 2 * (cfg.n_sparse + 1) ** 2 * cfg.embed_dim
    lookup = 2 * cfg.n_sparse * cfg.bag_size * cfg.embed_dim
    per_ex = mlp + inter + lookup
    return (3.0 if train else 1.0) * B * per_ex


def build_dlrm_train(entry: ArchEntry, shape: ShapeSpec, mesh) -> BuiltStep:
    cfg: DL.DLRMConfig = entry.config
    B = shape.params["batch"]
    ctx = ShardCtx(mesh)
    specs = DL.build_specs(cfg)
    loss = lambda p, b: DL.loss_fn(p, b, cfg, ctx)
    train_step, state_abs, state_sh = _state_pack(mesh, specs, loss,
                                                  "adamw")
    batch, batch_sh = _dlrm_batch_abs(cfg, B, mesh)
    return BuiltStep(
        name=f"{entry.arch_id}/{shape.name}", fn=train_step,
        args=(state_abs, batch), in_shardings=(state_sh, batch_sh),
        model_flops=_dlrm_model_flops(cfg, B, True), opt_name="adamw")


def build_dlrm_serve(entry: ArchEntry, shape: ShapeSpec, mesh) -> BuiltStep:
    cfg: DL.DLRMConfig = entry.config
    B = shape.params["batch"]
    ctx = ShardCtx(mesh)
    specs = DL.build_specs(cfg)
    params_abs = abstract_params(specs)
    param_sh = spec_shardings(specs, mesh)
    batch, batch_sh = _dlrm_batch_abs(cfg, B, mesh)
    del batch["labels"], batch_sh["labels"]

    def serve(params, batch):
        return jax.nn.sigmoid(DL.forward(params, batch, cfg, ctx))

    return BuiltStep(
        name=f"{entry.arch_id}/{shape.name}", fn=serve,
        args=(params_abs, batch), in_shardings=(param_sh, batch_sh),
        model_flops=_dlrm_model_flops(cfg, B, False))


def build_dlrm_retrieval(entry: ArchEntry, shape: ShapeSpec,
                         mesh) -> BuiltStep:
    cfg: DL.DLRMConfig = entry.config
    B, Nc = shape.params["batch"], shape.params["n_candidates"]
    ctx = ShardCtx(mesh)
    specs = DL.build_specs(cfg)
    params_abs = abstract_params(specs)
    param_sh = spec_shardings(specs, mesh)
    batch = {"dense": _sds((B, cfg.n_dense), jnp.float32),
             "sparse": _sds((B, cfg.n_sparse, cfg.bag_size), jnp.int32),
             "candidates": _sds((Nc, cfg.embed_dim), jnp.float32)}
    sh = {"dense": _repl(mesh), "sparse": _repl(mesh),
          "candidates": _shard_like(mesh, (Nc, cfg.embed_dim),
                                    "nodes", None)}

    def retrieve(params, batch):
        return DL.retrieval_score(params, batch, cfg, ctx, top_k=100)

    return BuiltStep(
        name=f"{entry.arch_id}/{shape.name}", fn=retrieve,
        args=(params_abs, batch), in_shardings=(param_sh, sh),
        model_flops=2.0 * Nc * cfg.embed_dim)


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------

def build_step(entry: ArchEntry, shape_name: str, mesh) -> BuiltStep:
    shape = entry.shape(shape_name)
    if shape.kind == "train":
        return build_lm_train(entry, shape, mesh)
    if shape.kind == "prefill":
        return build_lm_prefill(entry, shape, mesh)
    if shape.kind == "decode":
        return build_lm_decode(entry, shape, mesh)
    if shape.kind == "long_decode":
        return build_lm_decode(entry, shape, mesh, long_context=True)
    if shape.kind in ("gnn_full", "gnn_minibatch", "gnn_molecule"):
        return build_gnn_train(entry, shape, mesh)
    if shape.kind == "recsys_train":
        return build_dlrm_train(entry, shape, mesh)
    if shape.kind == "recsys_serve":
        return build_dlrm_serve(entry, shape, mesh)
    if shape.kind == "recsys_retrieval":
        return build_dlrm_retrieval(entry, shape, mesh)
    raise ValueError(shape.kind)
