"""Fabric CLI — run the cross-process serving tier.

  # terminal 1: the front door (routing + registry + autoscaler)
  python -m repro.launch.fabric frontdoor --port 7070

  # terminals 2..N: worker processes (each a whole PartitionServer)
  python -m repro.launch.fabric worker --frontdoor 127.0.0.1:7070 \
      --meshes 2 --devices-per-mesh 1

  # anywhere: fleet status as JSON
  python -m repro.launch.fabric status --frontdoor 127.0.0.1:7070

Every role prints one JSON "ready" line on stdout once it is
listening (machine-readable: the selftest, the bench and the
autoscaler's ``ProcessScaler`` all coordinate on it), then serves
until SIGTERM/SIGINT — which drains gracefully: no new admissions,
in-flight work finishes, queued tickets resolve ``server_closed``.

On real multi-host topologies a worker can join a ``jax.distributed``
process group first: ``--coordinator host:port --num-processes N
--process-id I`` (or the ``REPRO_COORDINATOR`` etc. environment
variables) feed ``repro.api.runtime.distributed_init`` before any jax
computation.
"""

from __future__ import annotations

import argparse
import json
import signal
import sys
import threading


def _addr(s: str):
    host, _, port = s.rpartition(":")
    if not host or not port.isdigit():
        raise argparse.ArgumentTypeError(
            f"expected HOST:PORT, got {s!r}")
    return host, int(port)


def _ready(role: str, **fields) -> None:
    print(json.dumps({"op": "ready", "role": role, **fields}),
          flush=True)


def _run_frontdoor(args) -> int:
    from repro.fabric import AutoscaleConfig, FrontDoor

    autoscale = None
    if args.autoscale:
        autoscale = AutoscaleConfig(
            min_workers=args.min_workers, max_workers=args.max_workers,
            grow_queue_depth=args.grow_queue_depth,
            grow_windows=args.grow_windows,
            shrink_windows=args.shrink_windows,
            eval_period_s=args.eval_period_s)
    fd = FrontDoor(host=args.host, port=args.port,
                   lease_ttl_s=args.lease_ttl_s,
                   max_queue=args.max_queue,
                   max_retries=args.max_retries,
                   autoscale=autoscale,
                   worker_args=args.worker_args.split()
                   if args.worker_args else None)
    _ready("frontdoor", host=fd.host, port=fd.port,
           autoscale=bool(autoscale))
    stop = threading.Event()
    signal.signal(signal.SIGTERM, lambda *a: stop.set())
    signal.signal(signal.SIGINT, lambda *a: stop.set())
    stop.wait()
    fd.close()
    return 0


def _run_worker(args) -> int:
    # runtime setup strictly before any jax computation: the
    # multi-process group first (no-op in single-process mode), then
    # host-device faking for multi-device meshes on CPU
    from repro.api import runtime
    info = runtime.distributed_init(
        coordinator_address=args.coordinator,
        num_processes=args.num_processes, process_id=args.process_id)
    if args.devices_per_mesh > 1 and info["mode"] == "single-process":
        runtime.force_host_devices(args.meshes * args.devices_per_mesh)

    from repro.fabric import FabricWorker

    worker = FabricWorker(
        frontdoor=args.frontdoor, host=args.host, port=args.port,
        server_id=args.server_id, meshes=args.meshes,
        devices_per_mesh=args.devices_per_mesh, backend=args.backend,
        heartbeat_s=args.heartbeat_s, max_queue=args.max_queue)
    worker.install_signal_handlers()
    _ready("worker", server_id=worker.server_id, host=worker.host,
           port=worker.port, meshes=worker.meshes,
           devices=worker.devices_per_mesh, runtime=info)
    worker.wait()
    return 0


def _run_status(args) -> int:
    from repro.fabric import status_of

    st = status_of(*args.frontdoor, timeout=args.timeout)
    print(json.dumps(st, indent=None if args.compact else 2))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.launch.fabric")
    sub = ap.add_subparsers(dest="role", required=True)

    fdp = sub.add_parser("frontdoor", help="run the RPC front door")
    fdp.add_argument("--host", default="127.0.0.1")
    fdp.add_argument("--port", type=int, default=0,
                     help="0 picks an ephemeral port (see ready line)")
    fdp.add_argument("--lease-ttl-s", type=float, default=5.0)
    fdp.add_argument("--max-queue", type=int, default=1024)
    fdp.add_argument("--max-retries", type=int, default=1)
    fdp.add_argument("--autoscale", action="store_true",
                     help="own a local worker fleet sized by pressure")
    fdp.add_argument("--min-workers", type=int, default=1)
    fdp.add_argument("--max-workers", type=int, default=2)
    fdp.add_argument("--grow-queue-depth", type=float, default=2.0)
    fdp.add_argument("--grow-windows", type=int, default=2)
    fdp.add_argument("--shrink-windows", type=int, default=4)
    fdp.add_argument("--eval-period-s", type=float, default=0.5)
    fdp.add_argument("--worker-args", default="",
                     help="extra args for autoscaled workers, e.g. "
                          "'--meshes 2'")
    fdp.set_defaults(run=_run_frontdoor)

    wp = sub.add_parser("worker", help="run one PartitionServer process")
    wp.add_argument("--frontdoor", type=_addr, default=None,
                    help="front door HOST:PORT to register with")
    wp.add_argument("--host", default="127.0.0.1")
    wp.add_argument("--port", type=int, default=0)
    wp.add_argument("--server-id", default=None)
    wp.add_argument("--meshes", type=int, default=1)
    wp.add_argument("--devices-per-mesh", type=int, default=1)
    wp.add_argument("--backend", default=None)
    wp.add_argument("--heartbeat-s", type=float, default=1.0)
    wp.add_argument("--max-queue", type=int, default=1024)
    wp.add_argument("--coordinator", default=None,
                    help="jax.distributed coordinator HOST:PORT")
    wp.add_argument("--num-processes", type=int, default=None)
    wp.add_argument("--process-id", type=int, default=None)
    wp.set_defaults(run=_run_worker)

    sp = sub.add_parser("status", help="query a front door")
    sp.add_argument("--frontdoor", type=_addr, required=True)
    sp.add_argument("--timeout", type=float, default=10.0)
    sp.add_argument("--compact", action="store_true")
    sp.set_defaults(run=_run_status)

    args = ap.parse_args(argv)
    return args.run(args)


if __name__ == "__main__":
    sys.exit(main())
