"""Production mesh builders (functions, not module constants — importing
this module never touches jax device state)."""
from __future__ import annotations

from ..dist.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: 16x16 = 256 chips ('data', 'model'); multi-pod adds a
    leading 'pod' axis (2 pods = 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(p: int):
    """1D 'pe' mesh over p local (or forced-host) devices — alias of the
    mesh the distributed partitioner builds internally."""
    from ..dist.dist_lp import make_mesh_1d
    return make_mesh_1d(p)
