"""Production mesh builders (functions, not module constants — importing
this module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: 16x16 = 256 chips ('data', 'model'); multi-pod adds a
    leading 'pod' axis (2 pods = 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(p: int):
    """1D 'pe' mesh over p local (or forced-host) devices — used by the
    distributed partitioner and its tests."""
    return jax.make_mesh((p,), ("pe",),
                         axis_types=(jax.sharding.AxisType.Auto,))
