"""Decoder-only transformer LM: dense + MoE, GQA/MQA, RoPE, GLU FFNs.

One definition serves all five assigned LM architectures. Layers are
stacked (leading 'stack' axis) and applied with lax.scan + optional remat
so 35-layer/480B configs lower to a single compiled layer body.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..dist.sharding import NULL_CTX, ShardCtx
from .common import (ParamSpec, act_fn, cross_entropy_loss, rms_norm, rope)


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str = "lm"
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    vocab: int = 1024
    glu: bool = True                  # gated FFN (SwiGLU/GeGLU)
    activation: str = "silu"          # silu -> SwiGLU, gelu_tanh -> GeGLU
    qkv_bias: bool = False            # qwen2
    tied_embeddings: bool = False     # gemma
    rope_theta: float = 10000.0
    # MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    moe_dense_residual: bool = False  # arctic: dense FFN + MoE in parallel
    moe_d_ff: int = 0                 # per-expert hidden (defaults to d_ff)
    # numerics / memory
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    scan_layers: bool = True          # False: unrolled (accurate HLO cost)
    logit_softcap: float = 0.0

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def expert_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def vocab_pad(self) -> int:
        """Vocab rounded to a multiple of 256 so the vocab dim always
        shards over the model axis (unsharded fp32 logits were the top
        memory consumer on odd-vocab configs — EXPERIMENTS.md §Perf).
        Padded logit columns are masked with -inf in forward/decode."""
        return -(-self.vocab // 256) * 256


def build_specs(cfg: TransformerConfig) -> Dict[str, Any]:
    L, d, pd = cfg.n_layers, cfg.d_model, cfg.param_dtype
    ffn_mult = 2 if cfg.glu else 1

    def P(shape, axes, **kw):
        return ParamSpec(tuple(shape), tuple(axes), dtype=pd, **kw)

    layer: Dict[str, Any] = {
        "ln_attn": P((L, d), ("stack", "embed"), init="zeros"),
        "ln_ffn": P((L, d), ("stack", "embed"), init="zeros"),
        "wq": P((L, d, cfg.n_heads, cfg.head_dim),
                ("stack", "embed", "heads", "head_dim")),
        "wk": P((L, d, cfg.n_kv_heads, cfg.head_dim),
                ("stack", "embed", "kv_heads", "head_dim")),
        "wv": P((L, d, cfg.n_kv_heads, cfg.head_dim),
                ("stack", "embed", "kv_heads", "head_dim")),
        "wo": P((L, cfg.n_heads, cfg.head_dim, d),
                ("stack", "heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        layer["bq"] = P((L, cfg.n_heads, cfg.head_dim),
                        ("stack", "heads", "head_dim"), init="zeros")
        layer["bk"] = P((L, cfg.n_kv_heads, cfg.head_dim),
                        ("stack", "kv_heads", "head_dim"), init="zeros")
        layer["bv"] = P((L, cfg.n_kv_heads, cfg.head_dim),
                        ("stack", "kv_heads", "head_dim"), init="zeros")
    dense_ffn = cfg.moe_dense_residual or not cfg.moe
    if dense_ffn:
        layer["w_in"] = P((L, d, ffn_mult, cfg.d_ff),
                          ("stack", "embed", None, "mlp"))
        layer["w_out"] = P((L, cfg.d_ff, d), ("stack", "mlp", "embed"))
    if cfg.moe:
        E, f = cfg.n_experts, cfg.expert_ff
        layer["router"] = P((L, d, E), ("stack", "embed", "expert"))
        layer["e_in"] = P((L, E, d, ffn_mult, f),
                          ("stack", "expert", "embed", None, "mlp"))
        layer["e_out"] = P((L, E, f, d), ("stack", "expert", "mlp", "embed"))
    specs: Dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab_pad, d), ("vocab", "embed"),
                           init="embed", scale=0.02, dtype=pd),
        "ln_f": P((d,), ("embed",), init="zeros"),
        "layers": layer,
    }
    if not cfg.tied_embeddings:
        specs["head"] = P((d, cfg.vocab_pad), ("embed", "vocab"))
    return specs


# ---------------------------------------------------------------------------
# MoE layer (sort-based dispatch, static capacity)
# ---------------------------------------------------------------------------

def moe_ffn(lp, x, cfg: TransformerConfig, ctx: ShardCtx):
    """x: (T, d) -> (T, d), plus load-balancing aux loss.

    Group-local dispatch (GShard-style): tokens are blocked into G groups
    matching the data sharding, the expert sort/scatter happens *within*
    each group (vmapped — no cross-shard traffic), and the only
    communication is the (G, E, ...) <-> (E, G, ...) reshard around the
    expert einsum, which GSPMD lowers to the expert-parallel all_to_all.
    A naive global argsort instead makes XLA all-gather every token
    (measured: 242 GB/device of all-reduce on granite-1b — see
    EXPERIMENTS.md §Perf)."""
    T, d = x.shape
    E, k, f = cfg.n_experts, cfg.top_k, cfg.expert_ff
    G = ctx.data_groups()
    while T % G:
        G //= 2
    Tg = T // G
    cap = max(1, int(math.ceil(Tg * k * cfg.capacity_factor / E)))
    logits = (x.astype(jnp.float32) @ lp["router"].astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)                  # (T, E)
    topw, topi = jax.lax.top_k(gates, k)                     # (T, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    # aux loss (Switch): E * <fraction routed to e> . <mean gate e>
    me = jnp.mean(gates, axis=0)
    ce = jnp.mean(jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)

    xg = ctx.constrain(x.reshape(G, Tg, d), "batch", None, "embed")
    eid = topi.reshape(G, Tg * k)                            # (G, Tg*k)
    wsg = topw.reshape(G, Tg * k)
    tokid = jnp.arange(Tg * k, dtype=jnp.int32) // k         # (Tg*k,)

    # All heavy data movement below is expressed as row *gathers*; the
    # only scatters carry scalar int32 slot ids. (Scattering the (cap, d)
    # payload directly materializes full-shape u32 index temps in XLA —
    # 4x 4.7 GB/device on arctic-480b; EXPERIMENTS.md §Perf.)
    def group_plan(eid_g):
        order = jnp.argsort(eid_g, stable=True)
        s_eid = eid_g[order]
        start = jnp.searchsorted(s_eid, s_eid, side="left")
        rank = jnp.arange(Tg * k, dtype=jnp.int32) - start
        keep = rank < cap
        slot = jnp.where(keep, s_eid * cap + rank, E * cap)  # (Tg*k,)
        # slot -> source token (scalar scatter), sentinel row E*cap
        src_tok = jnp.full((E * cap + 1,), Tg, jnp.int32) \
            .at[slot].set(tokid[order], mode="drop")[:E * cap]
        # expanded position -> its slot (for the gather-based combine)
        slot_of = jnp.zeros((Tg * k,), jnp.int32) \
            .at[order].set(slot)                             # (Tg*k,)
        return src_tok, slot_of

    src_tok, slot_of = jax.vmap(group_plan)(eid)             # (G, E*cap) ...

    def group_gather(xg_g, src_tok_g):
        xp = jnp.concatenate([xg_g, jnp.zeros((1, d), xg_g.dtype)])
        return xp[src_tok_g].reshape(E, cap, d)

    buf = jax.vmap(group_gather)(xg, src_tok)                # (G, E, cap, d)
    buf = jnp.swapaxes(buf, 0, 1)                            # (E, G, cap, d)
    buf = ctx.constrain(buf, "expert", "batch", None, "embed")

    w_in = lp["e_in"].astype(cfg.compute_dtype)              # (E, d, g, f)
    w_out = lp["e_out"].astype(cfg.compute_dtype)            # (E, f, d)
    h = jnp.einsum("egcd,edif->egcif", buf, w_in)
    if cfg.glu:
        h = act_fn(cfg.activation)(h[..., 0, :]) * h[..., 1, :]
    else:
        h = act_fn(cfg.activation)(h[..., 0, :])
    out_buf = jnp.einsum("egcf,efd->egcd", h, w_out)         # (E, G, cap, d)
    out_buf = ctx.constrain(out_buf, "expert", "batch", None, "embed")
    out_buf = jnp.swapaxes(out_buf, 0, 1)                    # (G, E, cap, d)
    out_buf = ctx.constrain(out_buf, "batch", "expert", None, "embed")

    def group_combine(ob_g, slot_of_g, ws_g):
        flat = jnp.concatenate([ob_g.reshape(E * cap, d),
                                jnp.zeros((1, d), ob_g.dtype)])
        rows = flat[slot_of_g]                               # (Tg*k, d)
        rows = rows * ws_g.astype(rows.dtype)[:, None]
        return rows.reshape(Tg, k, d).sum(axis=1)

    y = jax.vmap(group_combine)(out_buf, slot_of, wsg)       # (G, Tg, d)
    y = ctx.constrain(y, "batch", None, "embed")
    return y.reshape(T, d), aux


def dense_ffn(lp, x, cfg: TransformerConfig):
    w_in = lp["w_in"].astype(cfg.compute_dtype)              # (d, g, f)
    w_out = lp["w_out"].astype(cfg.compute_dtype)            # (f, d)
    h = jnp.einsum("td,dgf->tgf", x, w_in)
    if cfg.glu:
        h = act_fn(cfg.activation)(h[:, 0]) * h[:, 1]
    else:
        h = act_fn(cfg.activation)(h[:, 0])
    return h @ w_out


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attention(lp, x, positions, cfg: TransformerConfig, ctx: ShardCtx,
              kv_cache: Optional[Tuple] = None,
              cache_len: Optional[jnp.ndarray] = None):
    """x: (B, S, d). With kv_cache=(k,v) of (B, S_ctx, Hkv, hd) performs
    decode (queries attend to cache + self)."""
    B, S, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cd = cfg.compute_dtype
    q = jnp.einsum("bsd,dhq->bshq", x, lp["wq"].astype(cd))
    k = jnp.einsum("bsd,dhq->bshq", x, lp["wk"].astype(cd))
    v = jnp.einsum("bsd,dhq->bshq", x, lp["wv"].astype(cd))
    if cfg.qkv_bias:
        q = q + lp["bq"].astype(cd)
        k = k + lp["bk"].astype(cd)
        v = v + lp["bv"].astype(cd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = ctx.constrain(q, "batch", "seq", "heads", "head_dim")
    k = ctx.constrain(k, "batch", "seq", "kv_heads", "head_dim")

    new_kv = (k, v)
    rep = H // Hkv
    if kv_cache is None:
        out = _blockwise_self_attention(q, k, v, positions, cfg, ctx)
    else:
        ck, cv = kv_cache                                    # (B, Sc, Hkv, hd)
        k = jnp.concatenate([ck.astype(cd), k], axis=1)
        v = jnp.concatenate([cv.astype(cd), v], axis=1)
        S_kv = k.shape[1]
        qg = q.reshape(B, S, Hkv, rep, hd)
        scores = jnp.einsum("bshrd,bthd->bhrst", qg, k,
                            preferred_element_type=jnp.float32)
        scores = scores / math.sqrt(hd)
        # cache slots 0..cache_len-1 are valid history; the S fresh slots
        # (appended at the end) are causal among themselves
        S_c = S_kv - S
        valid_cache = jnp.broadcast_to(
            jnp.arange(S_c)[None, None, :] < cache_len[:, None, None],
            (B, S, S_c))
        valid_new = jnp.broadcast_to(
            jnp.arange(S)[None, None, :] <= jnp.arange(S)[None, :, None],
            (B, S, S))
        mask = jnp.concatenate([valid_cache, valid_new], axis=2)
        scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(cd)
        out = jnp.einsum("bhrst,bthd->bshrd", probs, v)
        out = out.reshape(B, S, H, hd)
    y = jnp.einsum("bshq,hqd->bsd", out, lp["wo"].astype(cd))
    return y, new_kv


def _blockwise_self_attention(q, k, v, positions, cfg: TransformerConfig,
                              ctx: ShardCtx, kv_block: int = 1024):
    """Causal self-attention with a running-softmax scan over KV blocks
    (flash semantics in pure JAX): the (S, S) score matrix is never
    materialized — per step only (B, Sq, Hkv, rep, blk). The query seq
    dim is sequence-parallel over the model axis ('act_seq'); K/V blocks
    are gathered (Hkv*hd wide — small)."""
    B, S, Hkv, hd = k.shape
    H = q.shape[2]
    rep = H // Hkv
    cd = q.dtype
    blk = min(kv_block, S)
    while S % blk:
        blk //= 2
    nb = S // blk
    qg = q.reshape(B, S, Hkv, rep, hd)
    qg = ctx.constrain(qg, "batch", "act_seq", "kv_heads", None, None)
    scale = 1.0 / math.sqrt(hd)
    kb = k.reshape(B, nb, blk, Hkv, hd).swapaxes(0, 1)     # (nb,B,blk,Hkv,hd)
    vb = v.reshape(B, nb, blk, Hkv, hd).swapaxes(0, 1)
    posb = positions.reshape(B, nb, blk).swapaxes(0, 1)    # (nb, B, blk)
    q_pos = positions                                       # (B, S)

    def body(carry, xs):
        m, l, acc = carry
        kk, vv, pp = xs
        s = jnp.einsum("bshrd,bkhd->bshrk", qg, kk,
                       preferred_element_type=jnp.float32) * scale
        mask = q_pos[:, :, None] >= pp[:, None, :]          # (B, S, blk)
        s = jnp.where(mask[:, :, None, None, :], s, -1e30)
        m2 = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m2[..., None])
        corr = jnp.exp(m - m2)
        l2 = l * corr + jnp.sum(p, axis=-1)
        acc2 = acc * corr[..., None] + jnp.einsum(
            "bshrk,bkhd->bshrd", p.astype(cd), vv,
            preferred_element_type=jnp.float32)
        return (m2, l2, acc2), ()

    m0 = jnp.full((B, S, Hkv, rep), -1e30, jnp.float32)
    l0 = jnp.zeros((B, S, Hkv, rep), jnp.float32)
    a0 = jnp.zeros((B, S, Hkv, rep, hd), jnp.float32)
    # remat the per-block body: otherwise the bwd pass saves the f32
    # scores/probs for EVERY kv block (measured 12+ GB/device on
    # stablelm-12b train_4k — EXPERIMENTS.md §Perf)
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), (m0, l0, a0),
                                  (kb, vb, posb))
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(cd)
    out = out.reshape(B, S, H, hd)
    return ctx.constrain(out, "batch", "act_seq", None, None)


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def _vocab_pad_bias(cfg: TransformerConfig, dtype):
    if cfg.vocab_pad == cfg.vocab:
        return jnp.zeros((cfg.vocab_pad,), dtype)
    return jnp.where(jnp.arange(cfg.vocab_pad) < cfg.vocab, 0.0,
                     -1e30).astype(dtype)


def _layer_fn(lp, x, positions, cfg, ctx):
    B, S, d = x.shape
    h, _ = attention(lp, rms_norm(x, lp["ln_attn"]), positions, cfg, ctx)
    x = x + h
    # sequence-parallel residual: the scan-carried activation is sharded
    # over (batch -> data, seq -> model) so remat residuals fit HBM
    x = ctx.constrain(x, "batch", "act_seq", "embed")
    hin = rms_norm(x, lp["ln_ffn"]).reshape(B * S, d)
    aux = jnp.zeros((), jnp.float32)
    out = jnp.zeros_like(hin)
    if cfg.moe:
        mo, aux = moe_ffn(lp, hin, cfg, ctx)
        out = out + mo
    if cfg.moe_dense_residual or not cfg.moe:
        out = out + dense_ffn(lp, hin, cfg)
    x = x + out.reshape(B, S, d)
    x = ctx.constrain(x, "batch", "act_seq", "embed")
    return x, aux


def forward(params, tokens, cfg: TransformerConfig,
            ctx: ShardCtx = NULL_CTX, positions=None):
    """tokens: (B, S) -> logits (B, S, V); returns (logits, aux_loss)."""
    B, S = tokens.shape
    cd = cfg.compute_dtype
    x = params["embed"].astype(cd)[tokens] * math.sqrt(cfg.d_model)
    x = ctx.constrain(x, "batch", "act_seq", "embed")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    layer_fn = _layer_fn
    if cfg.remat:
        layer_fn = jax.checkpoint(
            _layer_fn, policy=jax.checkpoint_policies.nothing_saveable,
            static_argnums=(3, 4))

    if cfg.scan_layers:
        def body(carry, lp):
            x, aux = carry
            x, a = layer_fn(lp, x, positions, cfg, ctx)
            return (x, aux + a), ()

        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["layers"])
    else:
        aux = jnp.zeros((), jnp.float32)
        for li in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda p: p[li], params["layers"])
            x, a = layer_fn(lp, x, positions, cfg, ctx)
            aux = aux + a
    x = rms_norm(x, params["ln_f"])
    head = (params["embed"].T if cfg.tied_embeddings else params["head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cd))
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    logits = logits + _vocab_pad_bias(cfg, logits.dtype)
    logits = ctx.constrain(logits, "batch", "seq", "vocab")
    return logits, aux


def loss_fn(params, batch, cfg: TransformerConfig, ctx: ShardCtx = NULL_CTX):
    logits, aux = forward(params, batch["tokens"], cfg, ctx)
    loss = cross_entropy_loss(logits[:, :-1], batch["tokens"][:, 1:],
                              mask=batch.get("mask", None))
    return loss + 0.01 * aux


# ---------------------------------------------------------------------------
# serving (KV-cache decode)
# ---------------------------------------------------------------------------

def cache_specs(cfg: TransformerConfig, batch: int, max_len: int,
                long_context: bool = False):
    """KV cache as ParamSpecs so the launch layer can shard it. For
    long-context serving the sequence dim is sharded over the mesh."""
    seq_ax = "kv_seq" if long_context else "seq"
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    axes = ("stack", "batch", seq_ax, "kv_heads", "head_dim")
    return {
        "k": ParamSpec(shape, axes, init="zeros", dtype=cfg.compute_dtype),
        "v": ParamSpec(shape, axes, init="zeros", dtype=cfg.compute_dtype),
    }


def decode_step(params, cache, tokens, cache_len, cfg: TransformerConfig,
                ctx: ShardCtx = NULL_CTX):
    """One decode step. tokens: (B,) int32; cache_len: (B,) current length.
    Returns (logits (B, V), new_cache). The new token's K/V is written at
    position cache_len (static-shape dynamic_update via one-hot scatter so
    the op shards cleanly over a sequence-sharded cache)."""
    B = tokens.shape[0]
    cd = cfg.compute_dtype
    x = params["embed"].astype(cd)[tokens][:, None, :] * math.sqrt(cfg.d_model)
    positions = cache_len[:, None]

    def body(carry, xs):
        x, li = carry
        lp, ck, cv = xs
        h, (nk, nv) = attention(lp, rms_norm(x, lp["ln_attn"]), positions,
                                cfg, ctx, kv_cache=(ck, cv),
                                cache_len=cache_len)
        x = x + h
        hin = rms_norm(x, lp["ln_ffn"]).reshape(B, -1)
        out = jnp.zeros_like(hin)
        if cfg.moe:
            mo, _ = moe_ffn(lp, hin, cfg, ctx)
            out = out + mo
        if cfg.moe_dense_residual or not cfg.moe:
            out = out + dense_ffn(lp, hin, cfg)
        x = x + out.reshape(B, 1, -1)
        # scatter new kv at cache_len via one-hot (shards over kv_seq)
        S_max = ck.shape[1]
        oh = jax.nn.one_hot(cache_len, S_max, dtype=cd)      # (B, S_max)
        ck = ck + jnp.einsum("bs,bhd->bshd", oh, nk[:, 0])
        cv = cv + jnp.einsum("bs,bhd->bshd", oh, nv[:, 0])
        return (x, li + 1), (ck, cv)

    if cfg.scan_layers:
        (x, _), (nk, nv) = jax.lax.scan(
            body, (x, 0), (params["layers"], cache["k"], cache["v"]))
    else:
        nks, nvs = [], []
        for li in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda p: p[li], params["layers"])
            (x, _), (ck2, cv2) = body((x, li),
                                      (lp, cache["k"][li], cache["v"][li]))
            nks.append(ck2)
            nvs.append(cv2)
        nk, nv = jnp.stack(nks), jnp.stack(nvs)
    x = rms_norm(x, params["ln_f"])
    head = (params["embed"].T if cfg.tied_embeddings else params["head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(cd))[:, 0]
    logits = logits + _vocab_pad_bias(cfg, logits.dtype)
    return logits, {"k": nk, "v": nv}
