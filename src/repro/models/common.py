"""Model substrate: parameter specs with logical sharding axes, init,
and the tiny set of NN ops everything reuses (pure JAX, no flax).

Every parameter is declared as a ``ParamSpec`` carrying its *logical*
axes ('embed', 'mlp', 'heads', 'vocab', 'expert', ...). The launch layer
maps logical axes -> mesh axes through per-config rules
(dist/sharding.py), falling back to replication when a dim is not
divisible by the mesh axis — the planner never produces an invalid
sharding.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]          # logical axis per dim
    init: str = "normal"                     # normal | zeros | ones | embed
    scale: float = 1.0
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


SpecTree = Any     # nested dict of ParamSpec
ParamTree = Any    # nested dict of jnp.ndarray


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn: Callable[[ParamSpec], Any], specs: SpecTree):
    return jax.tree_util.tree_map(fn, specs,
                                  is_leaf=is_spec)


def init_params(specs: SpecTree, key: jax.Array) -> ParamTree:
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))

    def one(spec: ParamSpec, k):
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, spec.dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, spec.dtype)
        fan_in = spec.shape[0] if len(spec.shape) >= 2 else \
            max(1, int(np.prod(spec.shape)))
        if spec.init == "embed":
            std = spec.scale
        else:
            std = spec.scale / math.sqrt(fan_in)
        return (jax.random.normal(k, spec.shape, jnp.float32) * std
                ).astype(spec.dtype)

    return jax.tree_util.tree_unflatten(
        treedef, [one(s, k) for s, k in zip(leaves, keys)])


def abstract_params(specs: SpecTree):
    """ShapeDtypeStructs for dry-run lowering (no allocation)."""
    return tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs)


def param_count(specs: SpecTree) -> int:
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=is_spec)
    return sum(int(np.prod(s.shape)) for s in leaves)


# ---------------------------------------------------------------------------
# ops
# ---------------------------------------------------------------------------

def rms_norm(x, gamma, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gamma.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * gamma + beta).astype(x.dtype)


_ACT = {
    "gelu": jax.nn.gelu,
    "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
    "silu": jax.nn.silu,
    "relu": jax.nn.relu,
    "ssp": lambda x: jax.nn.softplus(x) - math.log(2.0),   # shifted softplus
    "tanh": jnp.tanh,
}


def act_fn(name: str):
    return _ACT[name]


def rope(x, positions, theta: float = 10000.0):
    """Rotary embedding. x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs    # (..., S, half)
    ang = ang[..., None, :]                                   # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


def cross_entropy_loss(logits, labels, mask=None, z_loss: float = 0.0):
    """Stable CE in fp32; optional z-loss (log-sum-exp regularizer)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    if mask is not None:
        return jnp.sum(loss * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(loss)
