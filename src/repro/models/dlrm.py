"""DLRM (Naumov et al., arXiv:1906.00091) — RM2-class config.

13 dense features -> bottom MLP 13-512-256-64; 26 sparse features ->
EmbeddingBag lookups (sum-pooled multi-hot); dot-product feature
interaction; top MLP 512-512-256-1.

JAX has no native EmbeddingBag — it is built here from ``jnp.take`` +
``jax.ops.segment_sum`` (the system requirement, see kernel taxonomy
§RecSys). The embedding tables are the hot path and are sharded over the
'model' axis by the placement engine (placement/dlrm_placement.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..dist.sharding import NULL_CTX, ShardCtx
from .common import ParamSpec


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-rm2"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 64
    vocab_per_table: int = 1_000_000
    bag_size: int = 1                   # multi-hot indices per feature
    bot_mlp: Tuple[int, ...] = (512, 256, 64)
    top_mlp: Tuple[int, ...] = (512, 512, 256, 1)
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32

    @property
    def n_interact(self) -> int:
        f = self.n_sparse + 1
        return f * (f - 1) // 2


def build_specs(cfg: DLRMConfig) -> Dict[str, Any]:
    specs: Dict[str, Any] = {
        # one stacked tensor for all tables: (n_tables, vocab, dim)
        "tables": ParamSpec((cfg.n_sparse, cfg.vocab_per_table,
                             cfg.embed_dim),
                            ("expert", "table", "table_dim"),
                            init="embed", scale=0.01, dtype=cfg.param_dtype),
    }
    dims = [cfg.n_dense] + list(cfg.bot_mlp)
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        specs[f"bot_w{i}"] = ParamSpec((a, b), (None, "mlp"),
                                       dtype=cfg.param_dtype)
        specs[f"bot_b{i}"] = ParamSpec((b,), ("mlp",), init="zeros",
                                       dtype=cfg.param_dtype)
    d_top_in = cfg.n_interact + cfg.bot_mlp[-1]
    dims = [d_top_in] + list(cfg.top_mlp)
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        specs[f"top_w{i}"] = ParamSpec((a, b), (None, "mlp"),
                                       dtype=cfg.param_dtype)
        specs[f"top_b{i}"] = ParamSpec((b,), ("mlp",), init="zeros",
                                       dtype=cfg.param_dtype)
    return specs


def embedding_bag(table, idx, weights=None, mode: str = "sum"):
    """table: (V, D); idx: (B, bag); -> (B, D). Sum/mean pooling via
    take + reduce (segment_sum over the bag dim is a reshape-reduce here
    because bags are fixed-size)."""
    rows = jnp.take(table, idx, axis=0)           # (B, bag, D)
    if weights is not None:
        rows = rows * weights[..., None]
    out = rows.sum(axis=1)
    if mode == "mean":
        out = out / idx.shape[1]
    return out


def _mlp(params, prefix, n, x, final_act=None):
    for i in range(n):
        x = x @ params[f"{prefix}_w{i}"] + params[f"{prefix}_b{i}"]
        if i < n - 1:
            x = jax.nn.relu(x)
        elif final_act is not None:
            x = final_act(x)
    return x


def forward(params, batch, cfg: DLRMConfig, ctx: ShardCtx = NULL_CTX):
    """batch: dense (B, 13) float, sparse (B, 26, bag) int32.
    Returns logits (B,)."""
    dense, sparse = batch["dense"], batch["sparse"]
    cd = cfg.compute_dtype
    bot = _mlp(params, "bot", len(cfg.bot_mlp), dense.astype(cd),
               final_act=jax.nn.relu)                       # (B, 64)
    bot = ctx.constrain(bot, "batch", None)

    # EmbeddingBag over all 26 tables (vmap over the table axis)
    def one_table(tab, ix):
        return embedding_bag(tab.astype(cd), ix)
    emb = jax.vmap(one_table, in_axes=(0, 1), out_axes=1)(
        params["tables"], sparse)                            # (B, 26, D)
    emb = ctx.constrain(emb, "batch", None, None)

    feats = jnp.concatenate([bot[:, None, :], emb], axis=1)  # (B, 27, D)
    inter = jnp.einsum("bnd,bmd->bnm", feats, feats)         # (B, 27, 27)
    iu, ju = jnp.triu_indices(feats.shape[1], k=1)
    flat = inter[:, iu, ju]                                  # (B, 351)
    top_in = jnp.concatenate([flat, bot], axis=-1)
    logits = _mlp(params, "top", len(cfg.top_mlp), top_in)   # (B, 1)
    return logits[:, 0]


def loss_fn(params, batch, cfg: DLRMConfig, ctx: ShardCtx = NULL_CTX):
    logits = forward(params, batch, cfg, ctx)
    y = batch["labels"].astype(jnp.float32)
    z = logits.astype(jnp.float32)
    # stable BCE-with-logits
    loss = jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z)))
    return jnp.mean(loss)


def retrieval_score(params, batch, cfg: DLRMConfig,
                    ctx: ShardCtx = NULL_CTX, top_k: int = 100):
    """Retrieval-scoring path: one query (dense + sparse profile) against
    ``n_candidates`` precomputed candidate vectors — a single batched dot
    + top-k, never a loop."""
    dense, sparse = batch["dense"], batch["sparse"]          # (1, ...)
    cand = batch["candidates"]                               # (Nc, D)
    cd = cfg.compute_dtype
    bot = _mlp(params, "bot", len(cfg.bot_mlp), dense.astype(cd),
               final_act=jax.nn.relu)

    def one_table(tab, ix):
        return embedding_bag(tab.astype(cd), ix)
    emb = jax.vmap(one_table, in_axes=(0, 1), out_axes=1)(
        params["tables"], sparse)
    user = bot + emb.sum(axis=1)                             # (1, D)
    scores = (cand.astype(cd) @ user[0]).astype(jnp.float32)  # (Nc,)
    vals, idx = jax.lax.top_k(scores, top_k)
    return vals, idx
