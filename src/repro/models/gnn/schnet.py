"""SchNet (Schütt et al., arXiv:1706.08566) — continuous-filter conv.

Config: 3 interactions, d_hidden=64, 300 gaussian RBFs, cutoff 10 Å.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax.numpy as jnp

from ...dist.sharding import NULL_CTX, ShardCtx
from ..common import ParamSpec, act_fn
from .common import (GraphBatch, cosine_cutoff, edge_vectors, gaussian_rbf,
                     scatter_sum)


@dataclasses.dataclass(frozen=True)
class SchNetConfig:
    name: str = "schnet"
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    n_species: int = 100


def build_specs(cfg: SchNetConfig) -> Dict[str, Any]:
    d, r = cfg.d_hidden, cfg.n_rbf
    specs: Dict[str, Any] = {
        "embed": ParamSpec((cfg.n_species, d), (None, "feat"),
                           init="embed", scale=1.0),
    }
    for i in range(cfg.n_interactions):
        specs.update({
            f"i{i}_fw0": ParamSpec((r, d), (None, "feat")),
            f"i{i}_fb0": ParamSpec((d,), ("feat",), init="zeros"),
            f"i{i}_fw1": ParamSpec((d, d), ("feat", "feat")),
            f"i{i}_fb1": ParamSpec((d,), ("feat",), init="zeros"),
            f"i{i}_in_w": ParamSpec((d, d), ("feat", "feat")),
            f"i{i}_out_w0": ParamSpec((d, d), ("feat", "feat")),
            f"i{i}_out_b0": ParamSpec((d,), ("feat",), init="zeros"),
            f"i{i}_out_w1": ParamSpec((d, d), ("feat", "feat")),
            f"i{i}_out_b1": ParamSpec((d,), ("feat",), init="zeros"),
        })
    specs.update({
        "ro_w0": ParamSpec((d, d // 2), ("feat", None)),
        "ro_b0": ParamSpec((d // 2,), (None,), init="zeros"),
        "ro_w1": ParamSpec((d // 2, 1), (None, None)),
        "ro_b1": ParamSpec((1,), (None,), init="zeros"),
    })
    return specs


def forward(params, batch: GraphBatch, cfg: SchNetConfig,
            ctx: ShardCtx = NULL_CTX):
    """Returns per-graph energies (n_graphs,)."""
    ssp = act_fn("ssp")
    N = batch.n_node
    x = params["embed"][batch.species]                      # (N, d)
    rij, d, emask = edge_vectors(batch)
    rbf = gaussian_rbf(d, cfg.n_rbf, cfg.cutoff)            # (E, R)
    rbf = ctx.constrain(rbf, "edges", None)
    fc = cosine_cutoff(d, cfg.cutoff) * emask               # (E,)
    snd, rcv = batch.senders, batch.receivers
    for i in range(cfg.n_interactions):
        w = ssp(rbf @ params[f"i{i}_fw0"] + params[f"i{i}_fb0"])
        w = (w @ params[f"i{i}_fw1"] + params[f"i{i}_fb1"]) * fc[:, None]
        h = x @ params[f"i{i}_in_w"]                        # atomwise
        msg = ctx.constrain(h[snd] * w, "edges", None)     # cfconv filter
        agg = ctx.constrain(scatter_sum(msg, rcv, N), "nodes", None)
        v = ssp(agg @ params[f"i{i}_out_w0"] + params[f"i{i}_out_b0"])
        v = v @ params[f"i{i}_out_w1"] + params[f"i{i}_out_b1"]
        x = ctx.constrain(x + v, "nodes", None)
    e_atom = ssp(x @ params["ro_w0"] + params["ro_b0"])
    e_atom = e_atom @ params["ro_w1"] + params["ro_b1"]      # (N, 1)
    gid = batch.graph_id if batch.graph_id is not None else \
        jnp.zeros(N, jnp.int32)
    mask = batch.node_mask if batch.node_mask is not None else \
        jnp.ones(N, bool)
    e_atom = jnp.where(mask[:, None], e_atom, 0.0)
    return scatter_sum(e_atom[:, 0], gid, batch.n_graphs)


def loss_fn(params, batch: GraphBatch, cfg: SchNetConfig,
            ctx: ShardCtx = NULL_CTX):
    energies = forward(params, batch, cfg, ctx)
    return jnp.mean(jnp.square(energies - batch.labels))
