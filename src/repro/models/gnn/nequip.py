"""NequIP (Batzner et al., arXiv:2101.03164) — E(3)-equivariant
interatomic potential with tensor-product message passing, l_max = 2.

Adaptation (DESIGN.md §8): irreps are carried in *Cartesian* form —
l=0 scalars (N, C), l=1 vectors (N, C, 3), l=2 traceless symmetric
matrices (N, C, 3, 3) — instead of the spherical-harmonic basis. The O(3)
content for l <= 2 is identical and every Clebsch-Gordan path below is an
explicit Cartesian contraction, which makes equivariance directly
testable with rotation matrices (vectors -> Rv, tensors -> R T R^T).

Config: 5 layers, multiplicity 32, 8 Bessel RBFs, cutoff 5.0.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ...dist.sharding import NULL_CTX, ShardCtx
from ..common import ParamSpec
from .common import (GraphBatch, bessel_rbf, cosine_cutoff, edge_vectors,
                     scatter_sum)

EYE3 = jnp.eye(3)


def sym_traceless(t):
    """Project (..., 3, 3) onto the l=2 (symmetric traceless) component."""
    s = 0.5 * (t + jnp.swapaxes(t, -1, -2))
    tr = jnp.trace(s, axis1=-2, axis2=-1)[..., None, None]
    return s - tr * EYE3 / 3.0


def edge_harmonics(rhat):
    """Cartesian 'spherical harmonics' of unit vectors, l = 0, 1, 2."""
    y0 = jnp.ones(rhat.shape[:-1] + (1,))
    y1 = rhat
    y2 = sym_traceless(rhat[..., :, None] * rhat[..., None, :])
    return {0: y0, 1: y1, 2: y2}


def cart_tp(l1: int, a, l2: int, b) -> Dict[int, jnp.ndarray]:
    """Cartesian Clebsch-Gordan product of per-channel irreps.

    a: (..., C, [3]*l1-shape), b broadcastable likewise. Returns the l_out
    components reachable with l_out <= 2."""
    out: Dict[int, jnp.ndarray] = {}
    if l1 > l2:  # symmetrize dispatch
        swapped = cart_tp(l2, b, l1, a)
        return swapped
    if l1 == 0:
        # scalar times anything: shapes (...,C) x (...,C,...)
        extra = b.ndim - a.ndim
        out[l2] = a.reshape(a.shape + (1,) * extra) * b
        return out
    if l1 == 1 and l2 == 1:
        out[0] = jnp.sum(a * b, axis=-1)
        out[1] = jnp.cross(a, b)
        out[2] = sym_traceless(a[..., :, None] * b[..., None, :])
        return out
    if l1 == 1 and l2 == 2:
        # vector . matrix -> vector
        out[1] = jnp.einsum("...i,...ij->...j", a, b)
        # antisymmetric route -> l=2: sym traceless of (eps contraction)
        c = jnp.cross(a[..., None, :], b, axis=-1)       # (..., 3, 3)
        out[2] = sym_traceless(c)
        return out
    if l1 == 2 and l2 == 2:
        out[0] = jnp.einsum("...ij,...ij->...", a, b)
        out[1] = jnp.einsum("ijk,...jl,...lk->...i", _EPS, a, b)
        ab = jnp.einsum("...ij,...jk->...ik", a, b)
        ba = jnp.einsum("...ij,...jk->...ik", b, a)
        out[2] = sym_traceless(ab + ba)
        return out
    raise ValueError((l1, l2))


import numpy as _np
_e = _np.zeros((3, 3, 3))
_e[0, 1, 2] = _e[1, 2, 0] = _e[2, 0, 1] = 1.0
_e[0, 2, 1] = _e[2, 1, 0] = _e[1, 0, 2] = -1.0
_EPS = jnp.asarray(_e)


@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str = "nequip"
    n_layers: int = 5
    d_hidden: int = 32       # multiplicity per l
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    n_species: int = 100
    radial_hidden: int = 32


# paths: (l_in, l_filter) -> l_out, all <= l_max
PATHS: Tuple[Tuple[int, int, int], ...] = tuple(
    (li, lf, lo)
    for li in (0, 1, 2) for lf in (0, 1, 2) for lo in (0, 1, 2)
    if abs(li - lf) <= lo <= li + lf)


def build_specs(cfg: NequIPConfig) -> Dict[str, Any]:
    C = cfg.d_hidden
    specs: Dict[str, Any] = {
        "embed": ParamSpec((cfg.n_species, C), (None, "feat"),
                           init="embed", scale=1.0),
    }
    for i in range(cfg.n_layers):
        # radial MLP -> per-path, per-channel weights
        specs[f"l{i}_rw0"] = ParamSpec((cfg.n_rbf, cfg.radial_hidden),
                                       (None, None))
        specs[f"l{i}_rb0"] = ParamSpec((cfg.radial_hidden,), (None,),
                                       init="zeros")
        specs[f"l{i}_rw1"] = ParamSpec((cfg.radial_hidden, len(PATHS) * C),
                                       (None, None))
        for lo in (0, 1, 2):
            specs[f"l{i}_mix{lo}"] = ParamSpec((C, C), ("feat", "feat"),
                                               scale=0.5)
            if lo > 0:
                specs[f"l{i}_gate{lo}"] = ParamSpec((C, C), ("feat", "feat"),
                                                    scale=0.5)
    specs.update({
        "out_w0": ParamSpec((C, C), ("feat", None)),
        "out_b0": ParamSpec((C,), (None,), init="zeros"),
        "out_w1": ParamSpec((C, 1), (None, None)),
        "out_b1": ParamSpec((1,), (None,), init="zeros"),
    })
    return specs


def forward(params, batch: GraphBatch, cfg: NequIPConfig,
            ctx: ShardCtx = NULL_CTX):
    """Per-graph energies (n_graphs,) — rotation invariant."""
    N, C = batch.n_node, cfg.d_hidden
    rij, d, emask = edge_vectors(batch)
    rhat = rij / d[:, None]
    Y = edge_harmonics(rhat)
    rbf = ctx.constrain(bessel_rbf(d, cfg.n_rbf, cfg.cutoff),
                        "edges", None)
    fc = (cosine_cutoff(d, cfg.cutoff) * emask)[:, None]
    snd, rcv = batch.senders, batch.receivers

    x = {0: params["embed"][batch.species],
         1: jnp.zeros((N, C, 3)),
         2: jnp.zeros((N, C, 3, 3))}

    for i in range(cfg.n_layers):
        h = jax.nn.silu(rbf @ params[f"l{i}_rw0"] + params[f"l{i}_rb0"])
        w = (h @ params[f"l{i}_rw1"]).reshape(-1, len(PATHS), C) * \
            fc[:, :, None]                                 # (E, P, C)
        agg = {lo: 0.0 for lo in (0, 1, 2)}
        for pi, (li, lf, lo) in enumerate(PATHS):
            xj = x[li][snd]                                # (E, C, ...)
            yf = Y[lf][:, None] if lf > 0 else None        # (E, 1, ...)
            if lf == 0:
                prod = {li: xj}
            else:
                prod = cart_tp(li, xj, lf,
                               jnp.broadcast_to(yf, (xj.shape[0], C)
                                                + Y[lf].shape[1:]))
            if lo not in prod:
                continue
            m = prod[lo]
            wc = w[:, pi].reshape(w.shape[0], C, *([1] * (m.ndim - 2)))
            m = ctx.constrain(m * wc, "edges", *([None] * (m.ndim - 1)))
            agg[lo] = agg[lo] + scatter_sum(m, rcv, N)
        # linear mix + gated nonlinearity, residual update
        agg = {lo: ctx.constrain(a, "nodes",
                                 *([None] * (jnp.ndim(a) - 1)))
               for lo, a in agg.items()}
        s = x[0] + jnp.tanh(agg[0]) @ params[f"l{i}_mix0"]
        new = {0: s}
        for lo in (1, 2):
            g = jax.nn.sigmoid(s @ params[f"l{i}_gate{lo}"])
            mixed = jnp.einsum("nc...,cd->nd...", agg[lo],
                               params[f"l{i}_mix{lo}"])
            new[lo] = x[lo] + mixed * \
                g.reshape(g.shape + (1,) * (x[lo].ndim - 2))
        x = new

    e_atom = jax.nn.silu(x[0] @ params["out_w0"] + params["out_b0"])
    e_atom = e_atom @ params["out_w1"] + params["out_b1"]
    gid = batch.graph_id if batch.graph_id is not None else \
        jnp.zeros(N, jnp.int32)
    mask = batch.node_mask if batch.node_mask is not None else \
        jnp.ones(N, bool)
    e_atom = jnp.where(mask[:, None], e_atom, 0.0)
    return scatter_sum(e_atom[:, 0], gid, batch.n_graphs)


def loss_fn(params, batch: GraphBatch, cfg: NequIPConfig,
            ctx: ShardCtx = NULL_CTX):
    energies = forward(params, batch, cfg, ctx)
    return jnp.mean(jnp.square(energies - batch.labels))
