"""GNN substrate: padded graph batches + segment-op message passing.

JAX sparse is BCOO-only, so message passing is implemented directly over
edge-index arrays with ``jax.ops.segment_sum`` / ``segment_max`` (this IS
the system — see kernel taxonomy §GNN). Padded edges use ``n_node`` as the
sentinel so gathers stay in-bounds and scatters land in a junk slot.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ...graphs.format import Graph


@dataclasses.dataclass(frozen=True)
class GraphBatch:
    """Static-shape batch. senders/receivers padded with n_node."""
    senders: jnp.ndarray        # (E,) int32
    receivers: jnp.ndarray      # (E,) int32
    n_node: int                 # static (includes one sentinel slot at n)
    node_feat: Optional[jnp.ndarray] = None   # (N, F)
    species: Optional[jnp.ndarray] = None     # (N,) int32 atomic numbers
    positions: Optional[jnp.ndarray] = None   # (N, 3)
    graph_id: Optional[jnp.ndarray] = None    # (N,) int32 for batched graphs
    n_graphs: int = 1
    labels: Optional[jnp.ndarray] = None
    node_mask: Optional[jnp.ndarray] = None   # (N,) bool
    # dimenet triplets: edge ids (kj, ji) with shared middle vertex j
    trip_kj: Optional[jnp.ndarray] = None     # (T,) int32 (sentinel E)
    trip_ji: Optional[jnp.ndarray] = None     # (T,) int32


def from_graph(g: Graph, feat=None, labels=None, seed: int = 0,
               with_positions: bool = False, pad_edges: int = 0
               ) -> GraphBatch:
    rng = np.random.default_rng(seed)
    src = g.arc_tails().astype(np.int32)
    dst = np.asarray(g.adjncy, dtype=np.int32)
    E = g.m + pad_edges
    senders = np.full(E, g.n, dtype=np.int32)
    receivers = np.full(E, g.n, dtype=np.int32)
    senders[:g.m] = src
    receivers[:g.m] = dst
    pos = rng.standard_normal((g.n + 1, 3)).astype(np.float32) * 2.0 \
        if with_positions else None
    return GraphBatch(
        senders=jnp.asarray(senders), receivers=jnp.asarray(receivers),
        n_node=g.n + 1,
        node_feat=jnp.asarray(feat) if feat is not None else None,
        positions=jnp.asarray(pos) if pos is not None else None,
        species=None, labels=jnp.asarray(labels)
        if labels is not None else None)


def scatter_sum(values, index, num_segments):
    return jax.ops.segment_sum(values, index, num_segments=num_segments)


def edge_softmax(scores, receivers, n_node):
    """Per-destination softmax over incoming edges. scores: (E, ...)"""
    smax = jax.ops.segment_max(scores, receivers, num_segments=n_node)
    ex = jnp.exp(scores - smax[receivers])
    denom = jax.ops.segment_sum(ex, receivers, num_segments=n_node)
    return ex / jnp.maximum(denom[receivers], 1e-9)


def edge_vectors(batch: GraphBatch):
    """r_ij = pos[receiver] - pos[sender]; sentinel edges get unit z."""
    rij = batch.positions[batch.receivers] - batch.positions[batch.senders]
    pad = batch.senders >= batch.n_node - 1
    rij = jnp.where(pad[:, None], jnp.array([0.0, 0.0, 1.0]), rij)
    d = jnp.linalg.norm(rij, axis=-1)
    d = jnp.maximum(d, 1e-6)
    return rij, d, ~pad


def gaussian_rbf(d, n_rbf: int, cutoff: float):
    mu = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = 1.0 / ((mu[1] - mu[0]) ** 2 + 1e-9)
    return jnp.exp(-gamma * jnp.square(d[:, None] - mu[None, :]))


def bessel_rbf(d, n_rbf: int, cutoff: float):
    """DimeNet/NequIP radial basis: sqrt(2/c) sin(n pi d / c) / d."""
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    return (jnp.sqrt(2.0 / cutoff) * jnp.sin(n[None, :] * jnp.pi
            * d[:, None] / cutoff) / d[:, None])


def cosine_cutoff(d, cutoff: float):
    c = 0.5 * (jnp.cos(jnp.pi * jnp.minimum(d, cutoff) / cutoff) + 1.0)
    return jnp.where(d <= cutoff, c, 0.0)


def mlp_specs(name_sizes, prefix: str, axes_hidden: str = "feat"):
    """Helper: dense-stack MLP ParamSpecs {prefix}_w{i}/{prefix}_b{i}."""
    from ..common import ParamSpec
    out = {}
    for i, (din, dout) in enumerate(name_sizes):
        out[f"{prefix}_w{i}"] = ParamSpec((din, dout), (None, None))
        out[f"{prefix}_b{i}"] = ParamSpec((dout,), (None,), init="zeros")
    return out


def mlp_apply(params, prefix, x, act, n_layers, final_act: bool = False):
    for i in range(n_layers):
        x = x @ params[f"{prefix}_w{i}"] + params[f"{prefix}_b{i}"]
        if i < n_layers - 1 or final_act:
            x = act(x)
    return x
