"""GAT (Velickovic et al., arXiv:1710.10903) — SDDMM + edge softmax + SpMM.

gat-cora config: 2 layers, 8 hidden units, 8 heads, attn aggregator.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ...dist.sharding import NULL_CTX, ShardCtx
from ..common import ParamSpec, cross_entropy_loss
from .common import GraphBatch, edge_softmax, scatter_sum


@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str = "gat-cora"
    n_layers: int = 2
    d_in: int = 1433
    d_hidden: int = 8
    n_heads: int = 8
    n_classes: int = 7
    negative_slope: float = 0.2


def build_specs(cfg: GATConfig) -> Dict[str, Any]:
    specs: Dict[str, Any] = {}
    d_in = cfg.d_in
    for i in range(cfg.n_layers):
        last = i == cfg.n_layers - 1
        d_out = cfg.n_classes if last else cfg.d_hidden
        heads = 1 if last else cfg.n_heads
        specs[f"l{i}_w"] = ParamSpec((d_in, heads, d_out),
                                     ("feat", "heads", None))
        specs[f"l{i}_asrc"] = ParamSpec((heads, d_out), ("heads", None),
                                        scale=0.1)
        specs[f"l{i}_adst"] = ParamSpec((heads, d_out), ("heads", None),
                                        scale=0.1)
        specs[f"l{i}_b"] = ParamSpec((heads * d_out,), (None,), init="zeros")
        d_in = heads * d_out if not last else d_out
    return specs


def forward(params, batch: GraphBatch, cfg: GATConfig,
            ctx: ShardCtx = NULL_CTX):
    x = batch.node_feat                                   # (N, F)
    N = batch.n_node
    snd, rcv = batch.senders, batch.receivers
    for i in range(cfg.n_layers):
        last = i == cfg.n_layers - 1
        h = jnp.einsum("nf,fhd->nhd", x, params[f"l{i}_w"])  # (N, H, D)
        h = ctx.constrain(h, "nodes", None, None)
        a_s = jnp.sum(h * params[f"l{i}_asrc"], axis=-1)     # (N, H)
        a_d = jnp.sum(h * params[f"l{i}_adst"], axis=-1)
        e = a_s[snd] + a_d[rcv]                              # (E, H)
        e = jax.nn.leaky_relu(e, cfg.negative_slope)
        e = ctx.constrain(e, "edges", None)
        # mask sentinel edges out of the softmax
        pad = (snd >= N - 1)[:, None]
        e = jnp.where(pad, -1e30, e)
        alpha = edge_softmax(e, rcv, N)                      # (E, H)
        msg = alpha[:, :, None] * h[snd]                     # (E, H, D)
        msg = ctx.constrain(msg, "edges", None, None)
        out = scatter_sum(jnp.where(pad[:, :, None], 0.0, msg), rcv, N)
        out = ctx.constrain(out, "nodes", None, None)
        if last:
            x = jnp.mean(out, axis=1) + params[f"l{i}_b"]
        else:
            x = jax.nn.elu(out.reshape(N, -1) + params[f"l{i}_b"])
    return x                                                 # (N, n_classes)


def loss_fn(params, batch: GraphBatch, cfg: GATConfig,
            ctx: ShardCtx = NULL_CTX):
    logits = forward(params, batch, cfg, ctx)
    mask = batch.node_mask if batch.node_mask is not None else \
        jnp.ones(batch.n_node, bool)
    return cross_entropy_loss(logits, batch.labels,
                              mask=mask.astype(jnp.float32))
