"""DimeNet (Gasteiger et al., arXiv:2003.03123) — directional message
passing over edge-pair (triplet) gathers with a joint 2D spherical
Fourier-Bessel basis. This is the "triplet gather" kernel regime: not
expressible as SpMM (see kernel taxonomy §GNN).

Config: 6 blocks, d_hidden=128, n_bilinear=8, n_spherical=7, n_radial=6.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from scipy import optimize, special

from ...dist.sharding import NULL_CTX, ShardCtx
from ..common import ParamSpec
from .common import GraphBatch, bessel_rbf, cosine_cutoff, edge_vectors, \
    scatter_sum


@functools.lru_cache(maxsize=None)
def spherical_bessel_roots(n_l: int, n_roots: int) -> np.ndarray:
    """First ``n_roots`` positive roots of j_l for l < n_l (computed once
    by sign-change scan + brentq)."""
    out = np.zeros((n_l, n_roots))
    xs = np.linspace(1e-3, 60.0, 6000)
    for l in range(n_l):
        vals = special.spherical_jn(l, xs)
        sgn = np.sign(vals)
        flips = np.flatnonzero(sgn[1:] * sgn[:-1] < 0)
        roots = []
        for f in flips[:n_roots]:
            roots.append(optimize.brentq(
                lambda x: special.spherical_jn(l, x), xs[f], xs[f + 1]))
        out[l, :len(roots)] = roots
    return out


def spherical_jn_jax(l_max: int, x):
    """j_l(x) for l = 0..l_max via upward recurrence (x bounded away
    from 0)."""
    x = jnp.maximum(x, 1e-4)
    j = [jnp.sin(x) / x]
    if l_max >= 1:
        j.append(jnp.sin(x) / x**2 - jnp.cos(x) / x)
    for l in range(1, l_max):
        j.append((2 * l + 1) / x * j[l] - j[l - 1])
    return jnp.stack(j, axis=-1)          # (..., l_max+1)


def legendre_jax(l_max: int, c):
    p = [jnp.ones_like(c)]
    if l_max >= 1:
        p.append(c)
    for l in range(1, l_max):
        p.append(((2 * l + 1) * c * p[l] - l * p[l - 1]) / (l + 1))
    return jnp.stack(p, axis=-1)          # (..., l_max+1)


def build_triplets(senders: np.ndarray, receivers: np.ndarray, n_node: int,
                   cap: int) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side triplet index lists: pairs (e_kj, e_ji) sharing middle
    vertex j with k != i. Padded to ``cap`` with sentinel E."""
    E = senders.shape[0]
    valid = senders < n_node - 1
    order = np.argsort(senders, kind="stable")     # edges grouped by src j
    by_src_start = np.searchsorted(senders[order], np.arange(n_node + 1))
    kj_list, ji_list = [], []
    in_edges = [[] for _ in range(n_node)]
    for e in range(E):
        if valid[e]:
            in_edges[receivers[e]].append(e)
    for j in range(n_node - 1):
        out_es = order[by_src_start[j]:by_src_start[j + 1]]
        for e2 in out_es:                          # e2: j -> i
            if not valid[e2]:
                continue
            i = receivers[e2]
            for e1 in in_edges[j]:                 # e1: k -> j
                if senders[e1] != i:
                    kj_list.append(e1)
                    ji_list.append(e2)
    T = len(kj_list)
    kj = np.full(cap, E, dtype=np.int32)
    ji = np.full(cap, E, dtype=np.int32)
    take = min(T, cap)
    kj[:take] = np.asarray(kj_list[:take], dtype=np.int32)
    ji[:take] = np.asarray(ji_list[:take], dtype=np.int32)
    return kj, ji


@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str = "dimenet"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    cutoff: float = 5.0
    n_species: int = 100
    envelope_p: int = 6


def build_specs(cfg: DimeNetConfig) -> Dict[str, Any]:
    d, nb = cfg.d_hidden, cfg.n_bilinear
    nsbf = cfg.n_spherical * cfg.n_radial
    specs: Dict[str, Any] = {
        "embed": ParamSpec((cfg.n_species, d), (None, "feat"),
                           init="embed", scale=1.0),
        "emb_rbf_w": ParamSpec((cfg.n_radial, d), (None, "feat")),
        "emb_w": ParamSpec((3 * d, d), (None, "feat")),
        "emb_b": ParamSpec((d,), ("feat",), init="zeros"),
    }
    for i in range(cfg.n_blocks):
        specs.update({
            f"b{i}_rbf_w": ParamSpec((cfg.n_radial, d), (None, "feat")),
            f"b{i}_sbf_w": ParamSpec((nsbf, nb), (None, None)),
            f"b{i}_down": ParamSpec((d, nb), ("feat", None)),
            f"b{i}_up": ParamSpec((nb, d), (None, "feat")),
            f"b{i}_msg_w": ParamSpec((d, d), ("feat", "feat")),
            f"b{i}_msg_b": ParamSpec((d,), ("feat",), init="zeros"),
            f"b{i}_res_w": ParamSpec((d, d), ("feat", "feat"), scale=0.5),
            f"b{i}_res_b": ParamSpec((d,), ("feat",), init="zeros"),
            f"b{i}_out_rbf": ParamSpec((cfg.n_radial, d), (None, "feat")),
            f"b{i}_out_w": ParamSpec((d, d), ("feat", "feat")),
            f"b{i}_out_b": ParamSpec((d,), ("feat",), init="zeros"),
        })
    specs.update({
        "final_w0": ParamSpec((d, d // 2), ("feat", None)),
        "final_b0": ParamSpec((d // 2,), (None,), init="zeros"),
        "final_w1": ParamSpec((d // 2, 1), (None, None)),
        "final_b1": ParamSpec((1,), (None,), init="zeros"),
    })
    return specs


def forward(params, batch: GraphBatch, cfg: DimeNetConfig,
            ctx: ShardCtx = NULL_CTX):
    assert batch.trip_kj is not None, "dimenet needs triplet lists"
    N = batch.n_node
    E = batch.senders.shape[0]
    rij, d, emask = edge_vectors(batch)
    rbf = bessel_rbf(d, cfg.n_radial, cfg.cutoff) * \
        cosine_cutoff(d, cfg.cutoff)[:, None] * emask[:, None]
    rbf = ctx.constrain(rbf, "edges", None)
    snd, rcv = batch.senders, batch.receivers

    # ---- joint 2D basis on triplets ------------------------------------
    kj, ji = batch.trip_kj, batch.trip_ji
    kj_s, ji_s = jnp.minimum(kj, E - 1), jnp.minimum(ji, E - 1)
    tmask = (kj < E) & (ji < E)
    a = -rij[kj_s]                                  # j -> k
    b = rij[ji_s]                                   # j -> i
    cosang = jnp.sum(a * b, -1) / jnp.maximum(
        jnp.linalg.norm(a, axis=-1) * jnp.linalg.norm(b, axis=-1), 1e-9)
    cosang = jnp.clip(cosang, -1.0, 1.0)
    roots = jnp.asarray(spherical_bessel_roots(cfg.n_spherical,
                                               cfg.n_radial),
                        dtype=jnp.float32)          # (L, R)
    d_kj = d[kj_s]
    # per-l evaluation keeps every transient at (T, R) — one stacked
    # (T*L, R, L) tensor here measured 484 GB/device on ogb_products
    # (EXPERIMENTS.md §Perf)
    jls = []
    for l in range(cfg.n_spherical):
        x = roots[l][None, :] * (d_kj / cfg.cutoff)[:, None]   # (T, R)
        jls.append(spherical_jn_jax(l, x)[..., l])
    jl = jnp.stack(jls, axis=1)                     # (T, L, R)
    pl = legendre_jax(cfg.n_spherical - 1, cosang)  # (T, L)
    sbf = (jl * pl[:, :, None]).reshape(-1, cfg.n_spherical * cfg.n_radial)
    sbf = ctx.constrain(jnp.where(tmask[:, None], sbf, 0.0),
                        "edges", None)

    # ---- embedding block ------------------------------------------------
    h = params["embed"][batch.species]
    e_rbf = rbf @ params["emb_rbf_w"]
    m = jnp.concatenate([h[snd], h[rcv], e_rbf], axis=-1)
    m = jax.nn.silu(m @ params["emb_w"] + params["emb_b"])   # (E, d)
    m = ctx.constrain(m, "edges", None)

    energy = 0.0
    gid = batch.graph_id if batch.graph_id is not None else \
        jnp.zeros(N, jnp.int32)
    mask = batch.node_mask if batch.node_mask is not None else \
        jnp.ones(N, bool)

    for i in range(cfg.n_blocks):
        # directional aggregation over triplets (bilinear, low-rank).
        # down-project BEFORE the triplet gather: gathering the (E, d)
        # messages per triplet makes GSPMD all-gather a 63 GB operand on
        # ogb_products; the (E, nb) projection is d/nb = 16x smaller
        # (identical math — EXPERIMENTS.md §Perf)
        u_e = (m * (rbf @ params[f"b{i}_rbf_w"])) @ params[f"b{i}_down"]
        u_e = ctx.constrain(u_e, "edges", None)               # (E, nb)
        u = u_e[kj_s]
        s = sbf @ params[f"b{i}_sbf_w"]                       # (T, nb)
        t = ctx.constrain(jnp.where(tmask[:, None], u * s, 0.0),
                          "edges", None)
        agg = scatter_sum(t, jnp.where(tmask, ji_s, E), E + 1)[:E]
        agg = ctx.constrain(agg, "edges", None)
        m2 = agg @ params[f"b{i}_up"]
        m = jax.nn.silu(m @ params[f"b{i}_msg_w"] + params[f"b{i}_msg_b"]) \
            + m2
        m = m + jax.nn.silu(m @ params[f"b{i}_res_w"] + params[f"b{i}_res_b"])
        m = ctx.constrain(m, "edges", None)
        # output block: edges -> nodes
        o = (m * (rbf @ params[f"b{i}_out_rbf"]))
        o = ctx.constrain(o, "edges", None)
        node = ctx.constrain(scatter_sum(o, rcv, N), "nodes", None)
        node = jax.nn.silu(node @ params[f"b{i}_out_w"] + params[f"b{i}_out_b"])
        e_atom = jax.nn.silu(node @ params["final_w0"] + params["final_b0"])
        e_atom = e_atom @ params["final_w1"] + params["final_b1"]
        e_atom = jnp.where(mask[:, None], e_atom, 0.0)
        energy = energy + scatter_sum(e_atom[:, 0], gid, batch.n_graphs)
    return energy


def loss_fn(params, batch: GraphBatch, cfg: DimeNetConfig,
            ctx: ShardCtx = NULL_CTX):
    energies = forward(params, batch, cfg, ctx)
    return jnp.mean(jnp.square(energies - batch.labels))
