"""repro: dKaMinPar (Distributed Deep Multilevel Graph Partitioning) in JAX,
embedded as the placement engine of a multi-pod TPU training/serving
framework."""
__version__ = "0.1.0"
