"""Host-side BSR construction + jit wrapper for graph aggregation."""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from ...graphs.format import Graph
from .bsr_spmm import bsr_spmm


def graph_to_bsr(g: Graph, bs: int = 128
                 ) -> Tuple[np.ndarray, np.ndarray, int, int]:
    """Adjacency (with edge weights) -> padded BSR.

    Returns (col_flat, vals, block_rows, nnz_per_row)."""
    rb = -(-g.n // bs)
    cb = rb
    src = g.arc_tails()
    dst = np.asarray(g.adjncy)
    rblk = src // bs
    cblk = dst // bs
    key = rblk * cb + cblk
    order = np.argsort(key, kind="stable")
    uniq, inv_start = np.unique(key[order], return_index=True)
    # rows of blocks
    blk_r = (uniq // cb).astype(np.int64)
    blk_c = (uniq % cb).astype(np.int64)
    per_row = np.bincount(blk_r, minlength=rb)
    nnz_per_row = max(1, int(per_row.max()))
    col_flat = np.zeros(rb * nnz_per_row, dtype=np.int32)
    vals = np.zeros((rb * nnz_per_row, bs, bs), dtype=np.float32)
    # dense block contents
    blk_of_edge = np.searchsorted(uniq, key)
    slot_within = np.zeros(uniq.size, dtype=np.int64)
    running = np.zeros(rb, dtype=np.int64)
    for b in range(uniq.size):
        slot_within[b] = running[blk_r[b]]
        running[blk_r[b]] += 1
    flat_slot = blk_r * nnz_per_row + slot_within
    col_flat[flat_slot] = blk_c
    e_slot = flat_slot[blk_of_edge]
    np.add.at(vals, (e_slot, src % bs, dst % bs),
              g.eweights.astype(np.float32))
    return col_flat, vals, rb, nnz_per_row


def spmm(g: Graph, x: np.ndarray, bs: int = 128, interpret: bool = True
         ) -> np.ndarray:
    """Y[v] = sum_u w(v,u) * X[u] via the Pallas BSR kernel."""
    col_flat, vals, rb, nnz = graph_to_bsr(g, bs)
    f = x.shape[1]
    f_pad = max(128, -(-f // 128) * 128)
    xp = np.zeros((rb * bs, f_pad), dtype=np.float32)
    xp[:g.n, :f] = x
    y = bsr_spmm(jnp.asarray(col_flat), jnp.asarray(vals), jnp.asarray(xp),
                 block_rows=rb, nnz_per_row=nnz, interpret=interpret)
    return np.asarray(y)[:g.n, :f]
