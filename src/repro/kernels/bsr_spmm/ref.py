"""Pure-jnp oracle for bsr_spmm."""
from __future__ import annotations

import jax.numpy as jnp


def bsr_spmm_ref(col_flat, vals, x, *, block_rows: int, nnz_per_row: int):
    bs = vals.shape[1]
    f = x.shape[1]
    xb = x.reshape(-1, bs, f)
    gathered = xb[col_flat]                          # (RB*NNZ, BS, F)
    prod = jnp.einsum("nij,njf->nif", vals, gathered)
    prod = prod.reshape(block_rows, nnz_per_row, bs, f).sum(axis=1)
    return prod.reshape(block_rows * bs, f)
