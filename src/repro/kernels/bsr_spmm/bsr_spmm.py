"""Pallas TPU kernel: BSR (block-sparse row) SpMM —
``Y = A @ X`` where A is a block-sparse adjacency matrix.

This is the GNN aggregation primitive in its TPU-native form: instead of
per-edge scatter (no TPU gather/scatter units), the adjacency is blocked
into dense (BS x BS) tiles whose column indices are *scalar-prefetched*
so the BlockSpec index_map can steer the X DMA per grid step (the
standard Pallas block-sparse pattern). Dense tiles of a sparse matrix
waste FLOPs on zeros but hit the MXU at full rate — the classic TPU
trade (DESIGN.md §2, hardware adaptation).

Layout (host-built, see ops.py):
  vals      (NNZB, BS, BS) f32   dense nonzero blocks, row-major by block row
  col_idx   (NNZB,)        i32   column block of each nonzero block
  row_ptr   (RB + 1,)      i32   CSR-style pointers over block rows
  X         (CB * BS, F)   f32   dense features
  Y         (RB * BS, F)   f32

Grid: (block_rows, num_nonzero_steps) — step j processes the j-th
nonzero block of the current row (rows padded to equal nnz per row with
zero blocks pointing at column 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(col_ref, vals_ref, x_ref, y_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    a = vals_ref[...]                      # (BS, BS)
    x = x_ref[...]                         # (BS, F)
    y_ref[...] += jax.lax.dot(a, x, preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "nnz_per_row", "interpret"))
def bsr_spmm(col_flat, vals, x, *, block_rows: int, nnz_per_row: int,
             interpret: bool = True):
    """col_flat: (block_rows * nnz_per_row,) i32 column-block ids (padded
    entries point at block 0 with all-zero vals). vals: same order,
    (block_rows * nnz_per_row, BS, BS). x: (CB*BS, F)."""
    bs = vals.shape[1]
    f = x.shape[1]
    grid = (block_rows, nnz_per_row)

    def vals_map(i, j, col_ref):
        return (i * nnz_per_row + j, 0, 0)

    def x_map(i, j, col_ref):
        return (col_ref[i * nnz_per_row + j], 0)

    def y_map(i, j, col_ref):
        return (i, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, bs), vals_map),
            pl.BlockSpec((bs, f), x_map),
        ],
        out_specs=pl.BlockSpec((bs, f), y_map),
    )
    kernel = lambda col_ref, vals_ref, x_ref, y_ref: _kernel(
        col_ref, vals_ref.at[0], x_ref, y_ref)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((block_rows * bs, f), jnp.float32),
        interpret=interpret,
    )(col_flat, vals, x)
