"""Pure-jnp oracle for the lp_gain kernel."""
from __future__ import annotations

import jax.numpy as jnp


def lp_gain_ell_ref(lab, w, tgt_w, own_lab, vw, budget):
    eq = (lab[:, :, None] == lab[:, None, :])
    conn = jnp.sum(jnp.where(eq, w[:, None, :], 0.0), axis=2)   # (N, D)
    valid = lab >= 0
    staying = lab == own_lab
    fits = (tgt_w + vw <= budget[0, 0]) & ~staying & valid
    score = jnp.where(fits, conn, -1.0)
    best = jnp.max(score, axis=1, keepdims=True)
    is_best = (score == best) & fits
    big = jnp.int32(2**30)
    target = jnp.min(jnp.where(is_best, lab, big), axis=1, keepdims=True)
    target = jnp.where(best >= 0, target, -1)
    own_conn = jnp.sum(jnp.where(staying & valid, w, 0.0), axis=1,
                       keepdims=True)
    return best, target, own_conn
