"""jit wrapper: graph -> padded ELL -> lp_gain kernel."""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from ...graphs.format import Graph, to_ell
from .lp_gain import lp_gain_ell


def _pad_to(x, m, axis, fill):
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, m - x.shape[axis])
    return np.pad(x, pad, constant_values=fill)


def prepare_ell(g: Graph, row_tile: int = 256, max_degree: int = 512
                ) -> Tuple[np.ndarray, np.ndarray, int]:
    """Graph -> padded (idx, w) ELL arrays: D multiple of 128, rows a
    multiple of row_tile. Sentinel neighbor id = -1."""
    idx, wgt, d = to_ell(g, max_degree=max_degree)
    d_pad = max(128, -(-d // 128) * 128)
    n_pad = -(-g.n // row_tile) * row_tile
    idx = np.where(idx >= g.n, -1, idx)
    idx = _pad_to(_pad_to(idx, d_pad, 1, -1), n_pad, 0, -1)
    wgt = _pad_to(_pad_to(wgt, d_pad, 1, 0), n_pad, 0, 0)
    return idx.astype(np.int32), wgt.astype(np.float32), d_pad


def lp_gain(g: Graph, labels: np.ndarray, cluster_w: np.ndarray,
            budget: float, row_tile: int = 256, interpret: bool = True):
    """Compute (gain, target, own_conn) per vertex with the Pallas kernel.

    labels/cluster_w indexed by vertex id / label id respectively."""
    idx, wgt, _ = prepare_ell(g, row_tile)
    n_pad = idx.shape[0]
    lab_tab = np.concatenate([labels.astype(np.int32), [-1]])
    cw_tab = np.concatenate([cluster_w.astype(np.float32), [np.inf]])
    nbr_lab = np.where(idx >= 0, lab_tab[np.where(idx >= 0, idx, 0)], -1)
    tgt_w = np.where(nbr_lab >= 0,
                     cw_tab[np.where(nbr_lab >= 0, nbr_lab, 0)], np.inf)
    own = np.full((n_pad, 1), -2, dtype=np.int32)
    own[:g.n, 0] = labels
    vw = np.zeros((n_pad, 1), dtype=np.float32)
    vw[:g.n, 0] = g.vweights
    best, target, own_conn = lp_gain_ell(
        jnp.asarray(nbr_lab), jnp.asarray(wgt), jnp.asarray(tgt_w),
        jnp.asarray(own), jnp.asarray(vw),
        jnp.full((1, 1), budget, jnp.float32),
        row_tile=row_tile, interpret=interpret)
    gain = np.asarray(best)[:g.n, 0] - np.asarray(own_conn)[:g.n, 0]
    return (gain, np.asarray(target)[:g.n, 0],
            np.asarray(own_conn)[:g.n, 0])
