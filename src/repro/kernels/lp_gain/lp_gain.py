"""Pallas TPU kernel: label-propagation gain over ELL rows.

The partitioner's hot loop (paper §4) asks, per vertex v: among the
labels of v's neighbors, which one has the largest total connection
weight (subject to the target's weight budget), and what is the gain over
v's current label?

TPU adaptation (DESIGN.md §2): no hash tables — for a row of D padded
neighbors we form the DxD label-equality matrix and contract it with the
weight vector:   conn[j] = sum_i w[i] * [lab[i] == lab[j]]
which is an f32 matmul per row tile -> MXU-shaped. Neighbor labels /
target weights are pre-gathered outside (XLA gather is already optimal);
the O(D^2) scoring is what the kernel owns.

Inputs (padded: D multiple of 128, rows multiple of the tile):
  lab       (N, D) i32   neighbor labels (sentinel = -1 on padding)
  w         (N, D) f32   edge weights (0 on padding)
  tgt_w     (N, D) f32   current weight of each neighbor's cluster
  own_lab   (N, 1) i32   current label of the row vertex
  vw        (N, 1) f32   row vertex weight
  budget    scalar f32   max cluster weight W
Outputs:
  best_conn (N, 1) f32   best admissible connection weight (-1 if none)
  target    (N, 1) i32   argmax label (-1 if none)
  own_conn  (N, 1) f32   connection to the current label
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(lab_ref, w_ref, tgt_w_ref, own_ref, vw_ref, budget_ref,
            best_ref, target_ref, own_conn_ref):
    lab = lab_ref[...]                       # (R, D) i32
    w = w_ref[...]                           # (R, D) f32
    tgt_w = tgt_w_ref[...]
    own = own_ref[...]                       # (R, 1)
    vw = vw_ref[...]                         # (R, 1)
    budget = budget_ref[0, 0]

    # connection weight of each neighbor's label: eq-matmul on the MXU
    eq = (lab[:, :, None] == lab[:, None, :]).astype(jnp.float32)
    conn = jax.lax.dot_general(
        eq, w[:, :, None],
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)[:, :, 0]        # (R, D)

    valid = lab >= 0
    staying = lab == own
    fits = (tgt_w + vw <= budget) & ~staying & valid
    score = jnp.where(fits, conn, -1.0)
    best = jnp.max(score, axis=1, keepdims=True)            # (R, 1)
    # deterministic argmax -> smallest label among maximisers
    is_best = (score == best) & fits
    big = jnp.int32(2**30)
    target = jnp.min(jnp.where(is_best, lab, big), axis=1, keepdims=True)
    target = jnp.where(best >= 0, target, -1)
    own_conn = jnp.sum(jnp.where(staying & valid, w, 0.0), axis=1,
                       keepdims=True)
    best_ref[...] = best
    target_ref[...] = target
    own_conn_ref[...] = own_conn


@functools.partial(jax.jit, static_argnames=("row_tile", "interpret"))
def lp_gain_ell(lab, w, tgt_w, own_lab, vw, budget, *, row_tile: int = 256,
                interpret: bool = True):
    n, d = lab.shape
    assert n % row_tile == 0, (n, row_tile)
    grid = (n // row_tile,)
    out_shapes = (
        jax.ShapeDtypeStruct((n, 1), jnp.float32),
        jax.ShapeDtypeStruct((n, 1), jnp.int32),
        jax.ShapeDtypeStruct((n, 1), jnp.float32),
    )
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_tile, d), lambda i: (i, 0)),
            pl.BlockSpec((row_tile, d), lambda i: (i, 0)),
            pl.BlockSpec((row_tile, d), lambda i: (i, 0)),
            pl.BlockSpec((row_tile, 1), lambda i: (i, 0)),
            pl.BlockSpec((row_tile, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((row_tile, 1), lambda i: (i, 0)),
            pl.BlockSpec((row_tile, 1), lambda i: (i, 0)),
            pl.BlockSpec((row_tile, 1), lambda i: (i, 0)),
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(lab, w, tgt_w, own_lab, vw, budget)
