"""Host wrapper: fused duplicate-arc merge backing ``dedup_arcs``.

``core.contraction.dedup_arcs`` is int64 numpy (lexsort + ``np.add.at``).
The fused path runs the seg_merge Pallas kernel instead when the record
ids and weight totals fit int32 and the slab fits the kernel's VMEM
budget; otherwise it reports "doesn't apply" and the caller keeps the
numpy kernel. Results are identical: same (src, dst)-sorted unique arcs,
same summed weights.
"""
from __future__ import annotations

import numpy as np

from .seg_merge import I32_MAX, _next_pow2, seg_merge, seg_merge_vmem_bytes
from ..dispatch import VMEM_BUDGET_BYTES


def dedup_fits(csrc: np.ndarray, cdst: np.ndarray, w: np.ndarray) -> bool:
    """int32-exactness + VMEM guard for the fused dedup path."""
    if csrc.size == 0:
        return False
    if int(csrc.max(initial=0)) >= int(I32_MAX) or \
            int(cdst.max(initial=0)) >= int(I32_MAX):
        return False
    if int(np.abs(w).astype(np.int64).sum()) >= 2**31:
        return False
    return seg_merge_vmem_bytes(csrc.size) <= VMEM_BUDGET_BYTES


def dedup_arcs_fused(csrc: np.ndarray, cdst: np.ndarray, w: np.ndarray,
                     interpret: bool = True):
    """Fused twin of ``core.contraction.dedup_arcs`` (same contract:
    drop self loops, merge parallel arcs, return int64 sorted by
    (src, dst)). Caller must have checked ``dedup_fits``."""
    keep = csrc != cdst
    csrc, cdst, w = csrc[keep], cdst[keep], w[keep]
    if csrc.size == 0:
        return (csrc.astype(np.int64), cdst.astype(np.int64),
                w.astype(np.int64))
    L = max(2, _next_pow2(csrc.size))
    pad = L - csrc.size
    src32 = np.concatenate([csrc.astype(np.int32),
                            np.full(pad, I32_MAX, np.int32)])
    dst32 = np.concatenate([cdst.astype(np.int32),
                            np.full(pad, I32_MAX, np.int32)])
    w32 = np.concatenate([w.astype(np.int32), np.zeros(pad, np.int32)])
    s_src, s_dst, tot, first = (np.asarray(x) for x in seg_merge(
        src32, dst32, w32, interpret=interpret))
    take = (s_src < int(I32_MAX)) & (first != 0)
    return (s_src[take].astype(np.int64), s_dst[take].astype(np.int64),
            tot[take].astype(np.int64))
