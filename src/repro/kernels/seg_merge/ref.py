"""Composed-XLA oracle for the seg_merge kernel.

Exactly the owner-side merge block of
``dist.dist_contraction._build_exchange_fn``: stable lexicographic
``lax.sort`` + cumsum group ids + ``segment_sum``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def seg_merge_ref(src, dst, w):
    """Reference ``(s_src, s_dst, tot, first)`` for (L,) int32 records."""
    L = src.shape[0]
    s_src, s_dst, s_w = lax.sort((src, dst, w), num_keys=2)
    first = jnp.concatenate([
        jnp.ones((1,), jnp.bool_),
        (s_src[1:] != s_src[:-1]) | (s_dst[1:] != s_dst[:-1])])
    gid = jnp.cumsum(first.astype(jnp.int32)) - 1
    tot = jax.ops.segment_sum(s_w, gid, num_segments=L,
                              indices_are_sorted=True)
    return s_src, s_dst, tot[gid], first.astype(jnp.int32)
