"""Pallas TPU kernel: segmented sort + duplicate-arc merge (paper §5).

Contraction's inner loop deduplicates coarse arcs: sort (src, dst, w)
records lexicographically by (src, dst), flag the first record of every
equal-key run, and sum each run's weights. The composed path is a
``lax.sort`` (or host lexsort) followed by a cumsum-based segment-sum —
multiple passes over the record slab. This kernel keeps the whole slab
resident in VMEM and does all three stages in one ``pallas_call``:

  * **sort** — a bitonic network over the lane axis ((1, L) layout,
    L a power of two). Each compare-exchange stage pairs lane ``i``
    with ``i ^ j`` by reshaping the lanes to (L/2j, 2, j) and flipping
    the middle axis (a static reverse — XLA compiles the unrolled
    network orders of magnitude faster than the equivalent pair of
    rolls); keys compare lexicographically on (src, dst), the weight
    rides as payload. Bitonic networks are not stable, but equal keys
    are exactly the records that merge, so every output of this kernel
    is invariant to their order.
  * **run flags** — ``first[i] = (i == 0) | key[i] != key[i-1]``.
  * **run totals** — forward + backward segmented Hillis-Steele scans
    (log L rounds each) give every lane its run's total weight:
    ``tot = fwd_incl + bwd_incl - w``.

Invalid records (self loops, padding beyond the true record count)
carry key ``src = dst = I32_MAX`` / ``w = 0``: they sort to the tail and
callers drop them with ``(s_src < I32_MAX) & first``.

Outputs are bit-identical to the composed owner-side merge in
``dist.dist_contraction._build_exchange_fn`` and to the host
``core.contraction.dedup_arcs`` after that filter (int32 range).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl

I32_MAX = np.int32(np.iinfo(np.int32).max)


def _xchg(x, j, L):
    """Value at partner lane ``i ^ j`` (j a power of two): flip the
    middle axis of the (L/2j, 2, j) lane view."""
    return jnp.flip(x.reshape(-1, 2, j), axis=1).reshape(1, L)


def _shr(x, step):
    """Lanes shifted right by ``step``, zero/False fill on the left."""
    return jnp.pad(x[:, :-step], ((0, 0), (step, 0)))


def _shl(x, step):
    """Lanes shifted left by ``step``, zero/False fill on the right."""
    return jnp.pad(x[:, step:], ((0, 0), (0, step)))


def _kernel(src_ref, dst_ref, w_ref, osrc_ref, odst_ref, tot_ref,
            first_ref, *, L):
    s = src_ref[...]                                  # (1, L)
    d = dst_ref[...]
    w = w_ref[...]
    iota = lax.broadcasted_iota(jnp.int32, (1, L), 1)

    # ---- bitonic sort by (src, dst), w as payload -----------------------
    k = 2
    while k <= L:
        j = k // 2
        while j >= 1:
            sp = _xchg(s, j, L)
            dp = _xchg(d, j, L)
            wp = _xchg(w, j, L)
            lower = (iota & j) == 0
            want_min = ((iota & k) == 0) == lower
            gt = (s > sp) | ((s == sp) & (d > dp))
            lt = (s < sp) | ((s == sp) & (d < dp))
            take = jnp.where(want_min, gt, lt)
            s = jnp.where(take, sp, s)
            d = jnp.where(take, dp, d)
            w = jnp.where(take, wp, w)
            j //= 2
        k *= 2

    # ---- run-start flags (lane 0 is forced first, so the shifted-in
    # zero on the left never matters) --------------------------------------
    first = (iota == 0) | (s != _shr(s, 1)) | (d != _shr(d, 1))

    # ---- run totals: forward + backward segmented scans ------------------
    fsum, flag = w, first
    step = 1
    while step < L:
        fsum = fsum + jnp.where(~flag, _shr(fsum, step), 0)
        flag = flag | _shr(flag, step)
        step *= 2
    is_end = _shl(first, 1) | (iota == L - 1)
    bsum, flag = w, is_end
    step = 1
    while step < L:
        bsum = bsum + jnp.where(~flag, _shl(bsum, step), 0)
        flag = flag | _shl(flag, step)
        step *= 2

    osrc_ref[...] = s
    odst_ref[...] = d
    tot_ref[...] = fsum + bsum - w
    first_ref[...] = first.astype(jnp.int32)


def _next_pow2(x: int) -> int:
    return 1 << max(0, (int(x) - 1)).bit_length()


@functools.partial(jax.jit, static_argnames=("interpret",))
def seg_merge(src, dst, w, *, interpret: bool = True):
    """Sort + merge (L,) int32 arc records. Returns
    ``(s_src, s_dst, tot, first)`` — sorted keys, per-lane run totals,
    int32 run-start flags. Pads to a power of two internally (padding
    carries the same I32_MAX invalid key callers already filter)."""
    (L,) = src.shape
    Lp = max(2, _next_pow2(L))
    pad = Lp - L
    if pad:
        src = jnp.concatenate([src, jnp.full((pad,), I32_MAX, jnp.int32)])
        dst = jnp.concatenate([dst, jnp.full((pad,), I32_MAX, jnp.int32)])
        w = jnp.concatenate([w, jnp.zeros((pad,), jnp.int32)])
    out_shapes = tuple(jax.ShapeDtypeStruct((1, Lp), jnp.int32)
                       for _ in range(4))
    s_src, s_dst, tot, first = pl.pallas_call(
        functools.partial(_kernel, L=Lp),
        out_shape=out_shapes,
        interpret=interpret,
    )(src[None], dst[None], w[None])
    return s_src[0, :L], s_dst[0, :L], tot[0, :L], first[0, :L]


def seg_merge_vmem_bytes(L: int) -> int:
    """Planning estimate: ~10 live (1, L) i32 lanesets during the sort
    and scan stages (inputs, partners, flags, outputs)."""
    return 10 * max(2, _next_pow2(L)) * 4
