"""jit wrapper for the EmbeddingBag kernel (pads D to the lane width)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .embedding_bag import embedding_bag_1row


def embedding_bag(idx: np.ndarray, table: np.ndarray,
                  interpret: bool = True) -> np.ndarray:
    """idx (B, BAG) int32, table (V, D) -> (B, D) sum-pooled."""
    v, d = table.shape
    d_pad = max(128, -(-d // 128) * 128)
    tp = np.zeros((v, d_pad), dtype=np.float32)
    tp[:, :d] = table
    out = embedding_bag_1row(jnp.asarray(idx.astype(np.int32)),
                             jnp.asarray(tp), interpret=interpret)
    return np.asarray(out)[:, :d]
