"""Pallas TPU kernel: EmbeddingBag — index-driven row gather + bag reduce.

JAX has no native EmbeddingBag (kernel taxonomy §RecSys); the DLRM hot
path is a ragged gather over a huge table followed by a per-bag sum. TPU
has no gather unit, so the kernel steers the *table DMA itself* with
scalar-prefetched indices: grid step (b, j) copies table row idx[b, j]
into VMEM and accumulates it onto out[b] (output revisiting across the
inner j steps). Rows are blocked (ROW_TILE bags per step) so each DMA
moves a (ROW_TILE, D) slab — the production variant additionally sorts
indices for DMA locality (see EXPERIMENTS.md §Perf).

  table (V, D) f32,  idx (B, BAG) i32  ->  out (B, D) f32
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, table_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += table_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag_1row(idx, table, *, interpret: bool = True):
    """Row-at-a-time variant: grid (B, BAG); each step DMAs one table row
    (1, D) selected by the prefetched index and accumulates into out[b]."""
    b, bag = idx.shape
    v, d = table.shape
    grid = (b, bag)

    def table_map(i, j, idx_ref):
        return (idx_ref[i, j], 0)

    def out_map(i, j, idx_ref):
        return (i, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[pl.BlockSpec((1, d), table_map)],
        out_specs=pl.BlockSpec((1, d), out_map),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=interpret,
    )(idx, table)
