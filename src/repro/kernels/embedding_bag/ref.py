"""Pure-jnp oracle for embedding_bag."""
from __future__ import annotations

import jax.numpy as jnp


def embedding_bag_ref(idx, table):
    return jnp.take(table, idx, axis=0).sum(axis=1)
