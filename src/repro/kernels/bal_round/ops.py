"""Host-side ELL construction + jitted driver for the fused balance round.

``core.balance.rebalance`` feeds the composed round a single-chunk arc
slab (the whole graph, sorted per round inside the jit). The fused round
wants the graph in ELL form once — one row per vertex, D padded neighbor
lanes — so the per-round work is gathers (XLA, inside the same jit
program) plus the two Pallas kernels. Rows are the label-table space
``0 .. n_pad`` (+ tile padding): the sentinel and padded rows carry no
arcs and are masked by the ``valid`` column exactly like the composed
path masks them, so (labels, block_w) trajectories are bit-identical.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .bal_round import (I32_MAX, bal_scores, bal_scores_vmem_bytes,
                        greedy_pick)
from ..dispatch import VMEM_BUDGET_BYTES
from ..lp_move.ops import LANE, ROW_TILE, _round_up, ell_from_csr


def build_balance_ell(g, n_pad: int):
    """(R, D) neighbor-id / weight ELL over the (n_pad + 1) label-table
    row space (tile-padded); -1 / 0 padding."""
    deg = np.diff(g.indptr)
    D = _round_up(int(deg.max()) if deg.size else 1, LANE)
    R = _round_up(n_pad + 1, ROW_TILE)
    idx = np.full((R, D), -1, dtype=np.int32)
    w = np.zeros((R, D), dtype=np.int32)
    idx_full, w_full = ell_from_csr(np.asarray(g.indptr),
                                    np.asarray(g.adjncy, dtype=np.int64),
                                    np.asarray(g.eweights), D)
    idx[:g.n] = idx_full
    w[:g.n] = w_full
    return idx, w


def balance_ell_fits(R: int, D: int, restricted: bool = False) -> bool:
    return bal_scores_vmem_bytes(R, D, ROW_TILE,
                                 restricted=restricted) <= VMEM_BUDGET_BYTES


def build_balance_ell_dist(shards):
    """Per-PE ELL of the local arc shards: rows are local vertices
    (+ sentinel + tile padding), lanes hold *dst table indices* into the
    PE's (local + ghost + sentinel) label table. Sentinel arcs
    (src == n_loc) are dropped — arc-less rows never move."""
    P, n_loc = shards.P, shards.n_loc
    D_true = 1
    for p in range(P):
        sv = shards.arc_src[p][shards.arc_src[p] < n_loc]
        if sv.size:
            D_true = max(D_true, int(np.bincount(sv).max()))
    D = _round_up(D_true, LANE)
    R = _round_up(n_loc + 1, ROW_TILE)
    idx = np.full((P, R, D), -1, dtype=np.int32)
    w = np.zeros((P, R, D), dtype=np.int32)
    for p in range(P):
        real = shards.arc_src[p] < n_loc
        sv = shards.arc_src[p][real].astype(np.int64)
        order = np.argsort(sv, kind="stable")
        sv = sv[order]
        pos = np.arange(sv.shape[0]) - np.searchsorted(sv, sv, side="left")
        idx[p, sv, pos] = shards.arc_dst_idx[p][real][order]
        w[p, sv, pos] = shards.arc_w[p][real][order]
    return idx, w


def _col(x, R, fill=0):
    """(num,) -> (R, 1) column, padded rows carry ``fill``."""
    pad = R - x.shape[0]
    return jnp.concatenate(
        [x, jnp.full((pad,), fill, x.dtype)])[:, None]


def fused_round_scores(tab, lab_src, bw, l_max, parent, ell_idx, ell_w,
                       vw_pad, vld, salt, *, restricted, interpret):
    """Gather ELL operands + run ``bal_scores``. ``tab`` is the label
    table ELL lanes index into (host path: == ``lab_src``; dist path:
    local + ghost + sentinel); ``lab_src``/``vw_pad``/``vld`` live over
    the row space whose ``(rel, tgt)`` the caller consumes. Fallback
    target / feasibility columns are composed exactly as
    ``core.balance.balance_gains`` composes them."""
    R, _ = ell_idx.shape
    num = lab_src.shape[0]
    k = bw.shape[0]
    valid_l = ell_idx >= 0
    nlab = jnp.where(valid_l, tab[jnp.where(valid_l, ell_idx, 0)], -1)
    nl = jnp.where(valid_l, nlab, 0)
    nbw = bw[nl]
    nlm = l_max[nl]
    over_own = bw[lab_src] > l_max[lab_src]
    if restricted:
        grp_min = jax.ops.segment_min(bw, parent, num_segments=k)
        is_min = bw == grp_min[parent]
        bid = jnp.where(is_min, jnp.arange(k, dtype=jnp.int32), I32_MAX)
        grp_argmin = jax.ops.segment_min(bid, parent, num_segments=k)
        fb_t = grp_argmin[parent[lab_src]]
    else:
        fb_t = jnp.full((num,), jnp.argmin(bw).astype(jnp.int32))
    fb_ok = (bw[fb_t] <= l_max[fb_t] - vw_pad) & (fb_t != lab_src)
    kw = {}
    if restricted:
        kw = dict(npar=parent[nl], opar=_col(parent[lab_src], R))
    rel, tgt = bal_scores(
        nlab, ell_w, nbw, nlm, _col(lab_src, R), _col(vw_pad, R),
        _col(over_own.astype(jnp.int32), R), _col(vld.astype(jnp.int32), R),
        _col(fb_t, R), _col(fb_ok.astype(jnp.int32), R),
        jnp.reshape(salt, (1, 1)), restricted=restricted,
        row_tile=ROW_TILE, interpret=interpret, **kw)
    return rel[:num, 0], tgt[:num, 0]


@functools.partial(jax.jit, static_argnames=("n", "top_m", "restricted",
                                             "interpret"))
def balance_round_fused(labels, block_w, l_max, parent, ell_idx, ell_w,
                        vweights, valid, salt, *, n, top_m,
                        restricted=False, interpret=True):
    """Fused twin of ``core.balance.balance_round`` — same pool ranking,
    same accept rule, bit-identical (labels, block_w) trajectory."""
    rel, tgt = fused_round_scores(
        labels, labels, block_w, l_max, parent, ell_idx, ell_w,
        vweights, valid, salt, restricted=restricted, interpret=interpret)
    vals, vidx = lax.top_k(rel, top_m)
    accept, block_w = greedy_pick(vals, tgt[vidx], labels[vidx],
                                  vweights[vidx], block_w, l_max,
                                  interpret=interpret)
    labels = labels.at[vidx].set(
        jnp.where(accept, tgt[vidx], labels[vidx]))
    return labels, block_w, jnp.any(block_w > l_max)
