"""Composed-XLA oracle for the balance-round kernels.

Whole-array jnp mirrors of ``bal_round._scores_kernel`` /
``bal_round._pick_kernel`` (no Pallas): the property tests check the
kernels against these, and these against ``core.balance.balance_gains``
/ ``greedy_select`` on the equivalent sorted-slab inputs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .bal_round import I32_MAX, NEG_INF
from ..lp_move.lp_move import _h32


def bal_scores_ref(nlab, nw, nbw, nlm, own, vw, ovr, vld, fb_t, fb_ok,
                   salt, npar=None, opar=None, *, restricted=False):
    """Reference ``(rel, tgt)`` for the ELL balance-scores inputs."""
    validn = nlab >= 0
    ok = (nbw <= (nlm - vw)) & (nlab != own) & validn
    if restricted:
        ok &= npar == opar
    eq = nlab[:, :, None] == nlab[:, None, :]
    conn = jnp.sum(jnp.where(eq, nw[:, :, None], 0), axis=1)
    score = jnp.where(ok, conn, -1)
    best = jnp.max(score, axis=1, keepdims=True)
    is_best = score == best
    light = jnp.min(jnp.where(is_best, nbw, I32_MAX), axis=1,
                    keepdims=True)
    is_best &= nbw == light
    h = _h32(nlab, salt[0, 0])
    hbest = jnp.min(jnp.where(is_best, h, I32_MAX), axis=1, keepdims=True)
    is_best &= h == hbest
    tgt_adj = jnp.min(jnp.where(is_best, nlab, I32_MAX), axis=1,
                      keepdims=True)
    own_conn = jnp.sum(jnp.where((nlab == own) & validn, nw, 0), axis=1,
                       keepdims=True)
    has_adj = best >= 0
    g = jnp.where(has_adj, best - own_conn, -own_conn)
    tgt = jnp.where(has_adj, tgt_adj, fb_t)
    movable = (ovr != 0) & (has_adj | (fb_ok != 0)) & (vld != 0)
    gf = g.astype(jnp.float32)
    cv = jnp.maximum(vw.astype(jnp.float32), 1.0)
    rel = jnp.where(g >= 0, gf * cv, gf / cv)
    return jnp.where(movable, rel, NEG_INF), tgt


def greedy_pick_ref(vals, tgt_blk, src_blk, cand_w, block_w, l_max):
    """Reference greedy pool application — the ``core.balance``
    ``greedy_select`` loop, restated here to keep this module import-free
    of ``core`` (which itself dispatches into this package)."""
    m = vals.shape[0]

    def body(i, carry):
        block_w, accept = carry
        t, b, cw = tgt_blk[i], src_blk[i], cand_w[i]
        ok = (vals[i] > NEG_INF) & (block_w[b] > l_max[b]) & \
             (block_w[t] <= l_max[t] - cw) & (t != b)
        cwd = jnp.where(ok, cw, 0)
        block_w = block_w.at[b].add(-cwd).at[t].add(cwd)
        accept = accept.at[i].set(ok)
        return block_w, accept

    block_w, accept = jax.lax.fori_loop(
        0, m, body, (block_w, jnp.zeros((m,), jnp.bool_)))
    return accept, block_w
