"""Pallas TPU kernels: one fused balancing round (paper §4, Balancing).

``core.balance.balance_round`` composes each round out of a lexicographic
sort of the arc slab, two segment-sum passes, the four-stage tie-broken
argmax, and a ``fori_loop`` of dense-table reads for the greedy pool
application — every stage re-reading an O(m) or O(top_m * k) operand from
HBM. The two kernels here fuse those stages:

  * ``bal_scores`` — per-vertex relative gains + targets over the ELL
    slab (rows = vertices, D padded neighbor lanes) resident in VMEM:
    connection weights via the row-tile label-equality cube (the same
    sort-free contraction as ``kernels.lp_move``), the composed argmax
    tie chain (max score, lightest target block, min ``hash32(label,
    salt)``, min label) as masked row reductions, then the paper's
    relative gain ``g*c(v)`` / ``g/c(v)`` in the identical f32 op order.
    Per-neighbor block weights/budgets (``nbw``/``nlm``) and the O(k)
    fallback-target columns (``fb_t``/``fb_ok`` — lightest feasible
    block, composed outside the kernel exactly as the reference) are
    pre-gathered: the kernel keeps the O(m) part single-pass.
  * ``greedy_pick`` — the deterministic greedy application of the ranked
    candidate pool: a ``fori_loop`` over pool entries with the block
    weight table carried in registers/VMEM instead of re-reading it from
    HBM each step. One-hot lane reductions replace the composed path's
    dynamic gathers; the accept rule and integer updates are identical.

Inputs of ``bal_scores`` (R rows, D lanes, all i32 unless noted):
  nlab (R, D)  neighbor block labels (sentinel -1 on padding)
  nw   (R, D)  arc weights (0 on padding)
  nbw  (R, D)  block weight of the neighbor's block
  nlm  (R, D)  budget of the neighbor's block
  npar (R, D)  parent group of the neighbor's block (restricted only)
  own/opar/vw/ovr/vld/fb_t/fb_ok (R, 1) per-row columns: own block (+ its
  parent group, restricted only), vertex weight, overloaded / valid /
  fallback-feasible flags, fallback target
  salt (1, 1) u32
Outputs: rel (R, 1) f32 relative gain (NEG_INF = must not move),
  tgt (R, 1) i32 chosen target block.

Bit-identical to ``core.balance.balance_gains`` / ``greedy_select``
(enforced by tests/test_fused_kernels.py): integer arithmetic matches op
for op, and the single f32 multiply/divide happens on identical operands.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl

from ..lp_move.lp_move import _h32

I32_MAX = np.int32(np.iinfo(np.int32).max)
NEG_INF = np.float32(-np.inf)


def _scores_kernel(*refs, R, D, TA, restricted):
    if restricted:
        (salt_ref, nlab_ref, nw_ref, nbw_ref, nlm_ref, npar_ref, own_ref,
         opar_ref, vw_ref, ovr_ref, vld_ref, fbt_ref, fbok_ref,
         rel_ref, tgt_ref) = refs
    else:
        (salt_ref, nlab_ref, nw_ref, nbw_ref, nlm_ref, own_ref, vw_ref,
         ovr_ref, vld_ref, fbt_ref, fbok_ref, rel_ref, tgt_ref) = refs
        npar_ref = opar_ref = None
    salt = salt_ref[0, 0]

    def tile(t, _):
        rows = (pl.dslice(t * TA, TA), slice(None))
        nlab = pl.load(nlab_ref, rows)               # (TA, D)
        nw = pl.load(nw_ref, rows)
        nbw = pl.load(nbw_ref, rows)
        nlm = pl.load(nlm_ref, rows)
        own = pl.load(own_ref, rows)                 # (TA, 1)
        vw = pl.load(vw_ref, rows)
        validn = nlab >= 0
        # target must fit (w <= budget - c, exact at the int32 boundary)
        # and differ from the own block
        ok = (nbw <= (nlm - vw)) & (nlab != own) & validn
        if restricted:
            ok &= pl.load(npar_ref, rows) == pl.load(opar_ref, rows)
        # conn[r, j] = sum_i w[r, i] * [lab[r, i] == lab[r, j]]
        eq = nlab[:, :, None] == nlab[:, None, :]    # (TA, D, D)
        conn = jnp.sum(jnp.where(eq, nw[:, :, None], 0), axis=1)
        score = jnp.where(ok, conn, -1)
        best = jnp.max(score, axis=1, keepdims=True)
        is_best = score == best
        wk = jnp.where(is_best, nbw, I32_MAX)
        light = jnp.min(wk, axis=1, keepdims=True)
        is_best &= nbw == light
        h = _h32(nlab, salt)
        hk = jnp.where(is_best, h, I32_MAX)
        hbest = jnp.min(hk, axis=1, keepdims=True)
        is_best &= h == hbest
        tgt_adj = jnp.min(jnp.where(is_best, nlab, I32_MAX), axis=1,
                          keepdims=True)
        own_conn = jnp.sum(jnp.where((nlab == own) & validn, nw, 0),
                           axis=1, keepdims=True)
        has_adj = best >= 0
        g = jnp.where(has_adj, best - own_conn, -own_conn)
        tgt = jnp.where(has_adj, tgt_adj, pl.load(fbt_ref, rows))
        movable = (pl.load(ovr_ref, rows) != 0) & \
            (has_adj | (pl.load(fbok_ref, rows) != 0)) & \
            (pl.load(vld_ref, rows) != 0)
        gf = g.astype(jnp.float32)
        cv = jnp.maximum(vw.astype(jnp.float32), 1.0)
        rel = jnp.where(g >= 0, gf * cv, gf / cv)
        rel = jnp.where(movable, rel, NEG_INF)
        pl.store(rel_ref, rows, rel)
        pl.store(tgt_ref, rows, tgt)
        return 0

    lax.fori_loop(0, R // TA, tile, 0)


@functools.partial(jax.jit, static_argnames=("restricted", "row_tile",
                                             "interpret"))
def bal_scores(nlab, nw, nbw, nlm, own, vw, ovr, vld, fb_t, fb_ok, salt,
               npar=None, opar=None, *, restricted: bool = False,
               row_tile: int = 8, interpret: bool = True):
    """Fused per-vertex relative gains + targets. Returns ``(rel, tgt)``
    of shapes ``(R, 1)`` f32 / i32."""
    R, D = nlab.shape
    assert R % row_tile == 0, (R, row_tile)
    assert restricted == (npar is not None) == (opar is not None)
    out_shapes = (
        jax.ShapeDtypeStruct((R, 1), jnp.float32),
        jax.ShapeDtypeStruct((R, 1), jnp.int32),
    )
    kernel = functools.partial(_scores_kernel, R=R, D=D, TA=row_tile,
                               restricted=restricted)
    inputs = [salt, nlab, nw, nbw, nlm]
    if restricted:
        inputs += [npar, own, opar]
    else:
        inputs.append(own)
    inputs += [vw, ovr, vld, fb_t, fb_ok]
    return pl.pallas_call(
        kernel,
        out_shape=out_shapes,
        interpret=interpret,
    )(*inputs)


def _pick_kernel(vals_ref, tgt_ref, blk_ref, cw_ref, bw_ref, lm_ref,
                 acc_ref, bwout_ref, *, M, K):
    vals = vals_ref[...]                              # (1, M) f32
    tgt = tgt_ref[...]
    blk = blk_ref[...]
    cw = cw_ref[...]
    lm = lm_ref[...]                                  # (1, K)
    iota_m = lax.broadcasted_iota(jnp.int32, (1, M), 1)
    iota_k = lax.broadcasted_iota(jnp.int32, (1, K), 1)

    def body(i, carry):
        bw, acc = carry
        sel = iota_m == i
        v = jnp.max(jnp.where(sel, vals, NEG_INF))
        t = jnp.sum(jnp.where(sel, tgt, 0))
        b = jnp.sum(jnp.where(sel, blk, 0))
        c = jnp.sum(jnp.where(sel, cw, 0))
        bw_b = jnp.sum(jnp.where(iota_k == b, bw, 0))
        lm_b = jnp.sum(jnp.where(iota_k == b, lm, 0))
        bw_t = jnp.sum(jnp.where(iota_k == t, bw, 0))
        lm_t = jnp.sum(jnp.where(iota_k == t, lm, 0))
        ok = (v > NEG_INF) & (bw_b > lm_b) & (bw_t <= lm_t - c) & (t != b)
        cwd = jnp.where(ok, c, 0)
        bw = bw - jnp.where(iota_k == b, cwd, 0) \
                + jnp.where(iota_k == t, cwd, 0)
        acc = acc | (sel & ok)
        return bw, acc

    bw, acc = lax.fori_loop(
        0, M, body, (bw_ref[...], jnp.zeros((1, M), jnp.bool_)))
    acc_ref[...] = acc.astype(jnp.int32)
    bwout_ref[...] = bw


@functools.partial(jax.jit, static_argnames=("interpret",))
def greedy_pick(vals, tgt_blk, src_blk, cand_w, block_w, l_max, *,
                interpret: bool = True):
    """Fused greedy application of a ranked pool. ``vals`` is (M,) f32
    (descending), the rest (M,) / (K,) i32. Returns ``(accept, block_w)``
    — (M,) bool and the updated (K,) table, bit-identical to
    ``core.balance.greedy_select``."""
    (M,) = vals.shape
    (K,) = block_w.shape
    acc, bw = pl.pallas_call(
        functools.partial(_pick_kernel, M=M, K=K),
        out_shape=(jax.ShapeDtypeStruct((1, M), jnp.int32),
                   jax.ShapeDtypeStruct((1, K), jnp.int32)),
        interpret=interpret,
    )(vals[None], tgt_blk[None], src_blk[None], cand_w[None],
      block_w[None], l_max[None])
    return acc[0] != 0, bw[0]


def bal_scores_vmem_bytes(R: int, D: int, row_tile: int = 8,
                          restricted: bool = False) -> int:
    """Planning estimate of the scores kernel's VMEM working set."""
    slabs = (5 if restricted else 4) * R * D * 4
    cols = (9 if restricted else 8) * R * 4
    cube = row_tile * D * D * 4
    return slabs + cols + cube
