"""Kernel-mode resolution shared by the fused Pallas paths.

The ``kernel`` knob on ``PartitionerConfig`` selects the implementation
of the three fused hot loops (docs/KERNELS.md):

  * ``"composed"`` — the original XLA-composed pipelines (sort +
    segment ops). Always available; the reference the fused kernels are
    bit-identical to.
  * ``"fused"``    — single-pass Pallas kernels (lp_move, seg_merge,
    balance_round). On TPU they compile to Mosaic; off-TPU they run in
    ``interpret=True`` mode, which is correct but slow — useful only to
    exercise the fused code path in tests/CI.
  * ``"auto"``     — per-backend default: "fused" on TPU, "composed"
    anywhere else.

Fused wrappers also fall back to the composed path per call site when a
shape exceeds the kernel's VMEM budget (see ``fits_vmem``); the fallback
is safe because both paths are bit-identical by construction, and it is
*observable*, not silent: every decision is recorded via
``report_fallback`` (a one-shot warning per kernel plus a
``kernel-fallback`` trace record the drivers drain into the request
trace through ``drain_fallback_records``).
"""
from __future__ import annotations

import functools
import warnings
from typing import Dict, List

KERNEL_MODES = ("auto", "fused", "composed")

# single-core VMEM working-set budget the fused wrappers plan against
# (v5e has ~16 MiB more than half of which we leave to Mosaic)
VMEM_BUDGET_BYTES = 8 * 2**20


def check_kernel_mode(kernel: str) -> str:
    if kernel not in KERNEL_MODES:
        raise ValueError(f"unknown kernel mode {kernel!r}; expected one "
                         f"of {KERNEL_MODES}")
    return kernel


@functools.lru_cache(maxsize=1)
def _default_backend_is_tpu() -> bool:
    import jax
    return jax.default_backend() == "tpu"


def resolve_kernel_mode(kernel: str) -> str:
    """Map the config knob to a concrete mode ("fused" | "composed")."""
    check_kernel_mode(kernel)
    if kernel == "auto":
        return "fused" if _default_backend_is_tpu() else "composed"
    return kernel


def kernel_interpret() -> bool:
    """Whether fused kernels must run in Pallas interpret mode (no TPU)."""
    return not _default_backend_is_tpu()


def fits_vmem(*arrays_bytes: int, budget: int = VMEM_BUDGET_BYTES) -> bool:
    """Whole-chunk kernels keep every operand resident in VMEM; callers
    sum their operand footprints and fall back to composed beyond this."""
    return sum(arrays_bytes) <= budget


# --- fallback observability -------------------------------------------
# A fused wrapper that falls back to the composed path is *correct* but
# silently loses the kernel speedup; callers used to find out only by
# profiling. Decision sites call ``report_fallback`` so the drivers can
# drain ``kernel-fallback`` records into the run trace, and the first
# fallback per kernel raises a one-shot ``UserWarning``.

_fallback_records: List[Dict] = []
_fallback_warned: set = set()


def report_fallback(kernel: str, estimated_bytes: int,
                    budget: int = VMEM_BUDGET_BYTES,
                    detail: str = "") -> None:
    """Record one fused->composed fallback decision."""
    _fallback_records.append({
        "event": "kernel-fallback",
        "kernel": kernel,
        "estimated_bytes": int(estimated_bytes),
        "budget_bytes": int(budget),
        "detail": detail,
    })
    if kernel not in _fallback_warned:
        _fallback_warned.add(kernel)
        warnings.warn(
            f"fused kernel {kernel!r} fell back to the composed path: "
            f"estimated working set {int(estimated_bytes)} B exceeds "
            f"the {int(budget)} B VMEM budget ({detail or 'no detail'})"
            "; results are identical but the kernel speedup is lost "
            "(warning once per kernel)",
            UserWarning, stacklevel=3)


def drain_fallback_records() -> List[Dict]:
    """Return-and-clear the pending ``kernel-fallback`` records."""
    records = list(_fallback_records)
    _fallback_records.clear()
    return records


def reset_fallback_state() -> None:
    """Forget pending records and re-arm the one-shot warnings."""
    _fallback_records.clear()
    _fallback_warned.clear()
