"""Host-side ELL construction + jitted driver for the fused LP move kernel.

The composed clustering path feeds ``core.lp.cluster_iteration`` padded
*arc slabs* (B, m_pad). The fused kernel wants the same chunks in ELL
form — one row per chunk vertex, D padded neighbor lanes — so the gain
matrix is a dense per-row contraction instead of a sorted segment scan.
Chunk vertex ranges come from ``core.lp.chunk_bounds``: identical ranges
and the identical per-chunk salt stream keep the fused iteration
bit-identical to the composed one.

Gathers of neighbor labels / cluster weights stay in XLA *inside the
same jit program* as the kernel (they are memory-bound shuffles XLA
already emits optimally); only the arithmetic-dense move step runs in
Pallas.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .lp_move import I32_MAX, lp_move_chunk, lp_move_vmem_bytes
from ..dispatch import VMEM_BUDGET_BYTES

LANE = 128          # ELL neighbor lanes padded to the TPU lane width
ROW_TILE = 8        # sublane tile walked by the kernel's fori loops


@dataclasses.dataclass(frozen=True)
class MoveChunks:
    """Padded per-chunk ELL slabs for the fused LP move kernel.

    Row ``r`` of chunk ``b`` is vertex ``v0[b] + r``; rows beyond the
    chunk's true vertex range (and neighbor lanes beyond a vertex's
    degree) carry sentinel ``idx = -1`` / ``w = 0`` and can never move.
    """
    idx: np.ndarray   # (B, R, D) int32 neighbor vertex ids, -1 padding
    w: np.ndarray     # (B, R, D) int32 arc weights, 0 padding
    v0: np.ndarray    # (B,) int32 first vertex id of each chunk
    n: int            # true vertex count
    n_pad: int        # padded vertex count == composed sentinel id
    num_chunks: int

    @property
    def shape(self):
        return self.idx.shape


def _next_pow2(x: int) -> int:
    return 1 << max(0, (x - 1)).bit_length()


def _round_up(x: int, mult: int) -> int:
    return ((max(x, 1) + mult - 1) // mult) * mult


def ell_from_csr(indptr: np.ndarray, adjncy: np.ndarray,
                 eweights: np.ndarray, D: int):
    """Dense (n, D) neighbor-id / weight tables from CSR; -1 / 0 padding."""
    n = indptr.shape[0] - 1
    deg = np.diff(indptr)
    idx = np.full((n, D), -1, dtype=np.int32)
    w = np.zeros((n, D), dtype=np.int32)
    if adjncy.shape[0]:
        rows = np.repeat(np.arange(n), deg)
        pos = np.arange(adjncy.shape[0]) - np.repeat(indptr[:-1], deg)
        idx[rows, pos] = adjncy
        w[rows, pos] = eweights
    return idx, w


def build_move_chunks(g, num_chunks: int) -> MoveChunks:
    """ELL twin of ``core.lp.build_chunks`` (same bounds, same padding
    bucket policy: pow-2 rows, lane-multiple neighbor width)."""
    from ...core import lp

    if g.total_eweight >= 2**31 or g.total_vweight >= 2**31:
        raise ValueError(
            f"build_move_chunks: total vertex/edge weight "
            f"({g.total_vweight}/{g.total_eweight}) must be < 2^31")
    n = g.n
    n_pad = _next_pow2(n)
    bounds = lp.chunk_bounds(g, num_chunks)
    B = len(bounds) - 1
    deg = np.diff(g.indptr)
    D = _round_up(int(deg.max()) if deg.size else 1, LANE)
    R = _round_up(_next_pow2(max(
        bounds[b + 1] - bounds[b] for b in range(B))), ROW_TILE)
    idx_full, w_full = ell_from_csr(np.asarray(g.indptr),
                                    np.asarray(g.adjncy, dtype=np.int64),
                                    np.asarray(g.eweights), D)
    idx = np.full((B, R, D), -1, dtype=np.int32)
    w = np.zeros((B, R, D), dtype=np.int32)
    for b in range(B):
        r0, r1 = bounds[b], bounds[b + 1]
        idx[b, :r1 - r0] = idx_full[r0:r1]
        w[b, :r1 - r0] = w_full[r0:r1]
    return MoveChunks(idx=idx, w=w,
                      v0=np.asarray(bounds[:-1], dtype=np.int32),
                      n=n, n_pad=n_pad, num_chunks=B)


def move_chunks_fit_vmem(chunks: MoveChunks) -> bool:
    _, R, D = chunks.shape
    return lp_move_vmem_bytes(R, D, ROW_TILE) <= VMEM_BUDGET_BYTES


def build_move_chunks_dist(shards, num_chunks: int):
    """ELL twin of ``graphs.distribute.chunk_local_arcs``: per-(PE, chunk)
    slabs of the PE's local vertices with neighbor lanes holding *dst
    table indices* (labels are gathered jit-side from the live halo
    table). Sentinel arcs (src == n_loc) are dropped — the sentinel row
    must never move, which the kernel guarantees for arc-less rows.

    Returns ``(idx, w, v0)`` with shapes (P, B, R, D), (P, B, R, D),
    (P, B); row ``r`` of slab (p, b) is local vertex ``v0[p, b] + r``.
    """
    from ...graphs.distribute import chunk_local_arcs

    srcs, dsts, ws = chunk_local_arcs(shards, num_chunks)
    P, B, _ = srcs.shape
    n_loc = shards.n_loc
    R_true = 1
    D_true = 1
    spans = np.zeros((P, B, 2), dtype=np.int64)
    for p in range(P):
        for b in range(B):
            sv = srcs[p, b]
            real = sv < n_loc
            if real.any():
                v0, v1 = int(sv[real].min()), int(sv[real].max()) + 1
                spans[p, b] = (v0, v1)
                R_true = max(R_true, v1 - v0)
                D_true = max(D_true, int(np.bincount(sv[real]).max()))
    R = _round_up(_next_pow2(R_true), ROW_TILE)
    D = _round_up(D_true, LANE)
    idx = np.full((P, B, R, D), -1, dtype=np.int32)
    w = np.zeros((P, B, R, D), dtype=np.int32)
    for p in range(P):
        for b in range(B):
            sv = srcs[p, b]
            real = sv < n_loc
            if not real.any():
                continue
            v0 = spans[p, b, 0]
            rows = (sv[real] - v0).astype(np.int64)
            # arcs are src-sorted, so lanes are positions within the run
            pos = np.arange(rows.shape[0]) - np.searchsorted(
                rows, rows, side="left")
            idx[p, b, rows, pos] = dsts[p, b, real]
            w[p, b, rows, pos] = ws[p, b, real]
    return idx, w, spans[:, :, 0].astype(np.int32)


def _chunk_step(labels, cluster_w, c_idx, c_w, v0, salt, vweights, W, R,
                interpret):
    """Gather ELL operands, run the kernel, apply the chunk's moves."""
    rows = v0 + jnp.arange(R, dtype=jnp.int32)
    own = labels[rows][:, None]              # clamp-gather: dup rows inert
    vwr = vweights[rows][:, None]
    valid = c_idx >= 0
    nlab = jnp.where(valid, labels[jnp.where(valid, c_idx, 0)], -1)
    ncw = jnp.where(valid, cluster_w[jnp.where(valid, nlab, 0)], I32_MAX)
    scal = jnp.concatenate([
        jnp.reshape(W.astype(jnp.int32), (1, 1)),
        jnp.reshape(v0.astype(jnp.int32), (1, 1))], axis=1)
    moved, tgt = lp_move_chunk(nlab, c_w, ncw, own, vwr, scal,
                               jnp.reshape(salt, (1, 1)),
                               fit_sum=True, row_tile=ROW_TILE,
                               interpret=interpret)
    mrow = moved[:, 0] != 0
    trow = tgt[:, 0]
    orow = own[:, 0]
    new_rows = jnp.where(mrow, trow, orow)
    # rows past the label table are clamp-gathered dupes: drop their writes
    labels = labels.at[rows].set(new_rows, mode="drop")
    vwm = jnp.where(mrow, vwr[:, 0], 0)
    cluster_w = cluster_w.at[trow].add(vwm, mode="drop") \
                         .at[orow].add(-vwm, mode="drop")
    return labels, cluster_w


@functools.partial(jax.jit, static_argnames=("n", "interpret"))
def cluster_iteration_fused(labels, cluster_w, chunks_idx, chunks_w, v0s,
                            vweights, max_cluster_weight, seed, *, n,
                            interpret=True):
    """Fused twin of ``core.lp.cluster_iteration`` — same salt stream,
    bit-identical (labels, cluster_w) trajectory."""
    B, R, _ = chunks_idx.shape

    def body(carry, xs):
        labels, cluster_w = carry
        c_idx, c_w, v0, salt = xs
        labels, cluster_w = _chunk_step(
            labels, cluster_w, c_idx, c_w, v0, salt, vweights,
            max_cluster_weight, R, interpret)
        return (labels, cluster_w), ()

    salts = (jnp.arange(B, dtype=jnp.uint32) * np.uint32(0x85EBCA6B)
             + seed.astype(jnp.uint32))
    (labels, cluster_w), _ = jax.lax.scan(
        body, (labels, cluster_w), (chunks_idx, chunks_w, v0s, salts))
    return labels, cluster_w
