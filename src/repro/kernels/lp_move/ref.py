"""Pure-jnp oracle for the fused LP move kernel.

Whole-array XLA mirror of the kernel math (no Pallas, no tiling) over
the same ELL operands — the property tests assert the kernel is
bit-identical to this under padding edges; ``tests/test_fused_kernels.py``
separately asserts the end-to-end fused iteration is bit-identical to
the production composed path (``core.lp.cluster_iteration``).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from jax import lax

from .lp_move import I32_MAX, _h32


def lp_move_chunk_ref(nlab, nw, ncw, own, vw, scal, salt, nbud=None, *,
                      fit_sum: bool = True):
    """Reference ``(moved, tgt)`` for one ELL chunk; shapes as the kernel."""
    R, _ = nlab.shape
    W = scal[0, 0]
    v0 = scal[0, 1]
    s = salt[0, 0]
    validn = nlab >= 0
    staying = nlab == own
    if fit_sum:
        fits = ((ncw + vw) <= W) | staying
    else:
        fits = (ncw <= (nbud - vw)) | staying
    fits = fits & validn
    eq = nlab[:, :, None] == nlab[:, None, :]
    conn = jnp.sum(jnp.where(eq, nw[:, :, None], 0), axis=1)
    score = jnp.where(fits, conn, -1)
    best = jnp.max(score, axis=1, keepdims=True)
    is_best = score == best
    wk = jnp.where(is_best, ncw, I32_MAX)
    light = jnp.min(wk, axis=1, keepdims=True)
    is_best &= ncw == light
    h = _h32(nlab, s)
    hk = jnp.where(is_best, h, I32_MAX)
    hbest = jnp.min(hk, axis=1, keepdims=True)
    is_best &= h == hbest
    tgt = jnp.min(jnp.where(is_best, nlab, I32_MAX), axis=1, keepdims=True)
    own_conn = jnp.sum(jnp.where(staying & validn, nw, 0), axis=1,
                       keepdims=True)
    mv = (best > own_conn) & (tgt != own) & (tgt < I32_MAX) & (best > 0)
    tgt = jnp.where(mv, tgt, own)

    tgt_u = jnp.reshape(tgt, (1, R))
    own_u = jnp.reshape(own, (1, R))
    vw_u = jnp.reshape(vw, (1, R))
    mvw_u = jnp.where(jnp.reshape(mv, (1, R)), vw_u, 0)
    same = tgt_u == tgt                               # (R, R)
    d_in = jnp.sum(jnp.where(same, mvw_u, 0), axis=1, keepdims=True)
    d_out = jnp.sum(jnp.where(own_u == tgt, mvw_u, 0), axis=1,
                    keepdims=True)
    new_cw = light + d_in - d_out
    cand = mv & (new_cw > W)

    salt2 = s ^ np.uint32(0x9E3779B9)
    iota_u = lax.broadcasted_iota(jnp.int32, (1, R), 1)
    iota_v = lax.broadcasted_iota(jnp.int32, (R, 1), 0)
    rk_u = _h32(v0 + iota_u, salt2)
    rk_v = _h32(v0 + iota_v, salt2)
    cvw_u = jnp.where(jnp.reshape(cand, (1, R)), vw_u, 0)
    moved_in = jnp.sum(jnp.where(same, cvw_u, 0), axis=1, keepdims=True)
    prior = (rk_u < rk_v) | ((rk_u == rk_v) & (iota_u <= iota_v))
    within = jnp.sum(jnp.where(same & prior, cvw_u, 0), axis=1,
                     keepdims=True)
    allowed = jnp.maximum(W - (new_cw - moved_in), 0)
    revert = cand & (within > allowed)
    moved = mv & ~revert
    return moved.astype(jnp.int32), tgt
