"""Pallas TPU kernel: one fused LP clustering chunk step (paper §4).

``core.lp._cluster_chunk`` composes the chunk move out of a sort, two
segment-sum passes, a four-stage tie-broken argmax, and a second sorted
cumulative-sum pass for the overweight revert — eight XLA ops each
re-reading the arc slab from HBM. This kernel performs the whole step —

    gather -> gain -> argmax -> budget check -> hash-ordered revert

— in a single pass over the chunk's ELL slab resident in VMEM.

Reformulation (sort-free, docs/KERNELS.md):

  * gains: per row the DxD label-equality matrix contracted with the
    weight vector, ``conn[j] = sum_i w[i] * [lab[i] == lab[j]]`` —
    MXU-shaped; computed in int32 (exact, same arithmetic as the
    composed ``segment_sum``).
  * argmax: the composed tie-break chain (max score, then lightest
    target cluster, then min ``hash32(label, salt)``, then min label)
    becomes four masked row reductions.
  * revert: the composed path sorts candidate movers by (cluster,
    hash32(vertex, salt')) and reverts the cumulative-weight suffix that
    exceeds the budget. Sort-free pairwise form over the chunk rows:

      d_in[v]     = sum_u move_u · c(u) · [tgt_u == tgt_v]
      d_out[v]    = sum_u move_u · c(u) · [lab_u == tgt_v]
      new_cw[v]   = cw[tgt_v] + d_in[v] - d_out[v]
      cand_v      = move_v & (new_cw[v] > W)
      moved_in[v] = sum_u cand_u · c(u) · [tgt_u == tgt_v]
      within[v]   = sum_u cand_u · c(u) · [tgt_u == tgt_v]
                                        · [(rk_u, u) <= (rk_v, v)]
      revert_v    = cand_v & (within[v] > max(W - (new_cw[v]
                                                   - moved_in[v]), 0))

    ``(rk, index)`` is exactly the composed sort order (lax.sort is
    stable), so the reverted set is bit-identical. ``cw[tgt_v]`` needs no
    extra gather: the argmax's lightest-cluster tie stage already pinned
    it (``light``).

Layout: the whole chunk stays resident (one grid step); row tiles are
walked with ``fori_loop`` so the (tile, D, D) equality cube and the
(tile, R) pairwise masks bound the VMEM high-water mark. All arithmetic
is int32 in the composed op order — labels are bit-identical to
``core.lp.cluster_iteration`` (enforced by tests/test_fused_kernels.py).

Inputs (R rows = chunk vertices ``v0 .. v0+R-1``, D padded neighbors):
  nlab  (R, D) i32   neighbor labels (sentinel -1 on padding)
  nw    (R, D) i32   arc weights (0 on padding)
  ncw   (R, D) i32   cluster weight of each neighbor's label
  nbud  (R, D) i32   per-label budget (diff fit form only)
  own   (R, 1) i32   current label of the row vertex
  vw    (R, 1) i32   row vertex weight
  W/v0  (1, 2) i32   scalar budget + first row's vertex id
  salt  (1, 1) u32   chunk salt (same stream as the composed path)
Outputs:
  moved (R, 1) i32   1 where the vertex moves (post-revert)
  tgt   (R, 1) i32   its target label (== own where not moved)

``fit_sum=True`` uses the host clustering admission form
``cw + c(v) <= W`` (no ``nbud`` operand); ``fit_sum=False`` the
distributed ``cw <= bud - c(v)`` form. Both match their composed twins.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

I32_MAX = np.int32(np.iinfo(np.int32).max)


def _h32(x: jnp.ndarray, salt: jnp.ndarray) -> jnp.ndarray:
    """int32 mix hash — must match core.lp._hash32 bit for bit."""
    h = (x.astype(jnp.uint32) * np.uint32(2654435761)) ^ salt
    h = h ^ (h >> 15)
    return (h & np.uint32(0x7FFFFFFF)).astype(jnp.int32)


def _kernel(*refs, R, D, TA, TB, fit_sum):
    if fit_sum:
        (scal_ref, salt_ref, nlab_ref, nw_ref, ncw_ref, own_ref, vw_ref,
         moved_ref, tgt_ref, pmove_ref, light_ref, cand_ref,
         newcw_ref) = refs
        nbud_ref = None
    else:
        (scal_ref, salt_ref, nlab_ref, nw_ref, ncw_ref, nbud_ref, own_ref,
         vw_ref, moved_ref, tgt_ref, pmove_ref, light_ref, cand_ref,
         newcw_ref) = refs
    W = scal_ref[0, 0]
    v0 = scal_ref[0, 1]
    salt = salt_ref[0, 0]

    # ---- phase A: gain + argmax + admission per row tile ---------------
    def phase_a(t, _):
        r0 = t * TA
        rows = (pl.dslice(r0, TA), slice(None))
        nlab = pl.load(nlab_ref, rows)               # (TA, D)
        nw = pl.load(nw_ref, rows)
        ncw = pl.load(ncw_ref, rows)
        own = pl.load(own_ref, rows)                 # (TA, 1)
        vw = pl.load(vw_ref, rows)
        validn = nlab >= 0
        staying = nlab == own
        if fit_sum:
            fits = ((ncw + vw) <= W) | staying
        else:
            nbud = pl.load(nbud_ref, rows)
            fits = (ncw <= (nbud - vw)) | staying
        fits = fits & validn
        # conn[r, j] = sum_i w[r, i] * [lab[r, i] == lab[r, j]]
        eq = nlab[:, :, None] == nlab[:, None, :]    # (TA, D, D)
        conn = jnp.sum(jnp.where(eq, nw[:, :, None], 0), axis=1)
        score = jnp.where(fits, conn, -1)
        best = jnp.max(score, axis=1, keepdims=True)
        is_best = score == best
        wk = jnp.where(is_best, ncw, I32_MAX)
        light = jnp.min(wk, axis=1, keepdims=True)
        is_best &= ncw == light
        h = _h32(nlab, salt)
        hk = jnp.where(is_best, h, I32_MAX)
        hbest = jnp.min(hk, axis=1, keepdims=True)
        is_best &= h == hbest
        tgt = jnp.min(jnp.where(is_best, nlab, I32_MAX), axis=1,
                      keepdims=True)
        own_conn = jnp.sum(jnp.where(staying & validn, nw, 0), axis=1,
                           keepdims=True)
        mv = (best > own_conn) & (tgt != own) & (tgt < I32_MAX) & (best > 0)
        pl.store(tgt_ref, rows, jnp.where(mv, tgt, own))
        pl.store(pmove_ref, rows, mv.astype(jnp.int32))
        pl.store(light_ref, rows, light)
        return 0

    lax.fori_loop(0, R // TA, phase_a, 0)

    # ---- phase B1: per-mover updated target-cluster weight -------------
    tgt_u = jnp.reshape(tgt_ref[...], (1, R))
    own_u = jnp.reshape(own_ref[...], (1, R))
    vw_u = jnp.reshape(vw_ref[...], (1, R))
    mvw_u = jnp.reshape(pmove_ref[...], (1, R)) * vw_u

    def phase_b1(t, _):
        r0 = t * TB
        rows = (pl.dslice(r0, TB), slice(None))
        tgt_v = pl.load(tgt_ref, rows)               # (TB, 1)
        light_v = pl.load(light_ref, rows)
        pmove_v = pl.load(pmove_ref, rows)
        d_in = jnp.sum(jnp.where(tgt_u == tgt_v, mvw_u, 0), axis=1,
                       keepdims=True)
        d_out = jnp.sum(jnp.where(own_u == tgt_v, mvw_u, 0), axis=1,
                        keepdims=True)
        new_cw = light_v + d_in - d_out
        cand = (pmove_v != 0) & (new_cw > W)
        pl.store(newcw_ref, rows, new_cw)
        pl.store(cand_ref, rows, cand.astype(jnp.int32))
        return 0

    lax.fori_loop(0, R // TB, phase_b1, 0)

    # ---- phase B2: hash-ordered within-budget revert --------------------
    salt2 = salt ^ np.uint32(0x9E3779B9)
    iota_u = lax.broadcasted_iota(jnp.int32, (1, R), 1)
    rk_u = _h32(v0 + iota_u, salt2)
    cvw_u = jnp.reshape(cand_ref[...], (1, R)) * vw_u

    def phase_b2(t, _):
        r0 = t * TB
        rows = (pl.dslice(r0, TB), slice(None))
        tgt_v = pl.load(tgt_ref, rows)
        cand_v = pl.load(cand_ref, rows) != 0
        pmove_v = pl.load(pmove_ref, rows) != 0
        new_cw = pl.load(newcw_ref, rows)
        iota_v = r0 + lax.broadcasted_iota(jnp.int32, (TB, 1), 0)
        rk_v = _h32(v0 + iota_v, salt2)
        same = tgt_u == tgt_v                        # (TB, R)
        moved_in = jnp.sum(jnp.where(same, cvw_u, 0), axis=1,
                           keepdims=True)
        # composed order: stable sort by (cluster, rk) => (rk, index)
        prior = (rk_u < rk_v) | ((rk_u == rk_v) & (iota_u <= iota_v))
        within = jnp.sum(jnp.where(same & prior, cvw_u, 0), axis=1,
                         keepdims=True)
        allowed = jnp.maximum(W - (new_cw - moved_in), 0)
        revert = cand_v & (within > allowed)
        pl.store(moved_ref, rows,
                 (pmove_v & ~revert).astype(jnp.int32))
        return 0

    lax.fori_loop(0, R // TB, phase_b2, 0)


@functools.partial(jax.jit, static_argnames=("fit_sum", "row_tile",
                                             "interpret"))
def lp_move_chunk(nlab, nw, ncw, own, vw, scal, salt, nbud=None, *,
                  fit_sum: bool = True, row_tile: int = 8,
                  interpret: bool = True):
    """Run the fused chunk step. ``scal`` is ``[[W, v0]]`` int32, ``salt``
    ``[[salt]]`` uint32. Returns ``(moved, tgt)`` int32 ``(R, 1)``."""
    R, D = nlab.shape
    assert R % row_tile == 0, (R, row_tile)
    assert fit_sum == (nbud is None), "nbud goes with fit_sum=False only"
    out_shapes = (
        jax.ShapeDtypeStruct((R, 1), jnp.int32),
        jax.ShapeDtypeStruct((R, 1), jnp.int32),
    )
    kernel = functools.partial(_kernel, R=R, D=D, TA=row_tile, TB=row_tile,
                               fit_sum=fit_sum)
    inputs = [scal, salt, nlab, nw, ncw]
    if not fit_sum:
        inputs.append(nbud)
    inputs += [own, vw]
    return pl.pallas_call(
        kernel,
        out_shape=out_shapes,
        scratch_shapes=[
            pltpu.VMEM((R, 1), jnp.int32),   # pre-revert move flags
            pltpu.VMEM((R, 1), jnp.int32),   # cw[target] at chunk start
            pltpu.VMEM((R, 1), jnp.int32),   # revert candidates
            pltpu.VMEM((R, 1), jnp.int32),   # updated target weights
        ],
        interpret=interpret,
    )(*inputs)


def lp_move_vmem_bytes(R: int, D: int, row_tile: int = 8,
                       fit_sum: bool = True) -> int:
    """Planning estimate of the kernel's VMEM working set (operands +
    scratch + the (TA, D, D) equality cube and (TB, R) pairwise masks)."""
    slabs = (3 if fit_sum else 4) * R * D * 4
    cols = 8 * R * 4                      # own/vw/outputs/scratch columns
    cube = row_tile * D * D * 4
    pairwise = 4 * row_tile * R * 4
    return slabs + cols + cube + pairwise
