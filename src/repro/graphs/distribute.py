"""1D vertex distribution with ghost vertices (paper §2 machine model).

Each PE owns a contiguous vertex range; arcs live with their tail; heads
owned by other PEs are *ghosts*. The halo plan precomputes, for every PE
pair (p, q), which of p's interface vertices q references — the static
send/recv schedule for label/feature halo exchanges.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from .format import Graph


@dataclasses.dataclass(frozen=True)
class GraphShards:
    """Stacked per-PE arrays (leading axis = PE)."""
    P: int
    n: int                   # global vertex count
    n_loc: int               # padded local vertex slots per PE
    m_loc: int               # padded local arc slots per PE
    n_ghost: int             # padded ghost slots per PE
    halo_width: int          # padded per-peer halo message size S
    offsets: np.ndarray      # (P+1,) global range starts
    arc_src: np.ndarray      # (P, m_loc) int32 local tail (sentinel n_loc)
    arc_dst_idx: np.ndarray  # (P, m_loc) int32 index into label table
    arc_w: np.ndarray        # (P, m_loc) int32
    vweights: np.ndarray     # (P, n_loc) int32 (0-padded)
    local_gid: np.ndarray    # (P, n_loc) int32 global id (sentinel n)
    ghost_gid: np.ndarray    # (P, n_ghost) int32 global id (sentinel n)
    send_idx: np.ndarray     # (P, P, S) int32 local index to send (sent. n_loc)
    recv_slot: np.ndarray    # (P, P, S) int32 ghost slot of received value
                             #   (sentinel n_ghost = drop)

    @property
    def table_size(self) -> int:
        """Label-table length per PE: [locals | ghosts | sentinel]."""
        return self.n_loc + self.n_ghost + 1

    def comm_bytes_per_halo(self, itemsize: int = 4) -> int:
        """Real payload bytes moved per halo exchange (sum over PEs)."""
        return int((self.send_idx < self.n_loc).sum()) * itemsize


def balanced_offsets(g: Graph, P: int, by_arcs: bool = True) -> np.ndarray:
    """Contiguous 1D split balancing arc count (default) or vertex count."""
    if by_arcs and g.m > 0:
        targets = (np.arange(1, P) * g.m) // P
        cuts = np.searchsorted(g.indptr, targets, side="left")
    else:
        cuts = (np.arange(1, P) * g.n) // P
    offsets = np.concatenate([[0], cuts, [g.n]]).astype(np.int64)
    return np.maximum.accumulate(offsets)


def assemble_shards(n: int, offsets: np.ndarray,
                    arc_parts: List[Tuple[np.ndarray, np.ndarray,
                                          np.ndarray]],
                    vw_parts: List[np.ndarray]) -> GraphShards:
    """Build ``GraphShards`` from per-PE COO parts.

    PE p owns the contiguous global range [offsets[p], offsets[p+1]);
    ``arc_parts[p]`` is its (src_gid, dst_gid, w) arc triple (tails in
    p's range, sorted by tail) and ``vw_parts[p]`` its owned vertex
    weights. ``distribute_graph`` feeds this from CSR slices; the
    distributed contraction feeds it the owner-side coarse arcs so a
    coarse graph can enter the next level without a host CSR round-trip.
    """
    P = len(arc_parts)
    locals_per_pe: List[Tuple[int, int]] = [
        (int(offsets[p]), int(offsets[p + 1])) for p in range(P)]
    n_loc = max(1, max(v1 - v0 for v0, v1 in locals_per_pe))

    ghost_lists: List[np.ndarray] = []
    for p, (v0, v1) in enumerate(locals_per_pe):
        d = arc_parts[p][1]
        ghost_lists.append(np.unique(d[(d < v0) | (d >= v1)]))
    n_ghost = max(1, max(gl.size for gl in ghost_lists))
    m_loc = max(1, max(a[0].size for a in arc_parts))

    # halo width: p sends to q the vertices in q's ghost list ∩ p's range
    S = 1
    send_lists = [[None] * P for _ in range(P)]
    for q in range(P):
        gl = ghost_lists[q]
        own = np.searchsorted(offsets, gl, side="right") - 1
        for p in range(P):
            sl = gl[own == p]
            send_lists[p][q] = sl          # sorted (gl sorted)
            S = max(S, sl.size)

    arc_src = np.full((P, m_loc), n_loc, dtype=np.int32)
    arc_dst_idx = np.full((P, m_loc), n_loc + n_ghost, dtype=np.int32)
    arc_w = np.zeros((P, m_loc), dtype=np.int32)
    vweights = np.zeros((P, n_loc), dtype=np.int32)
    local_gid = np.full((P, n_loc), n, dtype=np.int32)
    ghost_gid = np.full((P, n_ghost), n, dtype=np.int32)
    send_idx = np.full((P, P, S), n_loc, dtype=np.int32)
    recv_slot = np.full((P, P, S), n_ghost, dtype=np.int32)

    for p, (v0, v1) in enumerate(locals_per_pe):
        cnt_v = v1 - v0
        s, d, w = arc_parts[p]
        cnt_a = s.size
        gl = ghost_lists[p]
        arc_src[p, :cnt_a] = s - v0
        d = d.astype(np.int64)
        is_local = (d >= v0) & (d < v1)
        idx = np.empty(cnt_a, dtype=np.int64)
        idx[is_local] = d[is_local] - v0
        idx[~is_local] = n_loc + np.searchsorted(gl, d[~is_local])
        arc_dst_idx[p, :cnt_a] = idx
        arc_w[p, :cnt_a] = w
        vweights[p, :cnt_v] = vw_parts[p]
        local_gid[p, :cnt_v] = np.arange(v0, v1)
        ghost_gid[p, :gl.size] = gl
        for q in range(P):
            sl = send_lists[p][q]
            send_idx[p, q, :sl.size] = sl - v0
            # on q's side, the message from p lands at q's ghost slots for sl
            recv_slot[q, p, :sl.size] = np.searchsorted(ghost_lists[q], sl)

    return GraphShards(P=P, n=n, n_loc=n_loc, m_loc=m_loc, n_ghost=n_ghost,
                       halo_width=S, offsets=offsets, arc_src=arc_src,
                       arc_dst_idx=arc_dst_idx, arc_w=arc_w,
                       vweights=vweights, local_gid=local_gid,
                       ghost_gid=ghost_gid, send_idx=send_idx,
                       recv_slot=recv_slot)


def distribute_graph(g: Graph, P: int, by_arcs: bool = True) -> GraphShards:
    offsets = balanced_offsets(g, P, by_arcs)
    src = g.arc_tails()
    arc_parts, vw_parts = [], []
    for p in range(P):
        v0, v1 = int(offsets[p]), int(offsets[p + 1])
        a0, a1 = int(g.indptr[v0]), int(g.indptr[v1])
        arc_parts.append((src[a0:a1], g.adjncy[a0:a1], g.eweights[a0:a1]))
        vw_parts.append(g.vweights[v0:v1])
    return assemble_shards(g.n, offsets, arc_parts, vw_parts)


def chunk_local_arcs(shards: GraphShards, num_chunks: int
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Split each PE's arc slab into ``num_chunks`` equal static slices
    aligned on src-vertex boundaries (arcs of one vertex never straddle a
    chunk). Returns (P, B, m_chunk) slabs for (src, dst_idx, w)."""
    P, B = shards.P, num_chunks
    tgt = -(-shards.m_loc // B)
    all_bounds = []
    m_chunk = 1
    for p in range(P):
        valid = shards.arc_src[p] < shards.n_loc
        cnt = int(valid.sum())
        bounds = [0]
        asrc = shards.arc_src[p]
        for b in range(1, B):
            pos = min(b * tgt, cnt)
            # advance to the next src boundary so a vertex's arcs stay whole
            while 0 < pos < cnt and asrc[pos] == asrc[pos - 1]:
                pos += 1
            bounds.append(max(pos, bounds[-1]))
        bounds.append(cnt)
        all_bounds.append(bounds)
        m_chunk = max(m_chunk, max(bounds[b + 1] - bounds[b]
                                   for b in range(B)))
    srcs = np.full((P, B, m_chunk), shards.n_loc, dtype=np.int32)
    dsts = np.full((P, B, m_chunk), shards.n_loc + shards.n_ghost,
                   dtype=np.int32)
    ws = np.zeros((P, B, m_chunk), dtype=np.int32)
    for p in range(P):
        bounds = all_bounds[p]
        for b in range(B):
            x0, x1 = bounds[b], bounds[b + 1]
            take = x1 - x0
            srcs[p, b, :take] = shards.arc_src[p, x0:x1]
            dsts[p, b, :take] = shards.arc_dst_idx[p, x0:x1]
            ws[p, b, :take] = shards.arc_w[p, x0:x1]
    return srcs, dsts, ws
