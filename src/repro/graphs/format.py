"""Graph containers for the partitioner and GNN substrate.

Conventions (match the paper's input format, Section 2):
  * An undirected edge {u, v} is stored as two directed arcs (u, v) and (v, u).
  * Arcs are stored in CSR order (sorted by tail vertex).
  * Vertex weights ``c`` and edge weights ``w`` are positive integers
    (int64 accumulators so contracted weights never overflow).

The multilevel driver runs in host Python, so the canonical container is
numpy-backed; jitted per-level ops receive the raw arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

INVALID = np.int32(-1)


@dataclasses.dataclass(frozen=True)
class Graph:
    """CSR graph with vertex/edge weights. ``m`` counts directed arcs."""

    indptr: np.ndarray      # (n+1,) int64
    adjncy: np.ndarray      # (m,)   int32/int64 — head vertex of each arc
    eweights: np.ndarray    # (m,)   int64
    vweights: np.ndarray    # (n,)   int64

    @property
    def n(self) -> int:
        return int(self.indptr.shape[0] - 1)

    @property
    def m(self) -> int:
        return int(self.adjncy.shape[0])

    @property
    def total_vweight(self) -> int:
        return int(self.vweights.sum())

    @property
    def total_eweight(self) -> int:
        return int(self.eweights.sum())

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def arc_tails(self) -> np.ndarray:
        """Expand CSR to COO tails: (m,) src vertex of each arc."""
        return np.repeat(np.arange(self.n, dtype=self.adjncy.dtype),
                         np.diff(self.indptr))

    def validate(self) -> None:
        n, m = self.n, self.m
        assert self.indptr[0] == 0 and self.indptr[-1] == m
        assert np.all(np.diff(self.indptr) >= 0)
        if m:
            assert self.adjncy.min() >= 0 and self.adjncy.max() < n
            assert self.eweights.min() >= 1
        assert np.all(self.vweights >= 1)
        # symmetry: every arc (u,v,w) must have a partner (v,u,w)
        src = self.arc_tails()
        fwd = np.lexsort((self.adjncy, src))
        bwd = np.lexsort((src, self.adjncy))
        assert np.array_equal(src[fwd], self.adjncy[bwd])
        assert np.array_equal(self.adjncy[fwd], src[bwd])
        assert np.array_equal(self.eweights[fwd], self.eweights[bwd])


def from_coo(n: int,
             src: np.ndarray,
             dst: np.ndarray,
             eweights: Optional[np.ndarray] = None,
             vweights: Optional[np.ndarray] = None,
             symmetrize: bool = True,
             dedup: bool = True) -> Graph:
    """Build a Graph from (possibly one-directional) COO arcs.

    Self loops are dropped; parallel arcs are merged by summing weights.
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if eweights is None:
        eweights = np.ones_like(src, dtype=np.int64)
    else:
        eweights = np.asarray(eweights, dtype=np.int64)

    keep = src != dst
    src, dst, eweights = src[keep], dst[keep], eweights[keep]
    if symmetrize:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        eweights = np.concatenate([eweights, eweights])

    if dedup and src.size:
        key = src * n + dst
        order = np.argsort(key, kind="stable")
        key, src, dst, eweights = key[order], src[order], dst[order], eweights[order]
        first = np.concatenate([[True], key[1:] != key[:-1]])
        seg = np.cumsum(first) - 1
        merged_w = np.zeros(int(seg[-1]) + 1, dtype=np.int64)
        np.add.at(merged_w, seg, eweights)
        src, dst, eweights = src[first], dst[first], merged_w
        if symmetrize:
            # a symmetrized + deduped arc list double-counts undirected weights
            # only if the input already contained both directions; from_coo
            # callers pass one direction, so weights are correct here.
            pass
    else:
        order = np.argsort(src, kind="stable")
        src, dst, eweights = src[order], dst[order], eweights[order]

    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, src + 1, 1)
    indptr = np.cumsum(indptr)
    if vweights is None:
        vweights = np.ones(n, dtype=np.int64)
    else:
        vweights = np.asarray(vweights, dtype=np.int64)
    g = Graph(indptr=indptr.astype(np.int64),
              adjncy=dst.astype(np.int32 if n < 2**31 else np.int64),
              eweights=eweights.astype(np.int64),
              vweights=vweights)
    return g


def permute(g: Graph, perm: np.ndarray) -> Tuple[Graph, np.ndarray]:
    """Relabel vertices: new id of old vertex v is perm[v]. Returns (graph, inv)."""
    inv = np.empty_like(perm)
    inv[perm] = np.arange(g.n, dtype=perm.dtype)
    src = g.arc_tails()
    new_src = perm[src]
    new_dst = perm[g.adjncy]
    order = np.lexsort((new_dst, new_src))
    indptr = np.zeros(g.n + 1, dtype=np.int64)
    np.add.at(indptr, new_src + 1, 1)
    g2 = Graph(indptr=np.cumsum(indptr),
               adjncy=new_dst[order].astype(g.adjncy.dtype),
               eweights=g.eweights[order],
               vweights=g.vweights[inv])
    return g2, inv


def degree_bucket_order(g: Graph, rng: np.random.Generator,
                        chunk: int = 256) -> np.ndarray:
    """Paper §4 iteration order: exponentially spaced degree buckets,
    randomized inter-/intra-chunk. Returns a vertex traversal order."""
    deg = g.degrees()
    bucket = np.zeros(g.n, dtype=np.int64)
    nz = deg > 0
    bucket[nz] = np.floor(np.log2(deg[nz])).astype(np.int64) + 1
    # sort by bucket, random within bucket
    order = np.lexsort((rng.random(g.n), bucket))
    # chunk and shuffle chunks within each bucket
    out = []
    start = 0
    b_sorted = bucket[order]
    boundaries = np.flatnonzero(np.diff(b_sorted)) + 1
    for seg in np.split(order, boundaries):
        n_chunks = max(1, len(seg) // chunk)
        chunks = np.array_split(seg, n_chunks)
        idx = rng.permutation(len(chunks))
        for i in idx:
            c = chunks[i].copy()
            rng.shuffle(c)
            out.append(c)
        start += len(seg)
    return np.concatenate(out) if out else np.empty(0, dtype=np.int64)


def to_ell(g: Graph, max_degree: Optional[int] = None
           ) -> Tuple[np.ndarray, np.ndarray, int]:
    """ELL (padded row) format: (n, d) neighbor ids and weights.

    Rows longer than ``max_degree`` are truncated (callers that need
    exactness must check ``degrees().max()`` first). Padding uses
    ``n`` as a sentinel neighbor with weight 0.
    """
    deg = g.degrees()
    d = int(deg.max()) if deg.size else 0
    if max_degree is not None:
        d = min(d, max_degree)
    d = max(d, 1)
    idx = np.full((g.n, d), g.n, dtype=np.int64)
    wgt = np.zeros((g.n, d), dtype=np.int64)
    pos = np.minimum(np.arange(g.m) - np.repeat(g.indptr[:-1], deg), d - 1)
    rows = g.arc_tails()
    take = (np.arange(g.m) - g.indptr[rows]) < d
    idx[rows[take], pos[take]] = g.adjncy[take]
    wgt[rows[take], pos[take]] = g.eweights[take]
    return idx, wgt, d


def induced_subgraph(g: Graph, mask: np.ndarray
                     ) -> Tuple[Graph, np.ndarray]:
    """Subgraph induced by ``mask`` (bool over vertices).

    Returns (subgraph, old_ids) with old_ids[i] = original id of new vertex i.
    """
    old_ids = np.flatnonzero(mask)
    new_id = np.full(g.n, -1, dtype=np.int64)
    new_id[old_ids] = np.arange(old_ids.size)
    src = g.arc_tails()
    keep = mask[src] & mask[g.adjncy]
    sub = from_coo(old_ids.size, new_id[src[keep]], new_id[g.adjncy[keep]],
                   eweights=g.eweights[keep], vweights=g.vweights[old_ids],
                   symmetrize=False, dedup=False)
    return sub, old_ids
