"""KaGen-style synthetic graph generators (numpy host-side).

The paper evaluates on rgg2d / rgg3d / rhg families plus real-world meshes
and complex networks. We reproduce the same families at laptop scale:

  * rgg2d / rgg3d — random geometric graphs, radius chosen for a target
    average degree (KaGen semantics).
  * rhg — random hyperbolic graph, power-law exponent 3 by default. Exact
    threshold model for small n, Chung–Lu power-law approximation beyond
    (documented; the partitioner only cares about the skewed-degree regime).
  * grid2d / grid3d — deterministic meshes (nlpkkt/europe.osm proxies).
  * ba — Barabási–Albert preferential attachment (social-network proxy).
"""
from __future__ import annotations

import numpy as np
from scipy.spatial import cKDTree

from .format import Graph, from_coo


def rgg2d(n: int, avg_deg: float, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    pts = rng.random((n, 2))
    # E[deg] = n * pi r^2  ->  r = sqrt(avg_deg / (pi n))
    r = np.sqrt(avg_deg / (np.pi * n))
    tree = cKDTree(pts)
    pairs = tree.query_pairs(r, output_type="ndarray")
    return from_coo(n, pairs[:, 0], pairs[:, 1])


def rgg3d(n: int, avg_deg: float, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    pts = rng.random((n, 3))
    r = (avg_deg / ((4.0 / 3.0) * np.pi * n)) ** (1.0 / 3.0)
    tree = cKDTree(pts)
    pairs = tree.query_pairs(r, output_type="ndarray")
    return from_coo(n, pairs[:, 0], pairs[:, 1])


def _rhg_exact(n: int, avg_deg: float, gamma: float, seed: int) -> Graph:
    """Threshold random hyperbolic graph, blocked O(n^2); n <= ~20k."""
    rng = np.random.default_rng(seed)
    alpha = (gamma - 1.0) / 2.0
    R = 2.0 * np.log(n) - np.log(avg_deg)  # calibration; refined below
    # radial cdf: F(r) = (cosh(alpha r) - 1) / (cosh(alpha R) - 1)
    u = rng.random(n)
    r = np.arccosh(1.0 + u * (np.cosh(alpha * R) - 1.0)) / alpha
    theta = rng.random(n) * 2.0 * np.pi
    cr, sr = np.cosh(r), np.sinh(r)
    srcs, dsts = [], []
    block = 2048
    for i0 in range(0, n, block):
        i1 = min(i0 + block, n)
        dtheta = np.abs(theta[i0:i1, None] - theta[None, :])
        dtheta = np.minimum(dtheta, 2.0 * np.pi - dtheta)
        cosh_d = (cr[i0:i1, None] * cr[None, :]
                  - sr[i0:i1, None] * sr[None, :] * np.cos(dtheta))
        adj = cosh_d <= np.cosh(R)
        ii, jj = np.nonzero(adj)
        ii = ii + i0
        keep = ii < jj
        srcs.append(ii[keep])
        dsts.append(jj[keep])
    src = np.concatenate(srcs) if srcs else np.empty(0, np.int64)
    dst = np.concatenate(dsts) if dsts else np.empty(0, np.int64)
    return from_coo(n, src, dst)


def _chung_lu_powerlaw(n: int, avg_deg: float, gamma: float, seed: int) -> Graph:
    rng = np.random.default_rng(seed)
    # degree weights ~ pareto with exponent gamma
    w = (1.0 - rng.random(n)) ** (-1.0 / (gamma - 1.0))
    w *= avg_deg * n / w.sum()
    m_target = int(avg_deg * n / 2)
    p = w / w.sum()
    src = rng.choice(n, size=2 * m_target, p=p)
    dst = rng.choice(n, size=2 * m_target, p=p)
    keep = src != dst
    return from_coo(n, src[keep], dst[keep])


def rhg(n: int, avg_deg: float, gamma: float = 3.0, seed: int = 0) -> Graph:
    if n <= 20000:
        return _rhg_exact(n, avg_deg, gamma, seed)
    return _chung_lu_powerlaw(n, avg_deg, gamma, seed)


def grid2d(nx: int, ny: int) -> Graph:
    n = nx * ny
    ids = np.arange(n).reshape(nx, ny)
    right = np.stack([ids[:, :-1].ravel(), ids[:, 1:].ravel()], axis=1)
    down = np.stack([ids[:-1, :].ravel(), ids[1:, :].ravel()], axis=1)
    e = np.concatenate([right, down])
    return from_coo(n, e[:, 0], e[:, 1])


def grid3d(nx: int, ny: int, nz: int) -> Graph:
    n = nx * ny * nz
    ids = np.arange(n).reshape(nx, ny, nz)
    ex = np.stack([ids[:-1].ravel(), ids[1:].ravel()], axis=1)
    ey = np.stack([ids[:, :-1].ravel(), ids[:, 1:].ravel()], axis=1)
    ez = np.stack([ids[:, :, :-1].ravel(), ids[:, :, 1:].ravel()], axis=1)
    e = np.concatenate([ex, ey, ez])
    return from_coo(n, e[:, 0], e[:, 1])


def ba(n: int, m_attach: int = 4, seed: int = 0) -> Graph:
    """Barabási–Albert via the repeated-nodes trick (vectorized-ish)."""
    rng = np.random.default_rng(seed)
    targets = list(range(m_attach))
    repeated: list[int] = []
    src, dst = [], []
    for v in range(m_attach, n):
        for t in targets:
            src.append(v)
            dst.append(t)
        repeated.extend(targets)
        repeated.extend([v] * m_attach)
        # sample next targets (with repetition tolerated; dedup in from_coo)
        idx = rng.integers(0, len(repeated), size=m_attach)
        targets = [repeated[i] for i in idx]
    return from_coo(n, np.array(src), np.array(dst))


def random_regular_ish(n: int, deg: int, seed: int = 0) -> Graph:
    """Fast approximately-regular random graph (union of deg/2 permutations)."""
    rng = np.random.default_rng(seed)
    srcs, dsts = [], []
    for _ in range(max(1, deg // 2)):
        p = rng.permutation(n)
        srcs.append(np.arange(n))
        dsts.append(p)
    return from_coo(n, np.concatenate(srcs), np.concatenate(dsts))


def weighted_variant(g: Graph, seed: int = 0,
                     max_vw: int = 8, max_ew: int = 8) -> Graph:
    """Attach random integer vertex/edge weights (for weighted-instance tests)."""
    rng = np.random.default_rng(seed)
    src = g.arc_tails()
    # symmetric edge weights: hash the unordered pair
    lo = np.minimum(src, g.adjncy)
    hi = np.maximum(src, g.adjncy)
    ew = (np.asarray(lo, np.uint64) * np.uint64(2654435761)
          ^ np.asarray(hi, np.uint64) * np.uint64(40503)) % np.uint64(max_ew) + np.uint64(1)
    vw = rng.integers(1, max_vw + 1, size=g.n)
    return Graph(indptr=g.indptr, adjncy=g.adjncy,
                 eweights=ew.astype(np.int64), vweights=vw.astype(np.int64))


_FAMILIES = {
    "rgg2d": lambda n, d, s: rgg2d(n, d, s),
    "rgg3d": lambda n, d, s: rgg3d(n, d, s),
    "rhg": lambda n, d, s: rhg(n, d, 3.0, s),
    "ba": lambda n, d, s: ba(n, max(1, int(d) // 2), s),
    "grid2d": lambda n, d, s: grid2d(int(np.sqrt(n)), int(np.sqrt(n))),
    "rr": lambda n, d, s: random_regular_ish(n, int(d), s),
}


def make(family: str, n: int, avg_deg: float = 8.0, seed: int = 0) -> Graph:
    return _FAMILIES[family](n, avg_deg, seed)
