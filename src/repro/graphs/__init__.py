from .format import Graph, from_coo, induced_subgraph, permute, to_ell
from . import generators

__all__ = ["Graph", "from_coo", "induced_subgraph", "permute", "to_ell",
           "generators"]
