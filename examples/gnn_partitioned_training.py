"""Train a GAT for a few hundred steps with partitioner-driven placement:
the paper's technique as the placement engine of the GNN substrate. Shows
the halo-volume reduction the partition buys (the collective roofline
term of EXPERIMENTS.md §Perf).

    PYTHONPATH=src python examples/gnn_partitioned_training.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partitioner import PartitionerConfig
from repro.graphs import generators
from repro.graphs.format import permute
from repro.models.common import init_params
from repro.models.gnn import gat
from repro.models.gnn.common import GraphBatch
from repro.placement import gnn_placement
from repro.train.optimizer import OptConfig
from repro.train.trainer import TrainLoopConfig, make_train_step, run_loop

# --- build a shuffled graph (no free locality) -------------------------
g = generators.make("rgg2d", 4000, 8.0, seed=7)
rng = np.random.default_rng(0)
g, _ = permute(g, rng.permutation(g.n))

# --- placement: partition into 8 "devices" ----------------------------
plan = gnn_placement.plan(
    g, 8, config=PartitionerConfig(contraction_limit=64, ip_repetitions=2,
                                   num_chunks=4))
print(f"halo bytes/exchange: naive={plan.baseline_halo_bytes} "
      f"partitioned={plan.halo_bytes} "
      f"({plan.baseline_halo_bytes / max(plan.halo_bytes, 1):.2f}x less)")

# --- train on the placement-relabelled graph ---------------------------
g2 = plan.graph
cfg = gat.GATConfig(d_in=32, d_hidden=8, n_heads=4, n_classes=5)
N = g2.n + 1
feat = rng.standard_normal((N, cfg.d_in)).astype(np.float32)
# learnable labels: community id from the partition itself
labels = np.concatenate([plan.perm * 0, [0]])
labels = np.zeros(N, dtype=np.int64)
labels[:g2.n] = (np.arange(g2.n) * 5) // g2.n
batch = GraphBatch(
    senders=jnp.asarray(g2.arc_tails().astype(np.int32)),
    receivers=jnp.asarray(np.asarray(g2.adjncy, dtype=np.int32)),
    n_node=N, node_feat=jnp.asarray(feat), labels=jnp.asarray(labels),
    node_mask=jnp.asarray(np.arange(N) < g2.n))

params = init_params(gat.build_specs(cfg), jax.random.key(0))
init_state, step = make_train_step(
    lambda p, b: gat.loss_fn(p, b, cfg), OptConfig(lr=3e-3))
t0 = time.time()
state, hist = run_loop(init_state, step, lambda s: batch, params,
                       TrainLoopConfig(steps=300, log_every=50))
print(f"300 steps in {time.time() - t0:.1f}s; loss: "
      + " -> ".join(f"{l:.3f}" for _, l in hist["loss"]))
assert hist["loss"][-1][1] < hist["loss"][0][1]
