"""Quickstart: partition a graph through the `repro.api` facade and
inspect quality.

    PYTHONPATH=src python examples/quickstart.py [n]
"""
import sys

from repro.api import GraphSpec, PartitionRequest, Partitioner

n = int(sys.argv[1]) if len(sys.argv) > 1 else 20000

# 1. describe the job: graph (generated here; pass a Graph to reuse one),
#    block count, balance slack — paper defaults
req = PartitionRequest(graph=GraphSpec("rgg2d", n, 8.0, seed=0),
                       k=16, epsilon=0.03, seed=0)

# 2. run it; the auto policy picks the single-process backend at 1 device
engine = Partitioner()
res = engine.run(req)
print(f"graph: n={res.metrics['n']} m={res.metrics['m']}")
print("deep MGP:    ", res.summary())
for rec in res.trace:  # per-level sizes/cuts/timings
    print("   ", rec)

# 3. compare against single-level label propagation (XtraPuLP-like) by
#    running the *same request* on the baseline backend
flat, = engine.compare(req, ["single_level_lp"])
print("single-level:", flat.summary())
assert res.feasible and res.cut < flat.cut
