"""Quickstart: partition a graph with dKaMinPar-JAX and inspect quality.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import partition
from repro.core.metrics import summarize
from repro.core.baselines import single_level_lp
from repro.graphs import generators

# 1. make (or load) a graph — here: random geometric, 20k vertices
g = generators.make("rgg2d", 20000, 8.0, seed=0)
print(f"graph: n={g.n} m={g.m}")

# 2. partition into 16 blocks, 3% imbalance (paper defaults)
part = partition(g, k=16, epsilon=0.03, seed=0)
print("deep MGP:   ", summarize(g, part, 16, 0.03))

# 3. compare against single-level label propagation (XtraPuLP-like)
flat = single_level_lp(g, 16)
print("single-level:", summarize(g, flat, 16, 0.03))
