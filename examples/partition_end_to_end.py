"""End-to-end driver (the paper's kind of system): generate large graphs,
partition them with both presets through a batched `repro.api` session,
validate feasibility, report throughput — the Figure 2 experiment in
miniature.

    PYTHONPATH=src python examples/partition_end_to_end.py [n]
"""
import sys

from repro.api import GraphSpec, PartitionRequest, PartitionSession

n = int(sys.argv[1]) if len(sys.argv) > 1 else 50000

# one session serves all (family x preset) requests; independent jobs run
# concurrently and GraphSpec graphs are materialized once per family
requests = [
    PartitionRequest(graph=GraphSpec(family, n, 8.0, seed=1), k=16,
                     epsilon=0.03, preset=preset, backend="single")
    for family in ("rgg2d", "rhg")
    for preset in ("fast", "strong")
]
with PartitionSession(max_workers=2) as sess:
    results = sess.run_batch(requests)
    print("session:", sess.stats())

for req, res in zip(requests, results):
    s = res.metrics
    print(f"{req.graph.family:6s} dKaMinPar-{req.preset:6s} "
          f"cut={s['cut']:8d} feasible={s['feasible']} "
          f"imb={s['imbalance']:.4f} time={res.time_s:5.1f}s "
          f"({s['m'] / res.time_s / 1e6:.2f} M arcs/s)")
    assert res.feasible
