"""End-to-end driver (the paper's kind of system): generate a large
graph, partition it with both presets, validate feasibility, report
throughput — the Figure 2 experiment in miniature.

    PYTHONPATH=src python examples/partition_end_to_end.py [n]
"""
import sys
import time

import numpy as np

from repro.core import partition
from repro.core.partitioner import fast_config, strong_config
from repro.core.metrics import summarize
from repro.graphs import generators

n = int(sys.argv[1]) if len(sys.argv) > 1 else 50000
for family in ("rgg2d", "rhg"):
    g = generators.make(family, n, 8.0, seed=1)
    for preset, cfg in (("fast", fast_config()),
                        ("strong", strong_config())):
        t0 = time.time()
        part = partition(g, 16, config=cfg)
        dt = time.time() - t0
        s = summarize(g, part, 16, 0.03)
        print(f"{family:6s} dKaMinPar-{preset:6s} cut={s['cut']:8d} "
              f"feasible={s['feasible']} imb={s['imbalance']:.4f} "
              f"time={dt:5.1f}s ({g.m / dt / 1e6:.2f} M arcs/s)")
        assert s["feasible"]
