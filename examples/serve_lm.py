"""Serve a small LM with batched KV-cache decoding (prefill + decode),
greedy sampling over batched requests.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import load_all
from repro.models import transformer as T
from repro.models.common import init_params

entry = load_all()["qwen2-7b"]
cfg = entry.smoke_config
params = init_params(T.build_specs(cfg), jax.random.key(0))

B, prompt_len, gen_len, max_len = 4, 12, 20, 64
rng = np.random.default_rng(0)
prompts = jnp.asarray(rng.integers(1, cfg.vocab, (B, prompt_len)),
                      jnp.int32)

# prefill: run the prompt through decode steps to fill the cache
cache = jax.tree_util.tree_map(
    jnp.zeros_like, init_params(T.cache_specs(cfg, B, max_len),
                                jax.random.key(1)))
decode = jax.jit(lambda p, c, t, l: T.decode_step(p, c, t, l, cfg))
t0 = time.time()
logits = None
for t in range(prompt_len):
    logits, cache = decode(params, cache, prompts[:, t],
                           jnp.full((B,), t, jnp.int32))

# greedy generation
out = []
tok = jnp.argmax(logits[:, :cfg.vocab], axis=-1).astype(jnp.int32)
for t in range(prompt_len, prompt_len + gen_len):
    out.append(tok)
    logits, cache = decode(params, cache, tok,
                           jnp.full((B,), t, jnp.int32))
    tok = jnp.argmax(logits[:, :cfg.vocab], axis=-1).astype(jnp.int32)
dt = time.time() - t0
toks = np.stack([np.asarray(t) for t in out], axis=1)
print(f"generated {B}x{gen_len} tokens in {dt:.1f}s "
      f"({B * (gen_len + prompt_len) / dt:.0f} tok/s incl. compile)")
print("sample token ids:", toks[0].tolist())
assert toks.shape == (B, gen_len)
assert (toks >= 0).all() and (toks < cfg.vocab).all()
