"""Refinement-tier tests (docs/REFINEMENT.md).

Host-side properties of the Jet-style unconstrained pass: feasibility
after afterburner repair from adversarial starts, the penalty schedule,
and the default-path guarantee that ``refine="lp"`` is byte-identical to
composing ``lp_refine`` + ``rebalance`` by hand (the pre-tier pipeline).
The request-level ``refine``/``quality`` knobs are covered end to end,
and a fast 2-device subprocess selftest checks the distributed twin
(P=1 host-vs-dist equivalence lives here too: the two implementations
chunk and salt differently, so the claim is feasibility plus comparable
cuts, not bit-identity — the dist-internal bit-identities are in
``selftest --test refine``).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import metrics
from repro.core.balance import rebalance
from repro.core.deep_mgp import PartitionerConfig, partition
from repro.core.refinement import (REFINE_MODES, balance_and_refine,
                                   check_refine_mode, lp_refine)
from repro.core.unconstrained import penalty_schedule, unconstrained_refine
from repro.graphs import generators

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def lmax_vec(g, k, eps=0.03):
    return np.full(k, metrics.l_max(g.total_vweight, k, eps,
                                    int(g.vweights.max())), dtype=np.int64)


def assert_feasible(g, part, lvec):
    k = int(lvec.shape[0])
    assert part.min() >= 0 and part.max() < k, (part.min(), part.max(), k)
    bw = metrics.block_weights(g, part, k)
    assert np.all(bw <= lvec), (bw, lvec)


# ---------------------------------------------------------------------------
# penalty schedule
# ---------------------------------------------------------------------------

def test_penalty_schedule_shape():
    # round 0 is fully unconstrained; the ramp approaches (R-1)/R < 1
    assert penalty_schedule(1) == [0.0]
    assert penalty_schedule(2) == [0.0, 0.5]
    assert penalty_schedule(4) == [0.0, 0.25, 0.5, 0.75]
    for r in penalty_schedule(7):
        assert 0.0 <= r < 1.0


def test_check_refine_mode():
    assert set(REFINE_MODES) == {"lp", "unconstrained"}
    for m in REFINE_MODES:
        assert check_refine_mode(m) == m
    with pytest.raises(ValueError, match="refine"):
        check_refine_mode("jet")


# ---------------------------------------------------------------------------
# feasibility property: unconstrained + afterburner never emits an
# infeasible partition, however bad the start
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,k", [(0, 8), (1, 16), (2, 4)])
def test_unconstrained_tier_always_feasible(seed, k):
    g = generators.make("rgg2d", 1500, 8.0, seed=seed)
    lvec = lmax_vec(g, k)
    rng = np.random.default_rng(seed)
    part0 = rng.integers(0, k, g.n).astype(np.int64)
    part0[rng.random(g.n) < 0.6] = 0          # heavily overloaded block 0
    stats = {}
    out = balance_and_refine(g, part0, lvec, num_iterations=3,
                             num_chunks=4, seed=seed,
                             refine="unconstrained", stats=stats)
    assert_feasible(g, out, lvec)
    assert stats["penalty"] == penalty_schedule(3)
    assert stats["repair_rounds"] is not None


def test_unconstrained_improves_cut():
    g = generators.make("rgg2d", 2000, 8.0, seed=7)
    k = 8
    lvec = lmax_vec(g, k)
    rng = np.random.default_rng(7)
    part0 = rng.integers(0, k, g.n).astype(np.int64)
    cut0 = metrics.edge_cut(g, part0)
    out = unconstrained_refine(g, part0, lvec, num_iterations=3,
                               num_chunks=4, seed=7)
    assert metrics.edge_cut(g, out) < cut0


# ---------------------------------------------------------------------------
# default-path bit-identity: balance_and_refine(refine="lp") must equal
# the hand-composed pre-tier pipeline byte for byte (no seed or call-
# sequence drift from threading the new knob through)
# ---------------------------------------------------------------------------

def test_lp_path_bit_identical_to_composition():
    g = generators.make("rgg2d", 1200, 8.0, seed=3)
    k = 8
    lvec = lmax_vec(g, k)
    rng = np.random.default_rng(3)
    part0 = rng.integers(0, k, g.n).astype(np.int64)

    got = balance_and_refine(g, part0, lvec, num_iterations=2,
                             num_chunks=4, seed=11, refine="lp")
    want = rebalance(g, part0, lvec, seed=11)
    want = lp_refine(g, want, lvec, num_iterations=2, num_chunks=4,
                     seed=11)
    want = rebalance(g, want, lvec, seed=12)
    assert np.array_equal(got, want)


def test_default_partition_ignores_unconstrained_module(monkeypatch):
    # refine="lp" (the default) must never even touch the unconstrained
    # kernels — the HEAD-bit-identity guarantee, enforced structurally
    from repro.core import unconstrained as u

    def boom(*a, **kw):
        raise AssertionError("lp path must not call unconstrained_refine")

    monkeypatch.setattr(u, "unconstrained_refine", boom)
    g = generators.make("rgg2d", 900, 8.0, seed=2)
    cfg = PartitionerConfig(contraction_limit=128, num_chunks=4)
    part = partition(g, 8, cfg)
    assert metrics.is_feasible(g, part, 8, cfg.epsilon)


# ---------------------------------------------------------------------------
# end-to-end: partition() under both modes, trace records
# ---------------------------------------------------------------------------

def test_partition_unconstrained_feasible_with_trace():
    g = generators.make("rgg2d", 3000, 8.0, seed=5)
    k = 8
    cfg = PartitionerConfig(contraction_limit=128, num_chunks=4,
                            refine="unconstrained")
    trace = []
    part = partition(g, k, cfg, trace=trace)
    assert metrics.is_feasible(g, part, k, cfg.epsilon)
    recs = [r for r in trace if r.get("phase") == "refine-mode"]
    assert recs, trace
    assert all(r["mode"] == "unconstrained" for r in recs)
    stages = {r["stage"] for r in recs}
    assert "final" in stages
    for r in recs:
        assert r["penalty"] == penalty_schedule(cfg.refine_iterations)
        assert "repair_rounds" in r


def test_partition_lp_emits_no_refine_mode_records():
    g = generators.make("rgg2d", 1500, 8.0, seed=5)
    cfg = PartitionerConfig(contraction_limit=128, num_chunks=4)
    trace = []
    partition(g, 8, cfg, trace=trace)
    assert not [r for r in trace if r.get("phase") == "refine-mode"]


def test_config_rejects_unknown_refine():
    with pytest.raises(ValueError, match="refine"):
        PartitionerConfig(refine="jet").validate()


# ---------------------------------------------------------------------------
# request-level knobs: refine / quality mapping
# ---------------------------------------------------------------------------

def test_request_quality_maps_to_refine():
    from repro.api.request import GraphSpec, PartitionRequest
    g = GraphSpec("rgg2d", 400, 8.0, seed=1)
    cases = [
        (dict(), "lp"),
        (dict(quality="fast"), "lp"),
        (dict(quality="best"), "unconstrained"),
        (dict(quality="best", refine="lp"), "lp"),          # explicit wins
        (dict(quality="fast", refine="unconstrained"), "unconstrained"),
    ]
    for kw, want in cases:
        req = PartitionRequest(graph=g, k=4, **kw).validate()
        assert req.resolve_config().refine == want, (kw, want)
    with pytest.raises(ValueError, match="quality"):
        PartitionRequest(graph=g, k=4, quality="ultra").validate()
    with pytest.raises(ValueError, match="refine"):
        PartitionRequest(graph=g, k=4, refine="jet").validate()


def test_fabric_codec_round_trips_refine_knobs():
    from repro.api.request import GraphSpec, PartitionRequest
    from repro.fabric import protocol
    req = PartitionRequest(graph=GraphSpec("rgg2d", 300, 8.0), k=4,
                           kernel="composed", refine="unconstrained",
                           quality="best")
    dec = protocol.decode_request(protocol.encode_request(req))
    assert (dec.kernel, dec.refine, dec.quality) == \
        ("composed", "unconstrained", "best")


# ---------------------------------------------------------------------------
# P=1 dist-vs-host equivalence (not bit-identity: the dist twin chunks
# local arcs and salts per-PE, the host pass reorders by degree bucket —
# the claim is feasibility + comparable quality on the same start)
# ---------------------------------------------------------------------------

def test_dist_unconstrained_p1_matches_host_quality():
    from repro.dist.dist_partitioner import dist_refine_and_balance
    g = generators.make("rgg2d", 1500, 8.0, seed=9)
    k = 8
    lvec = lmax_vec(g, k)
    rng = np.random.default_rng(9)
    part0 = rng.integers(0, k, g.n).astype(np.int64)
    cut0 = metrics.edge_cut(g, part0)

    host = balance_and_refine(g, part0.copy(), lvec, num_iterations=3,
                              num_chunks=4, seed=9,
                              refine="unconstrained")
    dist = dist_refine_and_balance(g, part0.copy(), lvec, P=1,
                                   num_iterations=3, num_chunks=4,
                                   seed=9, refine="unconstrained")
    assert_feasible(g, host, lvec)
    assert_feasible(g, dist, lvec)
    ch, cd = metrics.edge_cut(g, host), metrics.edge_cut(g, dist)
    assert ch < cut0 and cd < cut0
    # same algorithm, different traversal order: cuts land close
    assert abs(ch - cd) <= 0.35 * max(ch, cd), (ch, cd)


def test_refine_selftest_2dev():
    """Fast (non-slow) distributed coverage: both refinement tiers on 2
    forced devices — LP improves + stays feasible, unconstrained beats
    the same start after afterburner repair, and the owner-sharded
    weight tables reproduce the replicated ones bit for bit."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.selftest", "--devices", "2",
         "--n", "1200", "--k", "4", "--test", "refine"],
        capture_output=True, text=True, env=env, timeout=840)
    lines = [json.loads(l) for l in proc.stdout.splitlines()
             if l.startswith("{")]
    assert proc.returncode == 0, (proc.stdout, proc.stderr[-2000:])
    assert len(lines) == 3, lines
    assert all(r["pass"] for r in lines), lines
