"""Static-analysis suite tests: seeded-violation fixtures must fire,
clean programs must not, the allowlist loader must reject unreviewed
suppressions, and the dispatch fallback must be loud at the boundary."""
import subprocess
import sys
import warnings

import numpy as np
import pytest

from repro.analysis import collectives_pass, lint, overflow_pass, vmem
from repro.analysis.findings import (AllowEntry, Allowlist, Finding,
                                     Report)
from repro.analysis.fixtures import (fixture_collective_mismatch,
                                     fixture_lint, fixture_overflow,
                                     fixture_vmem)


def rules(report):
    return [f.rule for f in report.findings]


# ---------------------------------------------------------------------------
# negative tests: the seeded fixtures must fire their pass
# ---------------------------------------------------------------------------

def test_collective_fixture_fires_mismatch_and_check_rep():
    # P=1 is enough: the branch-signature mismatch and the
    # check_rep=False staging are structural, not device-count-bound
    report = Report()
    collectives_pass.run(fixture_collective_mismatch.captured(1), report)
    got = rules(report)
    assert "SPMD002" in got, got   # cond branches diverge on psum
    assert "SPMD003" in got, got   # check_rep=False, not allowlisted


def test_overflow_fixture_fires_on_sum_form():
    report = Report()
    overflow_pass.run(fixture_overflow.captured(), report)
    assert rules(report) == ["OFL001"], rules(report)
    (f,) = report.findings
    assert f.function == "admit"
    assert "fixture_overflow" in f.file


def test_overflow_guard_form_is_clean():
    # the sanctioned `w <= budget - c` rewrite of the same check
    import jax
    import jax.numpy as jnp

    def admit(cluster_w, vweights, labels, budget):
        cw = cluster_w[labels]
        return cw <= budget - vweights

    n = 8
    args = (jnp.ones((n,), jnp.int32), jnp.ones((n,), jnp.int32),
            jnp.zeros((n,), jnp.int32), jnp.full((n,), 100, jnp.int32))
    report = Report()
    overflow_pass.run([("guarded", jax.make_jaxpr(admit)(*args))], report)
    assert report.findings == []


def test_lint_fixture_fires_all_three_rules():
    report = Report()
    lint.check_file(fixture_lint.__file__, report, serve_hot=True)
    got = rules(report)
    assert got.count("LNT001") == 2, got  # np.random + random.random
    assert "LNT002" in got, got           # shard_map w/o check_rep=
    assert "LNT003" in got, got           # .item() in serve hot path


def test_vmem_fixture_fires_divergence():
    report = Report()
    vmem.run(report, static_fn=fixture_vmem.static_bytes)
    got = rules(report)
    assert "VMEM001" in got, got


def test_vmem_static_matches_runtime_gate():
    # the real inventories must agree with the runtime planning
    # formulas at every grid point (the 5% budget is headroom, not
    # slack we actually use)
    report = Report()
    points = vmem.run(report)
    assert points > 100
    assert report.findings == [], rules(report)


# ---------------------------------------------------------------------------
# allowlist semantics
# ---------------------------------------------------------------------------

def test_allowlist_rejects_missing_reason(tmp_path):
    p = tmp_path / "allow.toml"
    p.write_text('[[overflow]]\nfile = "src/x.py"\n')
    with pytest.raises(ValueError, match="reason"):
        Allowlist.load(str(p))


def test_allowlist_rejects_unknown_table(tmp_path):
    p = tmp_path / "allow.toml"
    p.write_text('[[typo]]\nfile = "src/x.py"\nreason = "r"\n')
    with pytest.raises(ValueError, match="unknown table"):
        Allowlist.load(str(p))


def test_allowlist_suppresses_only_matching_kind():
    allow = Allowlist([AllowEntry(kind="overflow", file="src/x.py",
                                  function="f", reason="bounded")])
    report = Report(allow)
    report.add(Finding(rule="OFL001", pass_name="overflow", message="m",
                       file="src/x.py", function="f"))
    report.add(Finding(rule="SPMD003", pass_name="collectives",
                       message="m", file="src/x.py", function="f"))
    assert len(report.suppressed) == 1
    assert rules(report) == ["SPMD003"]


def test_repo_allowlist_loads_and_every_entry_has_reason():
    allow = Allowlist.load()
    assert allow.entries, "repo allowlist is empty"
    assert all(e.reason for e in allow.entries)


# ---------------------------------------------------------------------------
# dispatch fallback observability (satellite: no more silent fallback)
# ---------------------------------------------------------------------------

def _dedup_inputs():
    csrc = np.array([0, 1, 1, 2, 0], dtype=np.int64)
    cdst = np.array([1, 0, 2, 1, 1], dtype=np.int64)
    w = np.ones(csrc.size, dtype=np.int64)
    return csrc, cdst, w


def test_fallback_boundary_exact_budget_stays_fused(monkeypatch):
    from repro.core import contraction
    from repro.kernels import dispatch
    from repro.kernels.seg_merge import ops as seg_ops
    from repro.kernels.seg_merge.seg_merge import seg_merge_vmem_bytes

    csrc, cdst, w = _dedup_inputs()
    est = seg_merge_vmem_bytes(csrc.size)
    # ops modules freeze the budget at import: patch the frozen copy
    monkeypatch.setattr(seg_ops, "VMEM_BUDGET_BYTES", est)
    dispatch.reset_fallback_state()
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # any fallback warning -> fail
        out = contraction.dedup_arcs(csrc, cdst, w, kernel="fused")
    assert dispatch.drain_fallback_records() == []
    want = contraction.dedup_arcs(csrc, cdst, w, kernel="composed")
    assert all(np.array_equal(a, b) for a, b in zip(out, want))


def test_fallback_one_past_budget_warns_once_and_records(monkeypatch):
    from repro.core import contraction
    from repro.kernels import dispatch
    from repro.kernels.seg_merge import ops as seg_ops
    from repro.kernels.seg_merge.seg_merge import seg_merge_vmem_bytes

    csrc, cdst, w = _dedup_inputs()
    est = seg_merge_vmem_bytes(csrc.size)
    monkeypatch.setattr(seg_ops, "VMEM_BUDGET_BYTES", est - 1)
    dispatch.reset_fallback_state()
    with pytest.warns(UserWarning, match="seg_merge"):
        out = contraction.dedup_arcs(csrc, cdst, w, kernel="fused")
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # one-shot: second time silent
        contraction.dedup_arcs(csrc, cdst, w, kernel="fused")
    records = dispatch.drain_fallback_records()
    assert len(records) == 2  # every decision recorded, warned once
    assert records[0]["event"] == "kernel-fallback"
    assert records[0]["kernel"] == "seg_merge"
    assert records[0]["estimated_bytes"] == est
    assert dispatch.drain_fallback_records() == []  # drained
    want = contraction.dedup_arcs(csrc, cdst, w, kernel="composed")
    assert all(np.array_equal(a, b) for a, b in zip(out, want))


def test_fallback_records_drain_into_partition_trace(monkeypatch):
    from repro.core import deep_mgp
    from repro.graphs import generators
    from repro.kernels import dispatch
    from repro.kernels.bal_round import ops as bal_ops
    from repro.kernels.lp_move import ops as move_ops
    from repro.kernels.seg_merge import ops as seg_ops

    # force every fused path over budget: the whole run falls back to
    # the composed kernels and the driver drains the records into the
    # trace (also keeps this test fast — no interpret-mode Pallas)
    monkeypatch.setattr(move_ops, "VMEM_BUDGET_BYTES", 0)
    monkeypatch.setattr(bal_ops, "VMEM_BUDGET_BYTES", 0)
    monkeypatch.setattr(seg_ops, "VMEM_BUDGET_BYTES", 0)
    dispatch.reset_fallback_state()
    g = generators.make("rgg2d", 300, 6.0, seed=2)
    cfg = deep_mgp.PartitionerConfig(contraction_limit=64,
                                     ip_repetitions=1, num_chunks=2,
                                     kernel="fused")
    trace = []
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", UserWarning)
        deep_mgp.partition(g, 2, cfg, trace=trace)
    events = [t for t in trace if t.get("event") == "kernel-fallback"]
    assert events, trace
    assert all(t["budget_bytes"] == dispatch.VMEM_BUDGET_BYTES or
               t["budget_bytes"] >= 0 for t in events)
    assert dispatch.drain_fallback_records() == []


# ---------------------------------------------------------------------------
# end-to-end CLI directions (subprocess; slow)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_cli_repo_clean_and_fixtures_fire():
    def run(*extra):
        return subprocess.run(
            [sys.executable, "-m", "repro.analysis", *extra],
            capture_output=True, text=True)

    proc = run()
    assert proc.returncode == 0, proc.stdout + proc.stderr
    for fx in ("collective", "overflow", "lint", "vmem"):
        proc = run("--fixture", fx)
        assert proc.returncode == 1, (fx, proc.stdout + proc.stderr)
