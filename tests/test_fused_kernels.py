"""Fused hot-loop kernel validation (lp_move / seg_merge / bal_round).

Two layers, all bit-exact (integer math end to end):

* hypothesis property tests of each Pallas kernel (interpret=True on
  CPU) against its composed-XLA oracle in ``kernels/*/ref.py``, with
  the padding edges the ELL layout produces in production — sentinel
  ``-1`` neighbor labels, zero-weight padded arcs, fully-padded rows,
  and record counts that are not a power of two / lane multiple before
  padding;
* end-to-end equality of the wired entry points under
  ``kernel="fused"`` vs ``kernel="composed"`` (labels AND cut), the
  same invariant ``launch/selftest.py --test kernels`` enforces on
  multi-device meshes.

Shapes are kept fixed inside each property so interpret-mode jit
compiles once per test, not once per example.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip (not error) without hypothesis
    _skip = pytest.mark.skip(reason="hypothesis not installed")

    def given(*_a, **_k):
        return lambda fn: _skip(fn)

    def settings(*_a, **_k):
        return lambda fn: fn

    class _NullStrategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _NullStrategies()

import jax.numpy as jnp

from repro.core import metrics
from repro.core.balance import rebalance
from repro.core.coarsening import cluster
from repro.core.contraction import contract, dedup_arcs
from repro.core.deep_mgp import PartitionerConfig, partition
from repro.graphs import generators
from repro.kernels.bal_round.bal_round import (NEG_INF, bal_scores,
                                               greedy_pick)
from repro.kernels.bal_round.ref import bal_scores_ref, greedy_pick_ref
from repro.kernels.lp_move.lp_move import I32_MAX, lp_move_chunk
from repro.kernels.lp_move.ref import lp_move_chunk_ref
from repro.kernels.seg_merge.seg_merge import seg_merge
from repro.kernels.seg_merge.ref import seg_merge_ref


# ---------------------------------------------------------------------------
# lp_move: fused LP move kernel vs composed oracle
# ---------------------------------------------------------------------------

R_LP, D_LP = 64, 128


def _rand_move_inputs(rng, n_labels, W):
    """ELL chunk operands with production padding: ~25% sentinel lanes
    (label -1, weight 0) and the last rows fully padded."""
    nlab = rng.integers(0, n_labels, (R_LP, D_LP)).astype(np.int32)
    nlab[rng.random((R_LP, D_LP)) < 0.25] = -1
    nlab[-4:] = -1                                   # fully padded rows
    nw = rng.integers(1, 6, (R_LP, D_LP)).astype(np.int32)
    nw[nlab < 0] = 0                                 # zero-weight padding
    ncw = rng.integers(0, 2 * W + 2, (R_LP, D_LP)).astype(np.int32)
    own = rng.integers(0, n_labels, (R_LP, 1)).astype(np.int32)
    vw = rng.integers(1, 4, (R_LP, 1)).astype(np.int32)
    scal = np.array([[W, int(rng.integers(0, 1000))]], dtype=np.int32)
    salt = np.array([[rng.integers(0, 2**32)]], dtype=np.uint32)
    nbud = rng.integers(0, 2 * W + 2, (R_LP, D_LP)).astype(np.int32)
    return nlab, nw, ncw, own, vw, scal, salt, nbud


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), n_labels=st.integers(2, 40),
       W=st.integers(2, 30))
def test_lp_move_chunk_matches_ref_host(seed, n_labels, W):
    rng = np.random.default_rng(seed)
    nlab, nw, ncw, own, vw, scal, salt, _ = _rand_move_inputs(
        rng, n_labels, W)
    args = [jnp.asarray(x) for x in (nlab, nw, ncw, own, vw, scal, salt)]
    moved, tgt = lp_move_chunk(*args, fit_sum=True)
    rmoved, rtgt = lp_move_chunk_ref(*args, fit_sum=True)
    np.testing.assert_array_equal(np.asarray(moved), np.asarray(rmoved))
    np.testing.assert_array_equal(np.asarray(tgt), np.asarray(rtgt))
    # fully padded rows never move
    assert not np.asarray(moved)[-4:].any()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), W=st.integers(2, 30))
def test_lp_move_chunk_matches_ref_dist(seed, W):
    """The dist admission test (per-neighbor budget, fit_sum=False)."""
    rng = np.random.default_rng(seed)
    nlab, nw, ncw, own, vw, scal, salt, nbud = _rand_move_inputs(
        rng, 24, W)
    args = [jnp.asarray(x) for x in (nlab, nw, ncw, own, vw, scal, salt,
                                     nbud)]
    moved, tgt = lp_move_chunk(*args, fit_sum=False)
    rmoved, rtgt = lp_move_chunk_ref(*args, fit_sum=False)
    np.testing.assert_array_equal(np.asarray(moved), np.asarray(rmoved))
    np.testing.assert_array_equal(np.asarray(tgt), np.asarray(rtgt))


def test_cluster_fused_vs_composed_bit_identical():
    """End to end through ``coarsening.cluster`` — the graph's max
    degree is far below one lane width, so ELL pads D up to 128 (the
    "D not a multiple of 128 pre-pad" edge)."""
    g = generators.make("rgg2d", 500, 8.0, seed=11)
    W = max(1, g.total_vweight // 10)
    lab_c = cluster(g, W, num_iterations=2, num_chunks=4, seed=2,
                    kernel="composed")
    lab_f = cluster(g, W, num_iterations=2, num_chunks=4, seed=2,
                    kernel="fused")
    np.testing.assert_array_equal(lab_f, lab_c)


# ---------------------------------------------------------------------------
# seg_merge: segmented sort + duplicate-arc merge vs composed oracle
# ---------------------------------------------------------------------------

L_SM = 256


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), ids=st.integers(2, 40))
def test_seg_merge_matches_ref(seed, ids):
    """Duplicate-heavy records incl. ~20% I32_MAX padding sentinels."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, ids, L_SM).astype(np.int32)
    dst = rng.integers(0, ids, L_SM).astype(np.int32)
    w = rng.integers(1, 9, L_SM).astype(np.int32)
    pad = rng.random(L_SM) < 0.2
    src[pad] = I32_MAX
    dst[pad] = I32_MAX
    w[pad] = 0
    s_src, s_dst, tot, first = seg_merge(src, dst, w)
    r_src, r_dst, r_tot, r_first = seg_merge_ref(
        jnp.asarray(src), jnp.asarray(dst), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(s_src), np.asarray(r_src))
    np.testing.assert_array_equal(np.asarray(s_dst), np.asarray(r_dst))
    np.testing.assert_array_equal(np.asarray(tot), np.asarray(r_tot))
    np.testing.assert_array_equal(np.asarray(first), np.asarray(r_first))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_dedup_arcs_fused_vs_composed(seed):
    """Non-pow2 record count (pads internally), self loops dropped,
    parallel arcs merged — fused output bit-identical incl. dtypes."""
    rng = np.random.default_rng(seed)
    m = 300                                   # pads to L=512 inside
    csrc = rng.integers(0, 25, m)
    cdst = rng.integers(0, 25, m)
    w = rng.integers(1, 7, m)
    outs_c = dedup_arcs(csrc, cdst, w, kernel="composed")
    outs_f = dedup_arcs(csrc, cdst, w, kernel="fused")
    for a_f, a_c in zip(outs_f, outs_c):
        assert a_f.dtype == a_c.dtype == np.int64
        np.testing.assert_array_equal(a_f, a_c)


def test_contract_fused_vs_composed_bit_identical():
    g = generators.make("rgg2d", 500, 8.0, seed=11)
    labels = cluster(g, max(1, g.total_vweight // 10), num_iterations=2,
                     num_chunks=4, seed=2, kernel="composed")
    (gc_c, map_c) = contract(g, labels, kernel="composed")
    (gc_f, map_f) = contract(g, labels, kernel="fused")
    np.testing.assert_array_equal(map_f, map_c)
    np.testing.assert_array_equal(gc_f.indptr, gc_c.indptr)
    np.testing.assert_array_equal(gc_f.adjncy, gc_c.adjncy)
    np.testing.assert_array_equal(gc_f.eweights, gc_c.eweights)
    np.testing.assert_array_equal(gc_f.vweights, gc_c.vweights)


# ---------------------------------------------------------------------------
# bal_round: balance scores + greedy pick vs composed oracles
# ---------------------------------------------------------------------------

R_BR, D_BR = 64, 128


def _rand_bal_inputs(rng, k, restricted):
    nlab = rng.integers(0, k, (R_BR, D_BR)).astype(np.int32)
    nlab[rng.random((R_BR, D_BR)) < 0.25] = -1
    nlab[-4:] = -1
    nw = rng.integers(1, 6, (R_BR, D_BR)).astype(np.int32)
    nw[nlab < 0] = 0
    nbw = rng.integers(0, 40, (R_BR, D_BR)).astype(np.int32)
    nlm = rng.integers(10, 40, (R_BR, D_BR)).astype(np.int32)
    own = rng.integers(0, k, (R_BR, 1)).astype(np.int32)
    vw = rng.integers(1, 4, (R_BR, 1)).astype(np.int32)
    ovr = (rng.random((R_BR, 1)) < 0.5).astype(np.int32)
    vld = np.ones((R_BR, 1), np.int32)
    vld[-4:] = 0
    fb_t = rng.integers(0, k, (R_BR, 1)).astype(np.int32)
    fb_ok = (rng.random((R_BR, 1)) < 0.5).astype(np.int32)
    salt = np.array([[rng.integers(0, 2**32)]], dtype=np.uint32)
    if not restricted:
        return (nlab, nw, nbw, nlm, own, vw, ovr, vld, fb_t, fb_ok,
                salt), {}
    par = rng.integers(0, max(1, k // 2), k + 1).astype(np.int32)
    npar = np.where(nlab >= 0, par[np.maximum(nlab, 0)], -2).astype(
        np.int32)
    opar = par[own]
    return (nlab, nw, nbw, nlm, own, vw, ovr, vld, fb_t, fb_ok,
            salt), {"npar": npar, "opar": opar}


@pytest.mark.parametrize("restricted", [False, True])
@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.integers(2, 32))
def test_bal_scores_matches_ref(restricted, seed, k):
    rng = np.random.default_rng(seed)
    args, kw = _rand_bal_inputs(rng, k, restricted)
    args = [jnp.asarray(x) for x in args]
    kw = {k_: jnp.asarray(v) for k_, v in kw.items()}
    rel, tgt = bal_scores(*args, **kw, restricted=restricted)
    r_rel, r_tgt = bal_scores_ref(*args, **kw, restricted=restricted)
    np.testing.assert_array_equal(np.asarray(rel), np.asarray(r_rel))
    np.testing.assert_array_equal(np.asarray(tgt), np.asarray(r_tgt))
    # padded / invalid rows can never be movable
    assert np.all(np.asarray(rel)[-4:] == NEG_INF)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_greedy_pick_matches_ref(seed):
    rng = np.random.default_rng(seed)
    M, K = 64, 16
    vals = rng.standard_normal(M).astype(np.float32)
    vals[rng.random(M) < 0.3] = NEG_INF              # masked pool slots
    tgt = rng.integers(0, K, M).astype(np.int32)
    src = rng.integers(0, K, M).astype(np.int32)
    cw = rng.integers(1, 5, M).astype(np.int32)
    bw = rng.integers(0, 60, K).astype(np.int32)
    lm = rng.integers(10, 50, K).astype(np.int32)
    acc, bw_out = greedy_pick(*(jnp.asarray(x) for x in
                                (vals, tgt, src, cw, bw, lm)))
    r_acc, r_bw = greedy_pick_ref(*(jnp.asarray(x) for x in
                                    (vals, tgt, src, cw, bw, lm)))
    np.testing.assert_array_equal(np.asarray(acc).astype(bool),
                                  np.asarray(r_acc))
    np.testing.assert_array_equal(np.asarray(bw_out), np.asarray(r_bw))


def test_rebalance_fused_vs_composed_bit_identical():
    """Skewed start (70% in block 0) so the round loop actually runs."""
    g = generators.make("rgg2d", 500, 8.0, seed=11)
    k = 6
    lmax = np.full(k, metrics.l_max(g.total_vweight, k, 0.03,
                                    int(g.vweights.max())), dtype=np.int64)
    rng = np.random.default_rng(5)
    part0 = np.where(rng.random(g.n) < 0.7, 0,
                     rng.integers(0, k, g.n)).astype(np.int64)
    st_c, st_f = {}, {}
    out_c = rebalance(g, part0.copy(), lmax, seed=7, kernel="composed",
                      stats=st_c)
    out_f = rebalance(g, part0.copy(), lmax, seed=7, kernel="fused",
                      stats=st_f)
    np.testing.assert_array_equal(out_f, out_c)
    assert st_f["rounds"] == st_c["rounds"]
    assert metrics.is_feasible(g, out_f, k, 0.03)


# ---------------------------------------------------------------------------
# full pipeline: one knob, every kernel, labels AND cut identical
# ---------------------------------------------------------------------------

def test_partition_fused_vs_composed_bit_identical():
    g = generators.make("rgg2d", 500, 8.0, seed=13)
    k = 4
    parts = {}
    for mode in ("composed", "fused"):
        cfg = PartitionerConfig(contraction_limit=80, ip_repetitions=1,
                                num_chunks=4, seed=3, kernel=mode)
        parts[mode] = partition(g, k, cfg)
    np.testing.assert_array_equal(parts["fused"], parts["composed"])
    assert metrics.edge_cut(g, parts["fused"]) == \
        metrics.edge_cut(g, parts["composed"])
    assert metrics.is_feasible(g, parts["fused"], k, 0.03)
