"""Distributed partitioner tests.

jax locks the device count at first init, so multi-device tests run in
subprocesses via ``repro.launch.selftest`` with
``--xla_force_host_platform_device_count``. Each selftest prints one JSON
line per check and exits nonzero on failure.
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_selftest(*extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.selftest", *extra],
        capture_output=True, text=True, env=env, timeout=840)
    lines = [json.loads(l) for l in proc.stdout.splitlines()
             if l.startswith("{")]
    assert proc.returncode == 0, (proc.stdout, proc.stderr[-2000:])
    return lines


def test_smoke_2dev():
    """Fast (non-slow) smoke: collectives round-trip + GraphShards halo
    exchange on 2 forced devices — tier-1 exercises the repro.dist import
    path and both all-to-all variants on every run."""
    res = run_selftest("--devices", "2", "--n", "500", "--test", "smoke")
    assert len(res) == 4, res
    assert all(r["pass"] for r in res), res


def test_grid_collectives_4dev():
    """Fast (non-slow) grid coverage: at P=2 the grid degenerates to the
    direct exchange, so tier-1 also runs P=4 (a genuine 2x2 grid) to keep
    the two-phase routing honest on every run."""
    res = run_selftest("--devices", "4", "--test", "collectives")
    assert all(r["pass"] for r in res), res


def test_api_facade_2dev():
    """Fast (non-slow) facade coverage on 2 forced devices: the repro.api
    dist backend must reproduce the driver bit-exactly, the feasibility
    flag must agree with metrics, auto must route to a dist backend, and
    a batched PartitionSession must equal per-request results."""
    res = run_selftest("--devices", "2", "--n", "800", "--test", "api")
    assert len(res) == 4, res
    assert all(r["pass"] for r in res), res


def test_sharded_contract_2dev():
    """Fast (non-slow) sharded-contraction coverage: hash ownership,
    segmented all-to-all edge exchange and owner-side merge must agree
    with the host kernel up to a coarse-id bijection on 2 devices."""
    res = run_selftest("--devices", "2", "--n", "600", "--test",
                       "contract")
    assert len(res) == 2, res
    assert all(r["pass"] for r in res), res


def test_balance_2dev():
    """Fast (non-slow) distributed-balancer coverage: P=1 bit-identity
    with the host balancer, adversarial-start feasibility, sharded
    cluster-weight enforcement equivalence, and the no-host-gather trace
    assertion for balance="dist" under both weight-table layouts."""
    res = run_selftest("--devices", "2", "--n", "900", "--k", "4",
                       "--test", "balance")
    assert len(res) == 8, res
    assert all(r["pass"] for r in res), res


def test_serve_2dev():
    """Fast (non-slow) serving-tier coverage: a 2-mesh PartitionServer
    (one device each) drains 8 concurrent mixed-size requests
    bit-identically to solo runs, fails a killed worker's requests over
    to the other mesh, and surfaces deadline expiry as a structured
    error."""
    res = run_selftest("--devices", "2", "--n", "800", "--k", "4",
                       "--test", "serve")
    assert len(res) == 4, res
    assert all(r["pass"] for r in res), res


@pytest.mark.slow
def test_serve_4dev_multidevice_meshes():
    """Serving tier with genuinely multi-device worker meshes: two
    disjoint 2-device slices, distributed requests routed by fit."""
    res = run_selftest("--devices", "4", "--n", "1600", "--k", "4",
                       "--test", "serve")
    assert len(res) == 4, res
    assert all(r["pass"] for r in res), res


@pytest.mark.slow
def test_halo_8dev():
    """Ghost-vertex exchange must reproduce the single-process graph's
    neighbor values for every ghost slot, via direct and grid routing."""
    res = run_selftest("--devices", "8", "--test", "halo", "--n", "3000")
    assert all(r["pass"] for r in res), res


@pytest.mark.slow
def test_collectives_8dev():
    res = run_selftest("--devices", "8", "--test", "collectives")
    assert all(r["pass"] for r in res), res


@pytest.mark.slow
def test_dist_cluster_8dev():
    res = run_selftest("--devices", "8", "--test", "cluster", "--n", "3000")
    assert all(r["pass"] for r in res), res


@pytest.mark.slow
def test_dist_refine_8dev():
    res = run_selftest("--devices", "8", "--test", "refine", "--n", "3000")
    assert all(r["pass"] for r in res), res


@pytest.mark.slow
def test_dist_contract_8dev():
    """Sharded contraction on a real 8-PE clustering: invariants, host
    isomorphism, and grid-vs-direct equality of the edge exchange."""
    res = run_selftest("--devices", "8", "--test", "contract",
                       "--n", "3000")
    assert all(r["pass"] for r in res), res


@pytest.mark.slow
def test_dist_balance_8dev():
    """Distributed balancer at scale: feasibility, quality bound and the
    no-host-gather assertion on 8 devices (2x4 grid routing)."""
    res = run_selftest("--devices", "8", "--test", "balance",
                       "--n", "3000")
    assert len(res) == 8, res
    assert all(r["pass"] for r in res), res


@pytest.mark.slow
def test_dist_partition_8dev():
    """Covers both memory models: the default host/replicated pipeline
    and the fully sharded one (contraction="sharded", weights="owner"),
    each feasible and within the 1.5x quality bound."""
    res = run_selftest("--devices", "8", "--test", "partition",
                       "--n", "3000")
    assert len(res) == 2, res
    assert all(r["pass"] for r in res), res


@pytest.mark.slow
def test_dist_partition_nonsquare_grid_6dev():
    """6 PEs -> 2x3 grid routing, both memory models."""
    res = run_selftest("--devices", "6", "--test", "partition",
                       "--n", "2000", "--k", "4")
    assert len(res) == 2, res
    assert all(r["pass"] for r in res), res
