"""Balancer correctness tests (paper §4, Balancing).

Host-side property tests: feasibility from adversarial starts, the
early-return fast path, the int32 boundary behavior (clear errors
instead of silent wraps), the padded-block fallback regression, the
shared ejection rule, and the uncoarsening seed derivation. The
distributed balancer itself is exercised in subprocesses via
``repro.launch.selftest --test balance`` (see test_distributed.py).
"""
import numpy as np
import pytest

from repro.core import metrics
from repro.core.balance import rebalance
from repro.core.coarsening import (ejection_candidates,
                                   enforce_cluster_weights)
from repro.core.deep_mgp import uncoarsen_seed
from repro.core.refinement import pad_blocks
from repro.graphs import generators
from repro.graphs.format import from_coo


def ring(n, vweights=None):
    src = np.arange(n)
    dst = (src + 1) % n
    return from_coo(n, src, dst, vweights=vweights)


def assert_feasible(g, part, l_max_vec):
    k = int(l_max_vec.shape[0])
    assert part.min() >= 0 and part.max() < k, (part.min(), part.max(), k)
    bw = metrics.block_weights(g, part, k)
    assert np.all(bw <= l_max_vec), (bw, l_max_vec)


# ---------------------------------------------------------------------------
# feasibility from adversarial starts
# ---------------------------------------------------------------------------

def test_rebalance_all_in_one_block():
    g = generators.make("rgg2d", 1200, 8.0, seed=3)
    k = 16
    lmax = np.full(k, metrics.l_max(g.total_vweight, k, 0.03,
                                    int(g.vweights.max())), dtype=np.int64)
    part = np.zeros(g.n, dtype=np.int64)
    fixed = rebalance(g, part, lmax, seed=1)
    assert_feasible(g, fixed, lmax)


def test_rebalance_k_close_to_n():
    g = ring(80)
    k = 64
    lmax = np.full(k, metrics.l_max(g.total_vweight, k, 0.03,
                                    int(g.vweights.max())), dtype=np.int64)
    part = np.zeros(g.n, dtype=np.int64)
    fixed = rebalance(g, part, lmax, seed=2)
    assert_feasible(g, fixed, lmax)


def test_rebalance_heterogeneous_lmax():
    g = generators.make("rgg2d", 800, 8.0, seed=4)
    k = 8
    base = metrics.l_max(g.total_vweight, k, 0.03, int(g.vweights.max()))
    lvec = (base * (1 + (np.arange(k) % 3))).astype(np.int64)
    rng = np.random.default_rng(0)
    part = rng.integers(0, k, g.n).astype(np.int64)
    part[rng.random(g.n) < 0.7] = 0
    fixed = rebalance(g, part, lvec, seed=5)
    assert_feasible(g, fixed, lvec)


# ---------------------------------------------------------------------------
# early return: feasible inputs never touch the O(m) chunk build
# ---------------------------------------------------------------------------

def test_rebalance_feasible_early_return(monkeypatch):
    g = generators.make("rgg2d", 500, 8.0, seed=6)
    k = 4
    # round-robin start is feasible for generous budgets
    part = (np.arange(g.n) % k).astype(np.int64)
    lmax = np.full(k, int(g.total_vweight), dtype=np.int64)

    from repro.core import lp

    def boom(*a, **kw):
        raise AssertionError("feasible input must not build chunks")

    monkeypatch.setattr(lp, "build_chunks", boom)
    stats = {}
    out = rebalance(g, part, lmax, seed=0, stats=stats)
    assert np.array_equal(out, part)
    assert out is not part and not np.shares_memory(out, part)
    assert stats["rounds"] == 0 and stats["gather_bytes"] == 0


# ---------------------------------------------------------------------------
# int32 boundary: exact at 2^31 - 1, clear error at 2^31
# ---------------------------------------------------------------------------

def test_rebalance_at_int32_boundary():
    # total vertex weight == 2^31 - 1 exactly; the balancer must detect the
    # overload and reach feasibility without any comparison wrapping
    w = np.array([2**29, 2**29, 2**29, 2**31 - 1 - 3 * 2**29],
                 dtype=np.int64)
    g = ring(4, vweights=w)
    assert g.total_vweight == 2**31 - 1
    lmax = np.full(2, 2**30 + 2**29 + 16, dtype=np.int64)
    part = np.zeros(4, dtype=np.int64)
    fixed = rebalance(g, part, lmax, seed=0)
    assert_feasible(g, fixed, lmax)


def test_rebalance_overweight_total_raises():
    w = np.full(4, 2**29, dtype=np.int64)   # total == 2^31
    g = ring(4, vweights=w)
    assert g.total_vweight == 2**31
    lmax = np.full(2, 2**30, dtype=np.int64)   # infeasible -> no early out
    with pytest.raises(ValueError, match="2\\^31"):
        rebalance(g, np.zeros(4, dtype=np.int64), lmax, seed=0)


def test_pad_blocks_raises_on_overflow():
    with pytest.raises(ValueError, match="int32"):
        pad_blocks(np.array([2**31, 5], dtype=np.int64),
                   np.array([10, 10], dtype=np.int64), None)


def test_pad_blocks_dummies_never_lightest():
    # dummy blocks must carry the maximal weight so the argmin fallback
    # can never pick one (the historical 2^30 filler could win)
    bw, lv, _, k = pad_blocks(np.array([2**30 + 7], dtype=np.int64),
                              np.array([2**29], dtype=np.int64), None)
    assert k == 1 and bw.shape[0] >= 64
    assert int(np.argmin(bw)) == 0          # the real block stays lightest
    assert np.all(bw[1:] == 2**31 - 1)


def test_rebalance_never_emits_padded_block_ids():
    # regression: an infeasible k=1 instance whose only block exceeds 2^30
    # used to leak moves into the padded dummy blocks (labels >= k)
    n = 600
    w = np.full(n, 2**21, dtype=np.int64)
    g = ring(n, vweights=w)
    assert g.total_vweight > 2**30
    lmax = np.full(1, 2**29, dtype=np.int64)   # unsatisfiable: k == 1
    out = rebalance(g, np.zeros(n, dtype=np.int64), lmax, seed=0,
                    max_rounds=2)
    assert np.all(out == 0)                    # never a dummy block id


# ---------------------------------------------------------------------------
# shared ejection rule (host sweep; the sharded sweep must match it)
# ---------------------------------------------------------------------------

def test_ejection_candidates_postconditions():
    rng = np.random.default_rng(1)
    n = 400
    labels = rng.integers(0, 12, n).astype(np.int64)
    vweights = rng.integers(1, 9, n).astype(np.int64)
    W = 40
    ej = ejection_candidates(labels, vweights, W)
    out = enforce_cluster_weights(labels.copy(), vweights, W)
    # exactly the ejection candidates changed cluster
    assert np.array_equal(np.sort(np.flatnonzero(out != labels)),
                          np.sort(ej))
    # every multi-member cluster now fits W
    cw = np.zeros(n, dtype=np.int64)
    np.add.at(cw, out, vweights)
    members = np.bincount(out, minlength=n)
    assert np.all(cw[members > 1] <= W)
    # the heaviest member of every original cluster is never ejected
    for c in np.unique(labels):
        mem = np.flatnonzero(labels == c)
        heaviest = mem[np.lexsort((mem, -vweights[mem]))][0]
        assert heaviest not in ej


# ---------------------------------------------------------------------------
# uncoarsening seeds: level-derived, never colliding on equal n
# ---------------------------------------------------------------------------

def test_uncoarsen_seed_distinct_per_level():
    # distinct across levels AND across the two uncoarsening streams
    # (the distributed loop and the base case it delegates to both
    # count levels from 0)
    seeds = {uncoarsen_seed(42, lvl, stream=s)
             for lvl in range(64) for s in (0, 1)}
    assert len(seeds) == 128
    # the historical formula collided whenever two levels had equal n
    old = lambda s, n: s + n % 1000003
    assert old(42, 5000) == old(42, 5000)
    assert uncoarsen_seed(42, 0) != uncoarsen_seed(42, 1)
