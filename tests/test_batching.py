"""repro.serve.batching: shape buckets, padding inertness, the bounded
LRU graph cache, coalescing, and stacked level-0 bit-identity.

The stacked tests force ``stack="on"`` — the CPU auto-gate would skip
the vmapped path — so the kernel-level bit-identity claim is exercised
regardless of the host backend.
"""
import numpy as np
import pytest

from repro.api import (BucketCache, GraphSpec, PartitionRequest,
                       Partitioner, PartitionSession, is_batchable)
from repro.core import PartitionerConfig
from repro.core import metrics
from repro.serve.batching import (BucketKey, bucket_of, distinct_count,
                                  pad_dim, pad_graph, remove_padding,
                                  request_fingerprint, run_coalesced,
                                  stacked_level0_labels)

CFG = PartitionerConfig(contraction_limit=128, ip_repetitions=2,
                        num_chunks=4)


def req(n=700, k=4, seed=5, **kw):
    return PartitionRequest(graph=GraphSpec("rgg2d", n, 8.0, seed=seed),
                            k=k, config=CFG, backend="single", **kw)


# ---------------------------------------------------------------------------
# padding ladder + buckets (pure)
# ---------------------------------------------------------------------------

def test_pad_dim_geometric_ladder():
    assert pad_dim(1) == 1
    assert pad_dim(2) == 2
    assert pad_dim(3) == 4
    assert pad_dim(1024) == 1024
    assert pad_dim(1025) == 2048
    assert pad_dim(0, floor=256) == 256
    assert pad_dim(300, floor=256) == 512


def test_bucket_of_groups_same_rung():
    # different seeds, same shape rung -> same bucket
    assert bucket_of(req(seed=1)) == bucket_of(req(seed=2))
    assert bucket_of(req()) == BucketKey(1024, 8192, 4, "single")
    # k is part of the key
    assert bucket_of(req(k=2)) != bucket_of(req(k=4))
    # a different rung is a different bucket
    assert bucket_of(req(n=700)) != bucket_of(req(n=1100))


def test_bucket_of_none_for_solo_only_paths():
    # multi-device asks stay solo
    assert bucket_of(req(devices=2)) is None
    # dist backends are not batchable
    big = PartitionRequest(graph=GraphSpec("rgg2d", 50000), k=4,
                           devices=4)
    assert bucket_of(big) is None
    assert not is_batchable("dist")
    assert is_batchable("single")


def test_request_fingerprint_identity():
    assert request_fingerprint(req()) == request_fingerprint(req())
    assert request_fingerprint(req(seed=1)) != request_fingerprint(
        req(seed=2))
    # raw Graph payloads key by object identity
    g = GraphSpec("rgg2d", 300, 8.0, seed=3).materialize()
    a = PartitionRequest(graph=g, k=2, config=CFG, backend="single")
    b = PartitionRequest(graph=g, k=2, config=CFG, backend="single")
    assert request_fingerprint(a) == request_fingerprint(b)
    g2 = GraphSpec("rgg2d", 300, 8.0, seed=3).materialize()
    c = PartitionRequest(graph=g2, k=2, config=CFG, backend="single")
    assert request_fingerprint(a) != request_fingerprint(c)
    assert distinct_count([req(), req(), req(seed=9)]) == 2


# ---------------------------------------------------------------------------
# graph-level padding is inert
# ---------------------------------------------------------------------------

def test_pad_graph_preserves_cut_and_block_weights():
    g = GraphSpec("rgg2d", 500, 8.0, seed=11).materialize()
    res = Partitioner().run(PartitionRequest(graph=g, k=4, config=CFG,
                                             backend="single"))
    gp = pad_graph(g, 512)
    assert gp.n == 512 and gp.m == g.m
    assert gp.vweights[g.n:].sum() == 0
    # any labels on the padded vertices leave the metrics unchanged
    ext = np.concatenate([res.assignment,
                          np.arange(512 - g.n, dtype=np.int64) % 4])
    assert metrics.edge_cut(gp, ext) == res.cut
    assert np.array_equal(metrics.block_weights(gp, ext, 4),
                          metrics.block_weights(g, res.assignment, 4))
    assert np.array_equal(remove_padding(ext, g.n), res.assignment)


def test_pad_graph_validates_and_noops():
    g = GraphSpec("rgg2d", 500, 8.0, seed=11).materialize()
    assert pad_graph(g, 500) is g
    with pytest.raises(ValueError):
        pad_graph(g, 400)


# ---------------------------------------------------------------------------
# bounded LRU cache
# ---------------------------------------------------------------------------

def test_bucket_cache_lru_eviction_and_recency():
    c = BucketCache(maxsize=2)
    c["a"], c["b"] = 1, 2
    assert c["a"] == 1          # touch "a" -> "b" is now LRU
    c["c"] = 3
    assert "b" not in c and "a" in c and "c" in c
    assert len(c) == 2 and c.evictions == 1
    assert c.get("missing", 42) == 42
    with pytest.raises(ValueError):
        BucketCache(maxsize=0)


def test_session_cache_bound_rematerializes_correctly():
    specs = [GraphSpec("rgg2d", 300 + 100 * i, 8.0, seed=i)
             for i in range(3)]
    reqs = [PartitionRequest(graph=s, k=2, config=CFG, backend="single")
            for s in specs]
    solo = Partitioner().run_batch(reqs)
    with PartitionSession(devices=1, graph_cache_size=1) as sess:
        # serve forward then backward: every spec is evicted and
        # re-materialized at least once, results never change
        out = sess.run_batch(reqs) + sess.run_batch(reqs[::-1])
        assert len(sess._graph_cache) == 1
        assert sess._graph_cache.evictions >= 3
    for r, s in zip(out, solo + solo[::-1]):
        assert np.array_equal(r.assignment, s.assignment)


# ---------------------------------------------------------------------------
# coalescing + stacked level-0: bit-identity
# ---------------------------------------------------------------------------

def test_coalescing_shares_one_run_bit_identical():
    reqs = [req(), req(seed=9), req(), req()]
    solo = Partitioner().run_batch(reqs)
    with PartitionSession(devices=1, stack="off") as sess:
        out = sess.submit_many(reqs).result()
        served = sess.stats()["served"]
    assert out[0] is out[2] and out[0] is out[3]   # one shared run
    assert out[0] is not out[1]
    assert served == 2                             # 4 requests, 2 runs
    for r, s in zip(out, solo):
        assert np.array_equal(r.assignment, s.assignment)
        assert r.cut == s.cut


def test_stacked_level0_labels_match_solo_cluster():
    from repro.core.coarsening import cluster
    from repro.core.deep_mgp import level0_cluster_plan

    graphs = [GraphSpec("rgg2d", 500 + 170 * i, 8.0, seed=3 + i
                        ).materialize() for i in range(3)]
    plans = [level0_cluster_plan(g, 4, CFG) for g in graphs]
    assert all(p is not None for p in plans)
    labs = stacked_level0_labels(graphs, plans)
    for g, p, lab in zip(graphs, plans, labs):
        ref = cluster(g, p["W"], num_iterations=p["num_iterations"],
                      num_chunks=p["num_chunks"], seed=p["seed"])
        assert np.array_equal(lab, ref)


def test_stacked_end_to_end_bit_identical_to_solo():
    reqs = [req(n=500, k=2, seed=1), req(n=700, k=4, seed=2),
            req(n=900, k=4, seed=3)]
    solo = Partitioner().run_batch(reqs)
    with PartitionSession(devices=1, stack="on") as sess:
        out = run_coalesced(sess, reqs, stack="on")
    for r, s in zip(out, solo):
        assert np.array_equal(r.assignment, s.assignment)
        assert r.cut == s.cut
        assert r.feasible


def test_session_rejects_bad_stack_knob():
    with pytest.raises(ValueError):
        PartitionSession(stack="maybe")
