"""Pallas kernel validation: shape/dtype sweeps + hypothesis properties,
interpret=True (CPU) against pure-jnp oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip (not error) without hypothesis
    _skip = pytest.mark.skip(reason="hypothesis not installed")

    def given(*_a, **_k):
        return lambda fn: _skip(fn)

    def settings(*_a, **_k):
        return lambda fn: fn

    class _NullStrategies:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _NullStrategies()

from repro.graphs import generators
from repro.kernels.bsr_spmm.ops import spmm
from repro.kernels.bsr_spmm.ref import bsr_spmm_ref
from repro.kernels.bsr_spmm.bsr_spmm import bsr_spmm
from repro.kernels.embedding_bag.embedding_bag import embedding_bag_1row
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.lp_gain.lp_gain import lp_gain_ell
from repro.kernels.lp_gain.ops import lp_gain
from repro.kernels.lp_gain.ref import lp_gain_ell_ref


# ---------------------------------------------------------------------------
# lp_gain
# ---------------------------------------------------------------------------

def _rand_lp_inputs(rng, n, d, n_labels, budget):
    lab = rng.integers(0, n_labels, (n, d)).astype(np.int32)
    lab[rng.random((n, d)) < 0.2] = -1                  # padding
    w = rng.integers(1, 5, (n, d)).astype(np.float32)
    w[lab < 0] = 0.0
    cw = rng.integers(1, budget + 3, n_labels).astype(np.float32)
    tgt_w = np.where(lab >= 0, cw[np.maximum(lab, 0)], np.inf
                     ).astype(np.float32)
    own = rng.integers(0, n_labels, (n, 1)).astype(np.int32)
    vw = rng.integers(1, 3, (n, 1)).astype(np.float32)
    return lab, w, tgt_w, own, vw


@pytest.mark.parametrize("n,d", [(256, 128), (512, 256), (1024, 128)])
def test_lp_gain_matches_ref(n, d):
    rng = np.random.default_rng(n + d)
    budget = 8.0
    lab, w, tgt_w, own, vw = _rand_lp_inputs(rng, n, d, 50, budget)
    args = [jnp.asarray(x) for x in (lab, w, tgt_w, own, vw)]
    b = jnp.full((1, 1), budget, jnp.float32)
    best, target, own_conn = lp_gain_ell(*args, b, row_tile=128)
    rbest, rtarget, rown = lp_gain_ell_ref(*args, b)
    np.testing.assert_allclose(np.asarray(best), np.asarray(rbest),
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(target), np.asarray(rtarget))
    np.testing.assert_allclose(np.asarray(own_conn), np.asarray(rown),
                               rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), n_labels=st.integers(2, 64),
       budget=st.integers(1, 20))
def test_lp_gain_property(seed, n_labels, budget):
    rng = np.random.default_rng(seed)
    lab, w, tgt_w, own, vw = _rand_lp_inputs(rng, 256, 128, n_labels,
                                             budget)
    args = [jnp.asarray(x) for x in (lab, w, tgt_w, own, vw)]
    b = jnp.full((1, 1), float(budget), jnp.float32)
    best, target, own_conn = lp_gain_ell(*args, b, row_tile=128)
    rbest, rtarget, rown = lp_gain_ell_ref(*args, b)
    np.testing.assert_allclose(np.asarray(best), np.asarray(rbest),
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(target), np.asarray(rtarget))


def test_lp_gain_on_graph_agrees_with_partitioner_math():
    """Kernel gains == brute-force edge-scan gains on a real graph."""
    g = generators.make("rgg2d", 600, 8.0, seed=2)
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 8, g.n)
    cw = np.zeros(8, dtype=np.int64)
    np.add.at(cw, labels, g.vweights)
    budget = float(cw.max() + 10)
    gain, target, own_conn = lp_gain(g, labels, cw, budget, row_tile=128)
    src = g.arc_tails()
    conn = np.zeros((g.n, 8))
    np.add.at(conn, (src, labels[g.adjncy]), g.eweights)
    own_ref = conn[np.arange(g.n), labels]
    np.testing.assert_allclose(own_conn, own_ref, rtol=1e-6)
    masked = conn.copy()
    masked[np.arange(g.n), labels] = -1
    best_ref = masked.max(axis=1)
    has = best_ref > 0
    np.testing.assert_allclose(gain[has], (best_ref - own_ref)[has],
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# bsr_spmm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,f,bs", [(300, 64, 128), (700, 130, 128)])
def test_bsr_spmm_matches_dense(n, f, bs):
    g = generators.make("rgg2d", n, 8.0, seed=3)
    rng = np.random.default_rng(1)
    x = rng.standard_normal((g.n, f)).astype(np.float32)
    y = spmm(g, x, bs=bs)
    # dense reference
    a = np.zeros((g.n, g.n), dtype=np.float32)
    src = g.arc_tails()
    a[src, np.asarray(g.adjncy)] = g.eweights
    np.testing.assert_allclose(y, a @ x, rtol=5e-5, atol=5e-4)


def test_bsr_kernel_vs_ref_random_blocks():
    rng = np.random.default_rng(7)
    rb, nnz, bs, f = 4, 3, 128, 128
    col = rng.integers(0, rb, rb * nnz).astype(np.int32)
    vals = (rng.random((rb * nnz, bs, bs)) *
            (rng.random((rb * nnz, bs, bs)) < 0.05)).astype(np.float32)
    x = rng.standard_normal((rb * bs, f)).astype(np.float32)
    out = bsr_spmm(jnp.asarray(col), jnp.asarray(vals), jnp.asarray(x),
                   block_rows=rb, nnz_per_row=nnz)
    ref = bsr_spmm_ref(jnp.asarray(col), jnp.asarray(vals), jnp.asarray(x),
                       block_rows=rb, nnz_per_row=nnz)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5,
                               atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_bsr_spmm_property(seed):
    rng = np.random.default_rng(seed)
    rb, nnz, bs, f = 3, 2, 128, 128
    col = rng.integers(0, rb, rb * nnz).astype(np.int32)
    vals = rng.standard_normal((rb * nnz, bs, bs)).astype(np.float32)
    x = rng.standard_normal((rb * bs, f)).astype(np.float32)
    out = bsr_spmm(jnp.asarray(col), jnp.asarray(vals), jnp.asarray(x),
                   block_rows=rb, nnz_per_row=nnz)
    ref = bsr_spmm_ref(jnp.asarray(col), jnp.asarray(vals), jnp.asarray(x),
                       block_rows=rb, nnz_per_row=nnz)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# embedding_bag
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,bag,v,d", [(32, 1, 500, 64), (16, 4, 200, 128),
                                       (8, 2, 100, 200)])
def test_embedding_bag_matches_ref(b, bag, v, d):
    rng = np.random.default_rng(b * bag)
    idx = rng.integers(0, v, (b, bag)).astype(np.int32)
    table = rng.standard_normal((v, d)).astype(np.float32)
    out = embedding_bag(idx, table)
    ref = embedding_bag_ref(jnp.asarray(idx), jnp.asarray(table))
    np.testing.assert_allclose(out, np.asarray(ref), rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), bag=st.integers(1, 6))
def test_embedding_bag_property(seed, bag):
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, 64, (8, bag)).astype(np.int32)
    table = rng.standard_normal((64, 128)).astype(np.float32)
    out = embedding_bag_1row(jnp.asarray(idx), jnp.asarray(table))
    ref = embedding_bag_ref(jnp.asarray(idx), jnp.asarray(table))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_embedding_bag_duplicate_indices():
    """Same row repeated in a bag must be summed, not deduped."""
    table = np.eye(8, 128, dtype=np.float32)
    idx = np.array([[2, 2, 2]], dtype=np.int32)
    out = embedding_bag(idx, table)
    assert out[0, 2] == pytest.approx(3.0)
