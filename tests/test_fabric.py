"""repro.fabric cross-process tier: wire protocol codecs, registry
lease semantics, autoscaler hysteresis, windowed metrics, the
multi-process runtime helpers, and front-door routing/failover against
scripted fake workers (no jax partitions — the real end-to-end path is
the slow 2-process test at the bottom plus ``selftest --test fabric``).
"""
import json
import os
import signal
import socket
import struct
import subprocess
import sys
import threading
import time
from types import SimpleNamespace

import numpy as np
import pytest

from repro.api import GraphSpec, PartitionRequest, Partitioner
from repro.api.runtime import (device_slices, distributed_init,
                               jax_backend_initialized)
from repro.core import PartitionerConfig
from repro.fabric import (AutoscaleConfig, AutoscalePolicy, FabricClient,
                          FrontDoor, ServerRegistry, pick_server)
from repro.fabric import protocol
from repro.serve import ServeMetrics

CFG = PartitionerConfig(contraction_limit=128, ip_repetitions=2,
                        num_chunks=4)


def tiny_request(n=60, k=2, seed=3):
    return PartitionRequest(graph=GraphSpec("rgg2d", n, 6.0, seed=seed),
                            k=k, config=CFG, backend="single")


# ---------------------------------------------------------------------------
# wire protocol
# ---------------------------------------------------------------------------

def test_framing_roundtrip_and_eof():
    a, b = socket.socketpair()
    try:
        protocol.send_msg(a, {"op": "ping", "x": [1, 2, 3]})
        assert protocol.recv_msg(b) == {"op": "ping", "x": [1, 2, 3]}
        a.close()
        # clean EOF at a frame boundary reads as None, not an error
        assert protocol.recv_msg(b) is None
    finally:
        b.close()


def test_framing_midframe_eof_is_protocol_error():
    a, b = socket.socketpair()
    try:
        # header promises 100 bytes, then the peer dies
        a.sendall(struct.pack(">I", 100) + b"abc")
        a.close()
        with pytest.raises(protocol.ProtocolError):
            protocol.recv_msg(b)
    finally:
        b.close()


def test_request_codec_spec_roundtrip():
    req = tiny_request()
    got = protocol.decode_request(protocol.encode_request(req))
    assert got.graph == req.graph  # GraphSpec is a frozen dataclass
    assert got.k == req.k and got.epsilon == req.epsilon
    assert got.preset == req.preset and got.seed == req.seed
    assert got.config == req.config and got.backend == "single"
    assert got.devices == req.devices
    assert got.collect_trace == req.collect_trace


def test_request_codec_graph_arrays_roundtrip():
    from repro.graphs import generators
    g = generators.make("rgg2d", 80, 6.0, seed=1)
    req = PartitionRequest(graph=g, k=2, config=CFG, backend="single",
                           contraction="sharded", weights="owner")
    got = protocol.decode_request(protocol.encode_request(req))
    for field in ("indptr", "adjncy", "eweights", "vweights"):
        want = getattr(g, field)
        have = getattr(got.graph, field)
        assert have.dtype == want.dtype
        assert np.array_equal(have, want)
    assert got.k == req.k and got.config == req.config
    assert got.contraction == "sharded" and got.weights == "owner"


def fake_ok(req, sid, assignment=None, cut=3):
    """A canned ok ServeResult wire dict, as a worker would send."""
    n = req.graph.n
    asg = np.arange(n, dtype=np.int64) % 2 if assignment is None \
        else assignment
    sr = SimpleNamespace(
        ok=True, error=None, detail="", worker=0, attempts=1, priority=0,
        queue_wait_s=0.001, total_s=0.01,
        result=SimpleNamespace(assignment=asg, cut=cut, feasible=True,
                               backend="fake", time_s=0.01,
                               metrics={"n": np.int64(n)}))
    return protocol.encode_serve_result(sr, sid)


def test_result_codec_roundtrip():
    req = tiny_request()
    wire = fake_ok(req, "srv-a")
    res = protocol.decode_result(wire)
    assert res.ok and res.server == "srv-a" and res.cut == 3
    assert res.assignment.dtype == np.int64
    assert np.array_equal(res.assignment,
                          np.arange(req.graph.n, dtype=np.int64) % 2)
    assert res.metrics == {"n": req.graph.n}  # numpy scalar stripped

    err = protocol.decode_result(
        protocol.error_result("worker_failed", "boom", attempts=2))
    assert not err.ok and err.error == "worker_failed"
    assert err.attempts == 2 and err.assignment is None
    assert err.summary()["error"] == "worker_failed"


# ---------------------------------------------------------------------------
# registry leases (fake clock)
# ---------------------------------------------------------------------------

class Clock:
    def __init__(self):
        self.t = 100.0

    def __call__(self):
        return self.t


def test_lease_register_renew_expire_timing():
    clk = Clock()
    reg = ServerRegistry(ttl_s=5.0, clock=clk)
    rec = reg.register("w0", "127.0.0.1", 1234, devices=2, meshes=3)
    assert rec.lease_expiry == 105.0 and rec.generation == 0
    assert [r.server_id for r in reg.alive()] == ["w0"]
    clk.t = 104.0
    assert reg.renew("w0", metrics={"inflight": 1})
    assert reg.get("w0").lease_expiry == 109.0
    assert reg.get("w0").renewals == 1
    assert reg.get("w0").metrics == {"inflight": 1}
    # no renewals past the new expiry: the lease lapses
    clk.t = 109.0
    assert reg.alive() == []
    dead = reg.expire()
    assert [r.server_id for r in dead] == ["w0"]
    assert reg.expire() == []  # expiry removes; a second sweep is empty


def test_renew_after_expiry_is_false_then_reregister_bumps_generation():
    clk = Clock()
    reg = ServerRegistry(ttl_s=2.0, clock=clk)
    reg.register("w0", "h", 1)
    clk.t += 3.0
    # the worker's cue to re-register: renew refuses a lapsed lease
    assert not reg.renew("w0")
    assert not reg.renew("never-registered")
    rec = reg.register("w0", "h", 2)
    assert rec.generation == 1 and rec.port == 2
    rec = reg.register("w0", "h", 3)
    assert rec.generation == 2


def test_expire_removes_only_lapsed_and_alive_is_sorted():
    clk = Clock()
    reg = ServerRegistry(ttl_s=5.0, clock=clk)
    reg.register("b", "h", 1)
    clk.t += 3.0
    reg.register("a", "h", 2)
    clk.t += 3.0  # b lapsed (6s), a still warm (3s)
    assert [r.server_id for r in reg.expire()] == ["b"]
    assert [r.server_id for r in reg.alive()] == ["a"]
    assert len(reg) == 1
    assert reg.deregister("a").server_id == "a"
    assert reg.deregister("a") is None


# ---------------------------------------------------------------------------
# autoscaler policy hysteresis (pure)
# ---------------------------------------------------------------------------

def test_policy_grows_only_after_consecutive_pressure_windows():
    pol = AutoscalePolicy(AutoscaleConfig(
        min_workers=1, max_workers=3, grow_queue_depth=2.0,
        grow_windows=2, shrink_windows=4))
    assert pol.observe(workers=1, queue_depth=5) == 0  # 1st breach
    assert pol.observe(workers=1, queue_depth=0, submitted=1) == 0  # reset
    assert pol.observe(workers=1, queue_depth=5) == 0
    assert pol.observe(workers=1, queue_depth=5) == 1  # 2nd in a row
    # pressure is per worker: depth 3 over 2 workers is no breach
    assert pol.observe(workers=2, queue_depth=3) == 0
    assert pol.observe(workers=2, queue_depth=3) == 0


def test_policy_deadline_miss_is_always_a_breach():
    pol = AutoscalePolicy(AutoscaleConfig(grow_windows=2, max_workers=2))
    assert pol.observe(workers=1, queue_depth=0, deadline_misses=1) == 0
    assert pol.observe(workers=1, queue_depth=0, deadline_misses=1) == 1


def test_policy_shrinks_after_idle_windows_within_bounds():
    pol = AutoscalePolicy(AutoscaleConfig(
        min_workers=1, max_workers=3, shrink_windows=3))
    for _ in range(2):
        assert pol.observe(workers=2, queue_depth=0) == 0
    assert pol.observe(workers=2, queue_depth=0) == -1
    # at min_workers the fleet never shrinks, however idle
    for _ in range(10):
        assert pol.observe(workers=1, queue_depth=0) == 0
    # inflight work is not idle
    for _ in range(10):
        assert pol.observe(workers=2, queue_depth=0, inflight=1) == 0


def test_policy_never_grows_past_max():
    pol = AutoscalePolicy(AutoscaleConfig(max_workers=2, grow_windows=1))
    assert pol.observe(workers=1, queue_depth=9) == 1
    assert pol.observe(workers=2, queue_depth=9) == 0


def test_autoscale_config_validates():
    with pytest.raises(ValueError):
        AutoscaleConfig(min_workers=0).validate()
    with pytest.raises(ValueError):
        AutoscaleConfig(min_workers=3, max_workers=2).validate()
    with pytest.raises(ValueError):
        AutoscaleConfig(eval_period_s=0.0).validate()


# ---------------------------------------------------------------------------
# scheduler: server-granularity routing (pure)
# ---------------------------------------------------------------------------

def S(sid, devices=1, inflight=0):
    return SimpleNamespace(sid=sid, devices=devices, inflight=inflight)


def test_pick_server_exact_fit_load_then_sid():
    assert pick_server(4, [S("a", 8), S("b", 4)]).sid == "b"  # exact
    assert pick_server(2, [S("a", 8), S("b", 4)]).sid == "b"  # smallest fit
    assert pick_server(1, [S("a", 1, inflight=2), S("b", 1)]).sid == "b"
    assert pick_server(1, [S("b", 1), S("a", 1)]).sid == "a"  # sid tiebreak
    assert pick_server(1, []) is None


# ---------------------------------------------------------------------------
# windowed metrics (satellite)
# ---------------------------------------------------------------------------

def test_snapshot_window_deltas_reset_between_reads():
    m = ServeMetrics(2)
    m.on_submit(1)
    m.on_dispatch(0)
    m.on_done(True, latency_s=0.2, queue_wait_s=0.01, worker=0)
    win = m.snapshot_window()
    assert win["submitted"] == 1 and win["completed"] == 1
    assert win["failed"] == 0
    assert win["latency_p99_s"] == pytest.approx(0.2)
    assert win["queue_depth_max"] >= 1
    # a second read covers only what happened since the first
    win2 = m.snapshot_window()
    assert win2["submitted"] == 0 and win2["completed"] == 0
    assert win2["latency_p99_s"] == 0.0
    m.on_submit(3)
    assert m.snapshot_window()["submitted"] == 1
    # cumulative snapshot is untouched by window reads
    assert m.snapshot()["submitted"] == 2


def test_per_worker_served_grows_for_late_workers():
    m = ServeMetrics(1)
    m.on_done(True, 0.1, 0.0, worker=0)
    m.on_done(True, 0.1, 0.0, worker=3)  # a server joined after startup
    assert m.snapshot()["per_worker_served"] == [1, 0, 0, 1]
    m.resize_workers(6)
    assert len(m.snapshot()["per_worker_served"]) == 6
    m.resize_workers(2)  # grow-only: never forgets a server's tally
    assert len(m.snapshot()["per_worker_served"]) == 6


# ---------------------------------------------------------------------------
# runtime helpers (satellites)
# ---------------------------------------------------------------------------

def test_device_slices_error_names_counts_and_feasible_carve():
    import jax
    have = len(jax.devices())
    with pytest.raises(RuntimeError) as ei:
        device_slices(have + 1, 4)
    msg = str(ei.value)
    assert f"only {have} device(s) available" in msg
    assert ("largest feasible" in msg) or ("no carve" in msg)
    with pytest.raises(ValueError):
        device_slices(0, 1)


def test_distributed_init_single_process_noop():
    info = distributed_init()
    assert info == {"mode": "single-process", "process_id": 0,
                    "num_processes": 1}


def test_distributed_init_env_fallback_single(monkeypatch):
    monkeypatch.setenv("REPRO_NUM_PROCESSES", "1")
    monkeypatch.delenv("REPRO_COORDINATOR", raising=False)
    assert distributed_init()["mode"] == "single-process"


def test_distributed_init_validates_ranks():
    with pytest.raises(ValueError):
        distributed_init(coordinator_address="127.0.0.1:9",
                         num_processes=2, process_id=5)


def test_distributed_init_refuses_initialized_backend():
    import jax
    jax.devices()  # make sure a backend exists in this process
    assert jax_backend_initialized()
    with pytest.raises(RuntimeError):
        distributed_init(coordinator_address="127.0.0.1:9",
                         num_processes=2, process_id=0)


# ---------------------------------------------------------------------------
# front door vs scripted fake workers (real sockets, no jax partitions)
# ---------------------------------------------------------------------------

class FakeWorker:
    """A scripted fabric server: registers with the front door over a
    real heartbeat connection and answers ``partition`` frames with
    whatever ``handler(msg, conn) -> wire dict | None`` returns (None =
    stay silent; the handler may also close ``conn`` to fake a crash).
    """

    def __init__(self, fd_addr, sid, handler, *, devices=1, meshes=1,
                 renew=True, heartbeat_s=0.1):
        self.sid = sid
        self.handler = handler
        self._renew = renew
        self._heartbeat_s = heartbeat_s
        self._fd_addr = fd_addr
        self._stop = threading.Event()
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.bind(("127.0.0.1", 0))
        self._listener.listen(8)
        self.host, self.port = self._listener.getsockname()[:2]
        self._devices, self._meshes = devices, meshes
        threading.Thread(target=self._accept, daemon=True).start()
        threading.Thread(target=self._heartbeat, daemon=True).start()

    def _accept(self):
        while not self._stop.is_set():
            try:
                conn, _ = self._listener.accept()
            except OSError:
                return
            threading.Thread(target=self._serve, args=(conn,),
                             daemon=True).start()

    def _serve(self, conn):
        try:
            while True:
                msg = protocol.recv_msg(conn)
                if msg is None:
                    return
                if msg.get("op") != "partition":
                    continue
                wire = self.handler(msg, conn)
                if wire is not None:
                    protocol.send_msg(conn, {"op": "result",
                                             "id": msg["id"],
                                             "result": wire})
        except (OSError, protocol.ProtocolError):
            return
        finally:
            try:
                conn.close()
            except OSError:
                pass

    def _heartbeat(self):
        try:
            sock = protocol.connect(*self._fd_addr, timeout=5.0)
            protocol.send_msg(sock, {
                "op": "register",
                "server": {"server_id": self.sid, "host": self.host,
                           "port": self.port, "devices": self._devices,
                           "meshes": self._meshes, "pid": 0}})
            protocol.recv_msg(sock)
            while self._renew and not self._stop.wait(self._heartbeat_s):
                protocol.send_msg(sock, {"op": "renew",
                                         "server_id": self.sid})
                protocol.recv_msg(sock)
            sock.close()
        except (OSError, protocol.ProtocolError):
            pass

    def stop(self):
        self._stop.set()
        try:
            self._listener.close()
        except OSError:
            pass


def wait_for_servers(fd, count, timeout=10.0):
    t_end = time.monotonic() + timeout
    while time.monotonic() < t_end:
        with fd._cond:
            live = sum(1 for h in fd._handles.values() if h.alive)
        if live >= count:
            return
        time.sleep(0.01)
    raise AssertionError(f"{count} server(s) never connected")


def test_frontdoor_routes_and_decodes():
    req = tiny_request()
    with FrontDoor(port=0, lease_ttl_s=2.0) as fd:
        w = FakeWorker((fd.host, fd.port), "a",
                       lambda m, c: fake_ok(
                           protocol.decode_request(m["request"]), "a"))
        try:
            wait_for_servers(fd, 1)
            with FabricClient(fd.host, fd.port) as client:
                res = client.submit(req).result(timeout=30)
                assert res.ok and res.server == "a"
                assert res.attempts == 1
                assert np.array_equal(
                    res.assignment,
                    np.arange(req.graph.n, dtype=np.int64) % 2)
                st = client.status()
                assert [s["server_id"] for s in st["servers"]] == ["a"]
        finally:
            w.stop()


def test_frontdoor_reroutes_on_server_closed_reply():
    req = tiny_request()
    with FrontDoor(port=0, lease_ttl_s=2.0) as fd:
        bad = FakeWorker((fd.host, fd.port), "a-bad",
                         lambda m, c: protocol.error_result(
                             "server_closed", "draining"))
        good = FakeWorker((fd.host, fd.port), "b-good",
                          lambda m, c: fake_ok(
                              protocol.decode_request(m["request"]),
                              "b-good"))
        try:
            wait_for_servers(fd, 2)
            with FabricClient(fd.host, fd.port) as client:
                # sid tiebreak routes to "a-bad" first; its structured
                # refusal re-routes to "b-good"
                res = client.submit(req).result(timeout=30)
                assert res.ok and res.server == "b-good"
                assert res.attempts == 2
        finally:
            bad.stop()
            good.stop()


def test_frontdoor_fails_over_on_connection_loss():
    req = tiny_request()

    def crash(msg, conn):
        conn.close()  # drop the work connection mid-request
        return None

    with FrontDoor(port=0, lease_ttl_s=2.0) as fd:
        bad = FakeWorker((fd.host, fd.port), "a-bad", crash)
        good = FakeWorker((fd.host, fd.port), "b-good",
                          lambda m, c: fake_ok(
                              protocol.decode_request(m["request"]),
                              "b-good"))
        try:
            wait_for_servers(fd, 2)
            with FabricClient(fd.host, fd.port) as client:
                res = client.submit(req).result(timeout=30)
                assert res.ok and res.server == "b-good"
                assert res.attempts == 2
        finally:
            bad.stop()
            good.stop()


def test_frontdoor_reroutes_from_expired_lease():
    req = tiny_request()
    with FrontDoor(port=0, lease_ttl_s=0.6) as fd:
        # "a-dead" accepts the request, never answers, never renews:
        # only the lease expiry can rescue its ticket
        dead = FakeWorker((fd.host, fd.port), "a-dead",
                          lambda m, c: None, renew=False)
        good = FakeWorker((fd.host, fd.port), "b-good",
                          lambda m, c: fake_ok(
                              protocol.decode_request(m["request"]),
                              "b-good"),
                          heartbeat_s=0.1)
        try:
            wait_for_servers(fd, 2)
            with FabricClient(fd.host, fd.port) as client:
                t0 = time.monotonic()
                res = client.submit(req).result(timeout=30)
                assert res.ok and res.server == "b-good"
                assert res.attempts == 2
                # rescued by expiry, not by a slow client timeout
                assert time.monotonic() - t0 < 10.0
            assert fd.registry.get("a-dead") is None
        finally:
            dead.stop()
            good.stop()


def test_frontdoor_no_worker_when_retries_exhausted():
    req = tiny_request()
    with FrontDoor(port=0, lease_ttl_s=2.0, max_retries=1) as fd:
        bad = FakeWorker((fd.host, fd.port), "only",
                         lambda m, c: protocol.error_result(
                             "worker_failed", "boom"))
        try:
            wait_for_servers(fd, 1)
            with FabricClient(fd.host, fd.port) as client:
                res = client.submit(req).result(timeout=30)
                assert not res.ok and res.error == "no_worker"
                assert "boom" in res.detail
        finally:
            bad.stop()


def test_frontdoor_fresh_ticket_waits_then_deadline():
    # zero registered servers: a fresh ticket is NOT no_worker'd (a
    # worker may register any moment) — its deadline still binds
    req = tiny_request()
    with FrontDoor(port=0, lease_ttl_s=2.0) as fd:
        with FabricClient(fd.host, fd.port) as client:
            res = client.submit(req, deadline_s=0.3).result(timeout=30)
            assert not res.ok and res.error == "deadline_exceeded"


def test_frontdoor_rejects_malformed_request():
    with FrontDoor(port=0, lease_ttl_s=2.0) as fd:
        sock = protocol.connect(fd.host, fd.port, timeout=5.0)
        try:
            protocol.send_msg(sock, {"op": "partition", "id": 7,
                                     "request": {"graph": {"kind": "?"}}})
            resp = protocol.recv_msg(sock)
            assert resp["op"] == "result" and resp["id"] == 7
            assert resp["result"]["error"] == "rejected"
        finally:
            sock.close()


def test_client_connection_loss_is_structured():
    lst = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    lst.bind(("127.0.0.1", 0))
    lst.listen(1)
    host, port = lst.getsockname()[:2]
    accepted = []

    def accept_then_hang():
        conn, _ = lst.accept()
        accepted.append(conn)

    threading.Thread(target=accept_then_hang, daemon=True).start()
    client = FabricClient(host, port)
    try:
        fut = client.submit(tiny_request())
        t_end = time.monotonic() + 5
        while not accepted and time.monotonic() < t_end:
            time.sleep(0.01)
        accepted[0].close()  # the "front door" dies mid-request
        res = fut.result(timeout=30)
        assert not res.ok and res.error == "connection_lost"
    finally:
        client.close()
        lst.close()


# ---------------------------------------------------------------------------
# 2-process end-to-end (slow: spawns a real worker subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_two_process_bit_identity_and_drain():
    import repro
    src_dir = os.path.dirname(os.path.dirname(repro.__file__))
    env = dict(os.environ)
    env["PYTHONPATH"] = src_dir + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    reqs = [PartitionRequest(
        graph=GraphSpec("rgg2d", 400 + 100 * i, 6.0, seed=2 + i),
        k=2 + i % 2, config=CFG) for i in range(3)]
    solo = [Partitioner().run(r) for r in reqs]
    with FrontDoor(port=0, lease_ttl_s=3.0) as fd:
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.launch.fabric", "worker",
             "--frontdoor", f"{fd.host}:{fd.port}",
             "--server-id", "t2p", "--heartbeat-s", "0.3"],
            stdout=subprocess.PIPE, env=env, text=True)
        try:
            ready = json.loads(proc.stdout.readline())
            assert ready["op"] == "ready" and ready["server_id"] == "t2p"
            wait_for_servers(fd, 1, timeout=60)
            with FabricClient(fd.host, fd.port) as client:
                rs = client.serve(reqs)
            assert all(r.ok and r.server == "t2p" for r in rs)
            for r, s in zip(rs, solo):
                assert np.array_equal(r.assignment, s.assignment)
                assert r.cut == s.cut
            # graceful drain: SIGTERM deregisters and exits cleanly
            proc.send_signal(signal.SIGTERM)
            assert proc.wait(timeout=60) == 0
            t_end = time.monotonic() + 10
            while fd.registry.get("t2p") and time.monotonic() < t_end:
                time.sleep(0.05)
            assert fd.registry.get("t2p") is None
        finally:
            if proc.poll() is None:
                proc.kill()
