"""repro.api facade: request/result contract, backend registry, old-vs-new
equivalence, batched sessions, runtime helpers.

Multi-device facade coverage lives in test_distributed.py (subprocess
selftest ``--test api``); here the dist backends run at P=1 in-process.
"""

import numpy as np
import pytest

from repro.api import (GraphSpec, PartitionRequest, Partitioner,
                       PartitionSession, available_backends,
                       partition as api_partition, register_backend,
                       resolve_backend, runtime)
from repro.core import PartitionerConfig, metrics
from repro.core.deep_mgp import partition as driver_partition
from repro.graphs import generators

CFG = PartitionerConfig(contraction_limit=128, ip_repetitions=2,
                        num_chunks=4)


@pytest.fixture(scope="module")
def g():
    return generators.make("rgg2d", 2000, 8.0, seed=3)


@pytest.fixture(scope="module")
def single_result(g):
    return Partitioner().run(
        PartitionRequest(graph=g, k=8, config=CFG, backend="single"))


# ---------------------------------------------------------------------------
# registry + auto policy
# ---------------------------------------------------------------------------

def test_builtin_backends_registered():
    assert {"single", "dist", "dist-grid", "plain_mgp",
            "single_level_lp"} <= set(available_backends())


def test_auto_policy_is_pure():
    import dataclasses
    req = PartitionRequest(graph=GraphSpec("rgg2d", 50000), k=16)
    assert resolve_backend(req, 50000) == "single"          # 1 device
    assert resolve_backend(
        dataclasses.replace(req, devices=4), 50000) == "dist"
    assert resolve_backend(
        dataclasses.replace(req, devices=16), 50000) == "dist-grid"
    # too small to shard -> stays single even with devices
    assert resolve_backend(
        dataclasses.replace(req, devices=8), 100) == "single"
    # explicit hint always wins
    assert resolve_backend(
        dataclasses.replace(req, backend="plain_mgp", devices=8),
        50000) == "plain_mgp"


def test_register_backend_roundtrip(g):
    @register_backend("toy-zeros")
    def _toy(graph, req, ctx):
        return np.zeros(graph.n, dtype=np.int64)
    try:
        res = Partitioner().run(
            PartitionRequest(graph=g, k=4, config=CFG,
                             backend="toy-zeros"))
        assert res.backend == "toy-zeros"
        assert not res.assignment.any()
        assert not res.feasible        # everything in one block
    finally:
        from repro.api import backends as _b
        _b._REGISTRY.pop("toy-zeros")


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [
    dict(k=0), dict(k=-3), dict(epsilon=0.0), dict(epsilon=-1.0),
    dict(devices=0), dict(preset="turbo"), dict(backend="nope"),
    dict(contraction="gather"), dict(weights="dense"),
    dict(balance="gathered"),
])
def test_request_validation_rejects(kw, g):
    base = dict(graph=g, k=8)
    base.update(kw)
    with pytest.raises(ValueError):
        PartitionRequest(**base).validate()


def test_request_memory_model_overrides(g):
    """contraction/weights/balance ride into the resolved config; None
    defers."""
    req = PartitionRequest(graph=g, k=8, contraction="sharded",
                           weights="owner", balance="dist").validate()
    cfg = req.resolve_config()
    assert cfg.contraction == "sharded" and cfg.weights == "owner"
    assert cfg.balance == "dist"
    base = PartitionRequest(graph=g, k=8).resolve_config()
    assert base.contraction == "host" and base.weights == "replicated"
    assert base.balance == "host"
    # an explicit config is still overridden by request-level knobs
    cfg2 = PartitionRequest(graph=g, k=8, config=CFG,
                            weights="owner").resolve_config()
    assert cfg2.weights == "owner" and cfg2.contraction == "host"


def test_request_validation_unknown_family():
    with pytest.raises(ValueError):
        PartitionRequest(graph=GraphSpec("nosuch", 100), k=2).validate()


@pytest.mark.parametrize("kw", [
    dict(epsilon=-0.5), dict(num_chunks=0),
    dict(contraction_limit=1, initial_k=2), dict(cluster_iterations=0),
    dict(contraction="gather"), dict(weights="dense"),
    dict(balance="gathered"),
])
def test_config_validate_rejects(kw):
    with pytest.raises(ValueError):
        PartitionerConfig(**kw).validate()


def test_driver_rejects_bad_k(g):
    with pytest.raises(ValueError):
        driver_partition(g, 0, CFG)
    from repro.dist.dist_partitioner import dist_partition_impl
    with pytest.raises(ValueError):
        dist_partition_impl(g, 0, 1, cfg=CFG)
    with pytest.raises(ValueError):
        dist_partition_impl(g, 4, 0, cfg=CFG)


# ---------------------------------------------------------------------------
# facade-vs-driver equivalence + shim removal
# ---------------------------------------------------------------------------

def test_single_matches_driver(g, single_result):
    want = driver_partition(g, 8, CFG)
    assert np.array_equal(single_result.assignment, want)


def test_dist_p1_matches_driver(g):
    from repro.dist.dist_partitioner import dist_partition_impl
    want = dist_partition_impl(g, 4, 1, cfg=CFG, use_grid=True)
    res = Partitioner().run(
        PartitionRequest(graph=g, k=4, config=CFG, backend="dist-grid",
                         devices=1))
    assert np.array_equal(res.assignment, want)
    assert res.feasible


def test_deprecated_shims_are_gone():
    """The PR 2 deprecation shims had one release of grace (docs/API.md)
    and must no longer exist — the facade is the only entrypoint."""
    from repro.core import partitioner as core_partitioner
    from repro.dist import dist_partitioner
    assert not hasattr(core_partitioner, "partition")
    assert not hasattr(dist_partitioner, "dist_partition")
    import repro.core
    assert not hasattr(repro.core, "partition")


def test_dist_p1_sharded_owner_memory_model(g):
    """The fully sharded memory model through the unchanged facade:
    feasible, and its coarsen trace records the sharded exchange."""
    res = Partitioner().run(
        PartitionRequest(graph=g, k=4, config=CFG, backend="dist",
                         devices=1, contraction="sharded",
                         weights="owner"))
    assert res.feasible
    coarsen = [t for t in res.trace if t["phase"] == "dist-coarsen"]
    assert coarsen and all(t["contraction"] == "sharded"
                           and "exchange_s" in t for t in coarsen)


# ---------------------------------------------------------------------------
# result contract
# ---------------------------------------------------------------------------

def test_feasible_flag_agrees_with_metrics(g, single_result):
    res = single_result
    assert res.feasible == metrics.is_feasible(g, res.assignment, 8, 0.03)
    assert res.feasible == res.metrics["feasible"]


def test_result_summary_and_trace(g, single_result):
    res = single_result
    s = res.summary()
    import json
    json.dumps(s)                       # JSON-serializable
    assert s["backend"] == "single" and s["n"] == g.n and s["m"] == g.m
    assert res.trace, "per-level trace must be populated"
    phases = [t["phase"] for t in res.trace]
    assert phases[0] == "coarsen" and phases[-1] == "final"
    assert all("time_s" in t for t in res.trace)
    # the final trace record's cut is the result's cut
    assert res.trace[-1]["cut"] == res.cut == metrics.edge_cut(
        g, res.assignment)


def test_convenience_partition_wrapper(g):
    res = api_partition(g, 4, config=CFG)
    assert res.backend == "single"
    assert res.assignment.shape == (g.n,)
    assert res.feasible


# ---------------------------------------------------------------------------
# batched sessions
# ---------------------------------------------------------------------------

def test_session_batch_equals_per_request():
    spec = GraphSpec("rgg2d", 1200, 8.0, seed=7)
    reqs = [PartitionRequest(graph=spec, k=k, config=CFG,
                             backend="single") for k in (2, 4, 8)]
    with PartitionSession(devices=1, max_workers=3) as sess:
        batch = sess.run_batch(reqs)
        stats = sess.stats()
        assert len(sess._graph_cache) == 1   # one spec -> one materialize
    solo = Partitioner().run_batch(reqs)
    for b, s in zip(batch, solo):
        assert np.array_equal(b.assignment, s.assignment)
        assert b.cut == s.cut
    assert stats["served"] == len(reqs)


def test_session_rejects_after_close():
    sess = PartitionSession(devices=1)
    sess.close()
    with pytest.raises(RuntimeError):
        sess.submit(PartitionRequest(graph=GraphSpec("rgg2d", 100), k=2))


def test_session_submit_close_race_raises_session_closed():
    """Hammer submit against close: every losing submit must raise the
    documented session-closed RuntimeError — never the raw executor
    shutdown error (the old race: closed-check outside the lock)."""
    import threading

    req = PartitionRequest(graph=GraphSpec("rgg2d", 120), k=2,
                           config=CFG, backend="single")
    for _ in range(10):
        sess = PartitionSession(devices=1, max_workers=2)
        errors, futs = [], []
        stop = threading.Event()

        def hammer():
            while not stop.is_set():
                try:
                    futs.append(sess.submit(req))
                except RuntimeError as e:
                    errors.append(str(e))
                    return

        t = threading.Thread(target=hammer)
        t.start()
        sess.close(wait=False)
        stop.set()
        t.join(timeout=30)
        assert all(e == "session is closed" for e in errors), errors
        for f in futs:
            if not f.cancelled():
                try:
                    f.result(timeout=60)
                except Exception:
                    pass


def test_run_batch_mid_loop_failure_cleans_up_futures():
    """A submit raise mid-batch must not leak already-submitted work:
    run_batch cancels/awaits the captured futures before re-raising."""
    sess = PartitionSession(devices=1, max_workers=2)
    captured = []
    orig_submit = sess.submit

    def flaky_submit(req):
        if captured:
            raise RuntimeError("injected submit failure")
        fut = orig_submit(req)
        captured.append(fut)
        return fut

    sess.submit = flaky_submit
    reqs = [PartitionRequest(graph=GraphSpec("rgg2d", 150, seed=i), k=2,
                             config=CFG, backend="single")
            for i in range(3)]
    try:
        with pytest.raises(RuntimeError, match="injected"):
            sess.run_batch(reqs)
        assert len(captured) == 1
        # the survivor was awaited (or cancelled) before the re-raise
        assert captured[0].done() or captured[0].cancelled()
    finally:
        sess.close()


# ---------------------------------------------------------------------------
# runtime helper
# ---------------------------------------------------------------------------

def test_force_host_devices_after_init():
    import jax
    jax.devices()                       # ensure the backend exists
    assert runtime.jax_backend_initialized()
    runtime.force_host_devices(0)       # no-op
    runtime.force_host_devices(1)       # enough devices -> no-op
    with pytest.raises(RuntimeError, match="already initialized"):
        runtime.force_host_devices(4096)
