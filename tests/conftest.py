"""Shared pytest config: registers the ``slow`` marker and gates the
multi-device subprocess tests behind ``--run-slow`` so the tier-1 run
(``pytest -x -q``) stays fast by default."""
import pytest


def pytest_addoption(parser):
    parser.addoption("--run-slow", action="store_true", default=False,
                     help="run tests marked slow (multi-device subprocess "
                          "selftests)")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running multi-device subprocess test "
                   "(opt in with --run-slow)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--run-slow"):
        return
    skip = pytest.mark.skip(reason="slow test: pass --run-slow to run")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
