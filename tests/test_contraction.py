"""Contraction invariants (paper §5), host and sharded.

Property-style checks over random graphs and clusterings: contraction
must preserve total vertex weight, produce no self loops, keep the arc
list symmetric, and — the load-bearing one for multilevel correctness —
the edge cut of any coarse partition must equal the cut of its
projection onto the fine graph.

The sharded path (``dist.dist_contraction``) runs here at P=1 in-process
(shard_map over a single forced host device); multi-device coverage
lives in test_distributed.py via the subprocess selftest
(``--test contract``).
"""
import numpy as np
import pytest

from repro.core import metrics
from repro.core.contraction import contract, dedup_arcs
from repro.graphs import generators
from repro.graphs.distribute import distribute_graph


def _random_labels(rng, n, style):
    if style == "coarse":          # ~n/8 clusters, contiguous-ish ids
        return rng.integers(0, max(1, n // 8), size=n)
    if style == "sparse_ids":      # arbitrary non-contiguous label values
        return rng.choice(10 * n, size=max(1, n // 5),
                          replace=False)[rng.integers(
                              0, max(1, n // 5), size=n)]
    return np.arange(n)            # identity: every vertex a singleton


CASES = [("rgg2d", 800, "coarse"), ("rhg", 600, "coarse"),
         ("ba", 500, "sparse_ids"), ("rgg2d", 300, "identity")]


@pytest.mark.parametrize("family,n,style", CASES)
def test_contract_invariants(family, n, style):
    g = generators.make(family, n, 8.0, seed=13)
    rng = np.random.default_rng(7)
    labels = _random_labels(rng, g.n, style)
    gc, mapping = contract(g, labels)
    # mapping is a dense relabeling of the clustering
    assert mapping.shape == (g.n,)
    assert np.array_equal(np.unique(mapping), np.arange(gc.n))
    # total vertex weight preserved
    assert gc.total_vweight == g.total_vweight
    # no self loops; symmetric arc list with positive weights
    src = gc.arc_tails()
    assert np.all(src != gc.adjncy)
    gc.validate()
    # cut of any coarse partition == cut of its fine projection
    for k in (2, 5):
        pc = rng.integers(0, k, size=gc.n)
        assert metrics.edge_cut(gc, pc) == metrics.edge_cut(g, pc[mapping])


def test_contract_merges_parallel_arcs():
    """Two fine edges between the same cluster pair become one coarse
    edge carrying the summed weight."""
    from repro.graphs.format import from_coo
    g = from_coo(4, np.array([0, 1, 0]), np.array([2, 3, 3]),
                 eweights=np.array([5, 7, 11]))
    gc, mapping = contract(g, np.array([0, 0, 1, 1]))
    assert gc.n == 2 and gc.m == 2          # one undirected coarse edge
    assert int(gc.eweights.sum()) == 2 * (5 + 7 + 11)
    assert metrics.edge_cut(gc, np.array([0, 1])) == 23


def test_dedup_arcs_kernel():
    s, d, w = dedup_arcs(np.array([1, 0, 1, 1]), np.array([0, 1, 0, 1]),
                         np.array([3, 4, 5, 9]))
    # self loop (1,1) dropped, parallel (1,0) merged, sorted by (src,dst)
    assert s.tolist() == [0, 1] and d.tolist() == [1, 0]
    assert w.tolist() == [4, 8]
    s, d, w = dedup_arcs(np.array([2]), np.array([2]), np.array([1]))
    assert s.size == d.size == w.size == 0


def test_dist_contract_matches_host_p1():
    """P=1 in-process: the sharded pipeline (ownership, renumbering,
    exchange, owner-side merge) must agree with the host kernel up to a
    coarse-id bijection, and its coarse shards must round-trip."""
    jax = pytest.importorskip("jax")
    if len(jax.devices()) < 1:      # pragma: no cover
        pytest.skip("no devices")
    from repro.dist.dist_contraction import dist_contract
    g = generators.make("rgg2d", 600, 8.0, seed=19)
    rng = np.random.default_rng(23)
    labels = rng.integers(0, 120, size=g.n)
    res = dist_contract(distribute_graph(g, 1), labels)
    gc_h, map_h = contract(g, labels)
    assert res.graph.n == gc_h.n and res.graph.m == gc_h.m
    assert res.graph.total_vweight == g.total_vweight
    pairs = np.unique(np.stack([map_h, res.mapping], 1), axis=0)
    assert pairs.shape[0] == gc_h.n
    assert np.unique(pairs[:, 0]).size == gc_h.n
    assert np.unique(pairs[:, 1]).size == gc_h.n
    pc = rng.integers(0, 4, size=res.graph.n)
    assert metrics.edge_cut(res.graph, pc) == \
        metrics.edge_cut(g, pc[res.mapping])
    # coarse shards carry the same graph the host view shows
    valid = res.shards.local_gid < res.graph.n
    assert int(res.shards.vweights[valid].sum()) == g.total_vweight
    assert int((res.shards.arc_src < res.shards.n_loc).sum()) == res.graph.m
