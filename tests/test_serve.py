"""repro.serve serving tier: scheduler policy, admission queue,
bit-identity under concurrency, and the failure paths (worker
exception, deadline expiry, kill/retry, admission overload).

Multi-device mesh coverage lives in test_distributed.py (subprocess
selftest ``--test serve``); here workers are meshless single-device
sessions, which exercises every queue/scheduler/supervision path.
"""
import threading
import time
from concurrent.futures import Future
from types import SimpleNamespace

import numpy as np
import pytest

from repro.api import (GraphSpec, PartitionRequest, Partitioner,
                       register_backend)
from repro.api.backends import required_devices
from repro.core import PartitionerConfig
from repro.serve import (AdmissionQueue, PartitionServer, ServeMetrics,
                         Ticket, pick_worker)
from repro.serve.metrics import percentile

CFG = PartitionerConfig(contraction_limit=128, ip_repetitions=2,
                        num_chunks=4)


def mixed_requests(count=8, base_n=700):
    return [PartitionRequest(
        graph=GraphSpec("rgg2d", base_n * (1 + i % 3), 8.0,
                        seed=5 + i % 2),
        k=2 * (1 + i % 2), config=CFG, backend="single")
        for i in range(count)]


# ---------------------------------------------------------------------------
# scheduler policy (pure)
# ---------------------------------------------------------------------------

def W(wid, devices, inflight=0):
    return SimpleNamespace(wid=wid, devices=devices, inflight=inflight)


def test_scheduler_prefers_exact_mesh_match():
    ws = [W(0, 8), W(1, 2), W(2, 4)]
    assert pick_worker(2, ws).wid == 1
    assert pick_worker(4, ws).wid == 2
    assert pick_worker(8, ws).wid == 0


def test_scheduler_smallest_fitting_then_fallback():
    ws = [W(0, 8), W(1, 4)]
    # no exact 2-PE mesh: smallest mesh that fits wins
    assert pick_worker(2, ws).wid == 1
    # nothing fits a 16-PE ask: any mesh still serves it (undersized
    # meshes run the request without the shared mesh)
    assert pick_worker(16, ws).wid == 0


def test_scheduler_load_and_id_tiebreaks():
    assert pick_worker(1, [W(0, 1, inflight=1), W(1, 1)]).wid == 1
    assert pick_worker(1, [W(0, 1), W(1, 1)]).wid == 0
    assert pick_worker(1, []) is None


def test_required_devices_follows_auto_policy():
    spec = GraphSpec("rgg2d", 50000)
    assert required_devices(
        PartitionRequest(graph=spec, k=4), 50000) == 1
    assert required_devices(
        PartitionRequest(graph=spec, k=4, devices=4), 50000) == 4
    # too small to shard -> the dist backends are never resolved
    assert required_devices(
        PartitionRequest(graph=spec, k=4, devices=4), 100) == 1
    assert required_devices(
        PartitionRequest(graph=spec, k=4, backend="single", devices=4),
        50000) == 1


# ---------------------------------------------------------------------------
# admission queue (pure)
# ---------------------------------------------------------------------------

def make_ticket(priority, seq, deadline=None):
    return Ticket(request=None, priority=priority, seq=seq,
                  future=Future(), submit_t=time.monotonic(),
                  deadline=deadline)


def test_queue_priority_then_fifo_order():
    q = AdmissionQueue(capacity=8)
    for prio, seq in [(1, 0), (0, 1), (1, 2), (0, 3)]:
        assert q.put(make_ticket(prio, seq))
    got = [q.pop() for _ in range(4)]
    assert [(t.priority, t.seq) for t in got] == \
        [(0, 1), (0, 3), (1, 0), (1, 2)]


def test_queue_requeue_goes_to_front_of_its_class():
    q = AdmissionQueue(capacity=8)
    first = make_ticket(0, 0)
    q.put(first)
    q.put(make_ticket(0, 1))
    t = q.pop()
    assert t is first
    assert q.requeue(t)           # keeps seq 0 -> ahead of seq 1
    assert q.pop() is first


def test_queue_capacity_and_close():
    q = AdmissionQueue(capacity=2)
    assert q.put(make_ticket(0, 0))
    assert q.put(make_ticket(0, 1))
    assert not q.put(make_ticket(0, 2))      # full
    assert q.requeue(make_ticket(0, 3))      # retries bypass the bound
    q.close()
    assert not q.put(make_ticket(0, 4))
    assert len(q.drain()) == 3
    assert q.depth() == 0


def test_queue_pop_survives_spurious_wakeup():
    """A notify with nothing to pop (spurious wakeup / a competing
    consumer winning the race) must put the waiter back to sleep for
    the remaining time — not return None with time still on the
    clock (the lost-wakeup bug)."""
    q = AdmissionQueue(capacity=8)
    got = []
    t = threading.Thread(target=lambda: got.append(q.pop(timeout=5.0)))
    t.start()
    time.sleep(0.05)                    # waiter is parked in wait()
    with q._cond:
        q._cond.notify_all()            # wake with an empty heap
    time.sleep(0.05)
    assert not got, "waiter returned early on a spurious wakeup"
    ticket = make_ticket(0, 0)
    assert q.put(ticket)
    t.join(timeout=5)
    assert got == [ticket]


def test_queue_two_consumers_no_starvation():
    """Two consumers, items trickling in: every item is delivered and
    neither popper gives up early because the other stole its notify."""
    q = AdmissionQueue(capacity=64)
    got, lock = [], threading.Lock()

    def consume():
        while True:
            t = q.pop(timeout=10.0)
            if t is None:
                return
            with lock:
                got.append(t.seq)

    threads = [threading.Thread(target=consume) for _ in range(2)]
    for t in threads:
        t.start()
    for seq in range(20):
        q.put(make_ticket(0, seq))
        time.sleep(0.002)
    deadline = time.monotonic() + 10
    while time.monotonic() < deadline:
        with lock:
            if len(got) == 20:
                break
        time.sleep(0.01)
    q.close()
    for t in threads:
        t.join(timeout=10)
    assert sorted(got) == list(range(20))


def test_queue_pop_closed_and_drained_returns_none():
    q = AdmissionQueue(capacity=8)
    ticket = make_ticket(0, 0)
    q.put(ticket)
    q.close()
    # closed but not drained: queued work still comes out
    assert q.pop(timeout=5.0) is ticket
    # closed and drained: immediate None, even with a long timeout
    t0 = time.monotonic()
    assert q.pop(timeout=30.0) is None
    assert time.monotonic() - t0 < 1.0


def test_pop_matching_order_equivalent_to_reference():
    """The single-scan ``pop_matching`` must drain in exactly the order
    a sort-the-whole-heap reference implementation would."""
    import random

    def reference_order(tickets, pred):
        rest = list(tickets)
        out = []
        while True:
            cands = sorted((t.priority, t.seq) for t in rest if pred(t))
            if not cands:
                return out
            prio, seq = cands[0]
            pick = next(t for t in rest
                        if (t.priority, t.seq) == (prio, seq))
            rest.remove(pick)
            out.append((prio, seq))

    rng = random.Random(42)
    for trial in range(20):
        tickets = [make_ticket(rng.randrange(4), seq)
                   for seq in range(rng.randrange(1, 40))]
        pred = (lambda t: True) if trial % 2 else \
            (lambda t: t.seq % 3 != 0)
        q = AdmissionQueue(capacity=64)
        order = list(range(len(tickets)))
        rng.shuffle(order)
        for i in order:
            q.put(tickets[i])
        got = []
        while True:
            t = q.pop_matching(pred)
            if t is None:
                break
            got.append((t.priority, t.seq))
        assert got == reference_order(tickets, pred)
        # non-matching tickets stay queued, heap invariant intact
        leftovers = [(t.priority, t.seq) for t in iter(
            lambda: q.pop_matching(lambda _: True), None)]
        assert leftovers == reference_order(
            [t for t in tickets if not pred(t)], lambda _: True)


def test_queue_pop_batch_collects_up_to_limit():
    q = AdmissionQueue(capacity=16)
    for seq in range(5):
        q.put(make_ticket(seq % 2, seq))
    batch = q.pop_batch(lambda t: t.priority == 0, limit=2)
    assert [(t.priority, t.seq) for t in batch] == [(0, 0), (0, 2)]
    # window=0 with nothing matching left beyond limit: immediate
    batch2 = q.pop_batch(lambda t: t.priority == 0, limit=5)
    assert [(t.priority, t.seq) for t in batch2] == [(0, 4)]
    assert q.depth() == 2               # priority-1 tickets untouched
    # a lingering pop_batch picks up late matching admissions
    late = make_ticket(0, 9)
    threading.Timer(0.05, lambda: q.put(late)).start()
    batch3 = q.pop_batch(lambda t: t.priority == 0, limit=1,
                         window_s=5.0)
    assert batch3 == [late]


def test_ticket_deadline():
    now = time.monotonic()
    t = make_ticket(0, 0, deadline=now - 1)
    assert t.expired()
    t2 = make_ticket(0, 0, deadline=now + 60)
    assert not t2.expired()
    assert 0 < t2.remaining() <= 60
    assert make_ticket(0, 0).remaining() is None


def test_metrics_percentile_and_snapshot():
    assert percentile([], 50) == 0.0
    xs = sorted(float(i) for i in range(1, 101))
    assert percentile(xs, 50) == pytest.approx(50.0, abs=1)
    assert percentile(xs, 99) == pytest.approx(99.0, abs=1)
    m = ServeMetrics(2)
    m.on_submit(3)
    m.on_done(True, 0.5, 0.1, worker=1)
    m.on_batch(4, 2)
    snap = m.snapshot()
    assert snap["submitted"] == 1 and snap["completed"] == 1
    assert snap["per_worker_served"] == [0, 1]
    assert snap["queue_depth_max"] == 3
    assert snap["batches"] == 1 and snap["coalesced"] == 2
    assert snap["batch_size_max"] == 4


def test_percentile_nearest_rank_exact():
    """Nearest-rank definition: value at 1-indexed rank ceil(p/100*n).
    The old banker's-rounding implementation read one element low for
    e.g. p50 of n=2 and p99 of n=100."""
    assert percentile([7.0], 50) == 7.0
    assert percentile([7.0], 99) == 7.0
    assert percentile([1.0, 2.0], 50) == 1.0
    assert percentile([1.0, 2.0], 75) == 2.0
    assert percentile([1.0, 2.0, 3.0], 50) == 2.0
    assert percentile([1.0, 2.0, 3.0], 100) == 3.0
    xs100 = [float(i) for i in range(1, 101)]
    assert percentile(xs100, 50) == 50.0
    assert percentile(xs100, 99) == 99.0
    assert percentile(xs100, 100) == 100.0
    xs101 = [float(i) for i in range(1, 102)]
    assert percentile(xs101, 50) == 51.0
    assert percentile(xs101, 99) == 100.0
    assert percentile(xs101, 0) == 1.0


# ---------------------------------------------------------------------------
# server: bit-identity under concurrency
# ---------------------------------------------------------------------------

def test_concurrent_mixed_batch_bit_identical_to_solo():
    reqs = mixed_requests(8)
    with PartitionServer(meshes=2) as srv:
        results = srv.serve(reqs)
        stats = srv.stats()
    solo = Partitioner().run_batch(reqs)
    for r, s in zip(results, solo):
        assert r.ok and r.error is None
        assert np.array_equal(r.result.assignment, s.assignment)
        assert r.result.cut == s.cut
    assert stats["completed"] == len(reqs)
    assert sum(stats["per_worker_served"]) == len(reqs)
    assert all(c > 0 for c in stats["per_worker_served"])


def test_graph_cache_shared_across_workers():
    spec = GraphSpec("rgg2d", 900, 8.0, seed=9)
    reqs = [PartitionRequest(graph=spec, k=k, config=CFG,
                             backend="single") for k in (2, 3, 4, 5)]
    with PartitionServer(meshes=2) as srv:
        results = srv.serve(reqs)
        assert len(srv._graph_cache) == 1   # one spec -> one materialize
    assert all(r.ok for r in results)


def test_batched_dispatch_bit_identical_and_coalesces():
    """A duplicate-heavy hot mix must batch (same shape bucket), share
    runs for identical requests, and still return results bit-identical
    to solo ``Partitioner.run`` per request."""
    distinct = [PartitionRequest(
        graph=GraphSpec("rgg2d", 600, 8.0, seed=s), k=4, config=CFG,
        backend="single") for s in (1, 2, 3)]
    reqs = [distinct[i % 3] for i in range(12)]
    solo = Partitioner().run_batch(distinct)
    with PartitionServer(meshes=1, batch_max=8,
                         batch_window_ms=50.0) as srv:
        # hold the worker so the burst piles up in one bucket, then
        # release: the dispatcher collects them as batches
        srv.workers[0].hold()
        futs = [srv.submit(r) for r in reqs]
        deadline = time.monotonic() + 30
        while time.monotonic() < deadline and \
                srv.workers[0].inflight == 0:
            time.sleep(0.01)
        srv.workers[0].release()
        results = [f.result(timeout=300) for f in futs]
        stats = srv.stats()
    for i, r in enumerate(results):
        assert r.ok, r.error
        s = solo[i % 3]
        assert np.array_equal(r.result.assignment, s.assignment)
        assert r.result.cut == s.cut
    assert stats["completed"] == len(reqs)
    assert stats["batches"] >= 1, "burst never dispatched as a batch"
    assert stats["coalesced"] >= 1
    assert stats["batch_size_max"] >= 2


def test_batching_disabled_keeps_solo_dispatch():
    reqs = mixed_requests(4, base_n=400)
    with PartitionServer(meshes=1, batch_max=1) as srv:
        results = srv.serve(reqs)
        stats = srv.stats()
    assert all(r.ok for r in results)
    assert stats["batches"] == 0


@pytest.mark.parametrize("kw", [
    dict(batch_max=0), dict(batch_window_ms=-1.0),
])
def test_server_rejects_bad_batch_knobs(kw):
    with pytest.raises(ValueError):
        PartitionServer(**kw)


# ---------------------------------------------------------------------------
# server: failure paths
# ---------------------------------------------------------------------------

def test_worker_exception_retries_then_structured_error():
    calls = []

    @register_backend("serve-test-boom")
    def _boom(g, req, ctx):
        calls.append(1)
        raise RuntimeError("kaboom")

    try:
        good = mixed_requests(1)[0]
        bad = PartitionRequest(graph=GraphSpec("rgg2d", 400), k=2,
                               backend="serve-test-boom")
        with PartitionServer(meshes=2) as srv:
            res = srv.serve([bad])[0]
            assert not res.ok
            assert res.error == "worker_failed"
            assert res.attempts == 2          # original + one retry
            assert "kaboom" in res.detail
            # both meshes were tried
            assert len(calls) == 2
            stats = srv.stats()
            assert stats["retried"] == 1 and stats["failed"] == 1
            # the queue is not deadlocked: a good request still serves
            after = srv.serve([good])[0]
            assert after.ok
    finally:
        from repro.api import backends as _b
        _b._REGISTRY.pop("serve-test-boom")


def test_deadline_expiry_returns_structured_error():
    reqs = mixed_requests(1)
    with PartitionServer(meshes=2) as srv:
        for w in srv.workers:
            w.hold()
        fut = srv.submit(reqs[0], deadline_s=0.02)
        time.sleep(0.15)
        for w in srv.workers:
            w.release()
        res = fut.result(timeout=60)
        assert not res.ok and res.error == "deadline_exceeded"
        assert res.result is None
        assert srv.stats()["expired"] == 1
        # server still serves after the expiry
        assert srv.serve(reqs)[0].ok


def test_killed_worker_request_completes_on_other_mesh():
    reqs = mixed_requests(4)
    solo = Partitioner().run_batch(reqs)
    with PartitionServer(meshes=2) as srv:
        srv.workers[1].hold()
        futs = [srv.submit(r) for r in reqs]
        deadline = time.monotonic() + 30
        while time.monotonic() < deadline and \
                srv.workers[1].inflight == 0:
            time.sleep(0.01)
        assert srv.workers[1].inflight > 0
        srv.kill_worker(1)
        results = [f.result(timeout=120) for f in futs]
        stats = srv.stats()
    for r, s in zip(results, solo):
        assert r.ok
        assert np.array_equal(r.result.assignment, s.assignment)
    assert stats["retried"] >= 1
    assert stats["per_worker_served"][1] == 0


def test_all_workers_dead_resolves_no_worker():
    with PartitionServer(meshes=2) as srv:
        srv.kill_worker(0)
        srv.kill_worker(1)
        res = srv.serve(mixed_requests(1))[0]
        assert not res.ok and res.error == "no_worker"


def test_admission_overload_rejects_structurally():
    reqs = mixed_requests(6, base_n=400)
    with PartitionServer(meshes=1, max_queue=2) as srv:
        srv.workers[0].hold()
        futs = [srv.submit(r) for r in reqs]
        rejected = [f.result(timeout=5) for f in futs
                    if f.done() and not f.result().ok]
        assert rejected, "queue of 2 must reject part of a burst of 6"
        assert all(r.error == "rejected" for r in rejected)
        srv.workers[0].release()
        kept = [f.result(timeout=120) for f in futs]
        assert sum(1 for r in kept if r.ok) >= 2
        assert srv.stats()["rejected"] == len(rejected)


def test_priorities_dispatch_before_later_arrivals():
    done = []
    lock = threading.Lock()

    def track(tag):
        def _cb(fut):
            with lock:
                done.append(tag)
        return _cb

    reqs = mixed_requests(5, base_n=400)
    with PartitionServer(meshes=1) as srv:
        srv.workers[0].hold()
        # fill the worker's one slot with an untracked request so every
        # tracked submission below provably stays in the priority queue
        filler = srv.submit(reqs[0])
        deadline = time.monotonic() + 30
        while time.monotonic() < deadline and \
                srv.workers[0].inflight == 0:
            time.sleep(0.01)
        assert srv.workers[0].inflight == 1
        labels = [3, 1, 2, 0]
        futs = []
        for r, prio in zip(reqs[1:], labels):
            f = srv.submit(r, priority=prio)
            f.add_done_callback(track(prio))
            futs.append(f)
        srv.workers[0].release()
        filler.result(timeout=120)
        for f in futs:
            f.result(timeout=120)
    assert done == sorted(labels)


def test_deadline_mid_attempt_keeps_worker_alive():
    """A deadline expiring during an attempt means the *request* ran
    out of time — the worker is slow, not wedged, and must stay in
    rotation (only a timeout_s overrun marks it dead)."""
    release = threading.Event()

    @register_backend("serve-test-slow")
    def _slow(g, req, ctx):
        release.wait(30)
        return np.zeros(g.n, dtype=np.int64)

    try:
        slow = PartitionRequest(graph=GraphSpec("rgg2d", 300), k=2,
                                backend="serve-test-slow")
        with PartitionServer(meshes=1) as srv:
            res = srv.serve([slow], deadline_s=0.2)[0]
            assert not res.ok and res.error == "deadline_exceeded"
            assert srv.workers[0].alive
            # while the abandoned attempt still occupies the executor,
            # a timeout-bounded request fails over (no other mesh ->
            # structured error) but must NOT wedge the worker: the
            # backlog is the abandoned job's, not the new attempt's
            busy = srv.serve(mixed_requests(1, base_n=400),
                             timeout_s=0.3)[0]
            assert not busy.ok and busy.error == "worker_failed"
            assert "draining" in busy.detail
            assert srv.workers[0].alive
            release.set()               # let the abandoned attempt end
            good = srv.serve(mixed_requests(1, base_n=400))[0]
            assert good.ok
    finally:
        release.set()
        from repro.api import backends as _b
        _b._REGISTRY.pop("serve-test-slow")


def test_retried_ticket_does_not_block_queue():
    """A requeued ticket whose only eligible mesh is busy must not
    head-of-line block requests an idle mesh could serve."""

    @register_backend("serve-test-boom2")
    def _boom(g, req, ctx):
        raise RuntimeError("kaboom")

    try:
        bad = PartitionRequest(graph=GraphSpec("rgg2d", 300), k=2,
                               backend="serve-test-boom2")
        reqs = mixed_requests(2, base_n=400)
        with PartitionServer(meshes=2) as srv:
            for w in srv.workers:
                w.hold()
            f_bad = srv.submit(bad)          # -> worker 0 (tie: lowest id)
            f_g1 = srv.submit(reqs[0])       # -> worker 1
            deadline = time.monotonic() + 30
            while time.monotonic() < deadline and (
                    srv.workers[0].inflight == 0
                    or srv.workers[1].inflight == 0):
                time.sleep(0.01)
            f_g2 = srv.submit(reqs[1])       # queued behind both
            # release worker 0 only: the bad request fails there, gets
            # requeued with excluded={0}, and its only eligible mesh
            # (worker 1) stays held — g2 must still run on worker 0
            srv.workers[0].release()
            res_g2 = f_g2.result(timeout=120)
            assert res_g2.ok
            assert srv.workers[1].inflight == 1   # still held
            srv.workers[1].release()
            assert f_g1.result(timeout=120).ok
            res_bad = f_bad.result(timeout=120)
            assert not res_bad.ok
            assert res_bad.error == "worker_failed"
    finally:
        from repro.api import backends as _b
        _b._REGISTRY.pop("serve-test-boom2")


def test_submit_after_close_raises():
    srv = PartitionServer(meshes=1)
    srv.close()
    with pytest.raises(RuntimeError):
        srv.submit(mixed_requests(1)[0])


def test_close_resolves_queued_tickets():
    srv = PartitionServer(meshes=1)
    srv.workers[0].hold()
    futs = [srv.submit(r) for r in mixed_requests(3, base_n=400)]
    srv.close(wait=False)
    srv.workers[0].release()
    results = [f.result(timeout=60) for f in futs]
    assert all(r.ok or r.error == "server_closed" for r in results)
    assert any(r.error == "server_closed" for r in results)


# ---------------------------------------------------------------------------
# construction validation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [
    dict(meshes=0), dict(devices_per_mesh=0), dict(max_retries=-1),
    dict(max_inflight_per_worker=0),
])
def test_server_rejects_bad_construction(kw):
    with pytest.raises(ValueError):
        PartitionServer(**kw)


def test_session_rejects_mismatched_mesh():
    from repro.api import PartitionSession

    class FakeMesh:
        axis_names = ("x",)
        devices = np.zeros(2)

    with pytest.raises(ValueError):
        PartitionSession(devices=2, mesh=FakeMesh())
