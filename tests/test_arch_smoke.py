"""Per-architecture smoke tests: instantiate the REDUCED config of each
assigned arch and run one forward/train step on CPU, asserting output
shapes and finiteness. The FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import load_all
from repro.models import dlrm as DL
from repro.models import transformer as T
from repro.models.common import init_params, param_count
from repro.models.gnn import dimenet as DN
from repro.models.gnn import gat as GT
from repro.models.gnn import nequip as NQ
from repro.models.gnn import schnet as SN
from repro.models.gnn.common import GraphBatch
from repro.train.optimizer import OptConfig
from repro.train.trainer import make_train_step

REGISTRY = load_all()
LM_ARCHS = [a for a, e in REGISTRY.items() if e.kind == "lm"]
GNN_ARCHS = [a for a, e in REGISTRY.items() if e.kind == "gnn"]


def _mol_batch(rng, n=24, e=64, n_graphs=2, want_trip=False, n_species=10):
    snd = rng.integers(0, n, e)
    rcv = rng.integers(0, n, e)
    keep = snd != rcv
    snd, rcv = snd[keep], rcv[keep]
    snd, rcv = np.concatenate([snd, rcv]), np.concatenate([rcv, snd])
    E = snd.shape[0]
    pos = rng.standard_normal((n + 1, 3)).astype(np.float32) * 1.5
    gid = (np.arange(n + 1) * n_graphs // (n + 1)).astype(np.int32)
    kw = {}
    if want_trip:
        from repro.models.gnn.dimenet import build_triplets
        kj, ji = build_triplets(snd.astype(np.int32), rcv.astype(np.int32),
                                n + 1, cap=4 * E)
        kw = dict(trip_kj=jnp.asarray(kj), trip_ji=jnp.asarray(ji))
    return GraphBatch(
        senders=jnp.asarray(snd.astype(np.int32)),
        receivers=jnp.asarray(rcv.astype(np.int32)), n_node=n + 1,
        species=jnp.asarray(rng.integers(0, n_species, n + 1)),
        positions=jnp.asarray(pos), graph_id=jnp.asarray(gid),
        n_graphs=n_graphs,
        labels=jnp.asarray(rng.standard_normal(n_graphs).astype(np.float32)),
        node_mask=jnp.asarray(np.arange(n + 1) < n), **kw)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke(arch):
    entry = REGISTRY[arch]
    cfg: T.TransformerConfig = entry.smoke_config
    params = init_params(T.build_specs(cfg), jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
    logits, aux = T.forward(params, toks, cfg)
    assert logits.shape == (2, 16, cfg.vocab_pad)
    assert bool(jnp.isfinite(logits[..., :cfg.vocab]).all())
    # one train step
    init_state, step = make_train_step(
        lambda p, b: T.loss_fn(p, b, cfg), OptConfig(lr=1e-3))
    state = init_state(params)
    state, metrics = jax.jit(step)(state, {"tokens": toks})
    assert bool(metrics["finite"])
    assert float(metrics["loss"]) > 0
    # one decode step agrees in shape
    cache = jax.tree_util.tree_map(
        jnp.zeros_like, init_params(T.cache_specs(cfg, 2, 8),
                                    jax.random.key(2)))
    lg, cache2 = T.decode_step(params, cache, toks[:, 0],
                               jnp.zeros(2, jnp.int32), cfg)
    assert lg.shape == (2, cfg.vocab_pad)
    assert bool(jnp.isfinite(lg[:, :cfg.vocab]).all())
    assert cache2["k"].shape == cache["k"].shape


def test_lm_decode_matches_prefill():
    """Step-by-step decode logits == teacher-forced forward logits."""
    cfg = dataclasses.replace(REGISTRY["qwen2-7b"].smoke_config,
                              compute_dtype=jnp.float32, remat=False)
    params = init_params(T.build_specs(cfg), jax.random.key(0))
    Btoks = jax.random.randint(jax.random.key(1), (2, 7), 0, cfg.vocab)
    full_logits, _ = T.forward(params, Btoks, cfg)
    cache = jax.tree_util.tree_map(
        jnp.zeros_like, init_params(T.cache_specs(cfg, 2, 8),
                                    jax.random.key(2)))
    for t in range(Btoks.shape[1]):
        lg, cache = T.decode_step(params, cache, Btoks[:, t],
                                  jnp.full((2,), t, jnp.int32), cfg)
        np.testing.assert_allclose(
            np.asarray(lg[:, :cfg.vocab]),
            np.asarray(full_logits[:, t, :cfg.vocab]),
            rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke(arch):
    entry = REGISTRY[arch]
    cfg = entry.smoke_config
    rng = np.random.default_rng(3)
    if arch == "gat-cora":
        mod = GT
        n, e = 60, 200
        snd = rng.integers(0, n, e).astype(np.int32)
        rcv = rng.integers(0, n, e).astype(np.int32)
        batch = GraphBatch(
            senders=jnp.asarray(snd), receivers=jnp.asarray(rcv),
            n_node=n + 1,
            node_feat=jnp.asarray(
                rng.standard_normal((n + 1, cfg.d_in)).astype(np.float32)),
            labels=jnp.asarray(rng.integers(0, cfg.n_classes, n + 1)),
            node_mask=jnp.asarray(np.arange(n + 1) < n))
        out = mod.forward(init_params(mod.build_specs(cfg),
                                      jax.random.key(0)), batch, cfg)
        assert out.shape == (n + 1, cfg.n_classes)
        assert bool(jnp.isfinite(out).all())
    else:
        mod = {"schnet": SN, "nequip": NQ, "dimenet": DN}[arch]
        batch = _mol_batch(rng, want_trip=(arch == "dimenet"))
        params = init_params(mod.build_specs(cfg), jax.random.key(0))
        out = mod.forward(params, batch, cfg)
        assert out.shape == (batch.n_graphs,)
        assert bool(jnp.isfinite(out).all())
    # one train step
    params = init_params(mod.build_specs(cfg), jax.random.key(0))
    init_state, step = make_train_step(
        lambda p, b: mod.loss_fn(p, b, cfg), OptConfig(lr=1e-3))
    state = init_state(params)
    state, metrics = step(state, batch)
    assert bool(metrics["finite"]), metrics


def test_nequip_equivariance():
    """Energy invariant under global rotation — validates every Cartesian
    CG path (DESIGN.md §8)."""
    from scipy.spatial.transform import Rotation
    cfg = REGISTRY["nequip"].smoke_config
    rng = np.random.default_rng(5)
    b1 = _mol_batch(rng)
    params = init_params(NQ.build_specs(cfg), jax.random.key(1))
    e1 = NQ.forward(params, b1, cfg)
    R = Rotation.random(random_state=7).as_matrix().astype(np.float32)
    b2 = dataclasses.replace(b1, positions=b1.positions @ R.T)
    e2 = NQ.forward(params, b2, cfg)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2),
                               rtol=1e-4, atol=1e-4)


def test_dimenet_triplets():
    """Triplet lists: every (kj, ji) pair shares j and k != i."""
    from repro.models.gnn.dimenet import build_triplets
    rng = np.random.default_rng(9)
    snd = rng.integers(0, 10, 40).astype(np.int32)
    rcv = rng.integers(0, 10, 40).astype(np.int32)
    keep = snd != rcv
    snd, rcv = snd[keep], rcv[keep]
    E = snd.shape[0]
    kj, ji = build_triplets(snd, rcv, 11, cap=E * 20)
    real = kj < E
    assert np.all(rcv[kj[real]] == snd[ji[real]])   # share middle vertex
    assert np.all(snd[kj[real]] != rcv[ji[real]])   # k != i


def test_dlrm_smoke():
    entry = REGISTRY["dlrm-rm2"]
    cfg: DL.DLRMConfig = entry.smoke_config
    params = init_params(DL.build_specs(cfg), jax.random.key(0))
    rng = np.random.default_rng(4)
    B = 32
    batch = {
        "dense": jnp.asarray(rng.standard_normal(
            (B, cfg.n_dense)).astype(np.float32)),
        "sparse": jnp.asarray(rng.integers(
            0, cfg.vocab_per_table, (B, cfg.n_sparse, cfg.bag_size)
        ).astype(np.int32)),
        "labels": jnp.asarray((rng.random(B) < 0.3).astype(np.float32)),
    }
    logits = DL.forward(params, batch, cfg)
    assert logits.shape == (B,)
    assert bool(jnp.isfinite(logits).all())
    init_state, step = make_train_step(
        lambda p, b: DL.loss_fn(p, b, cfg), OptConfig(lr=1e-3))
    state, metrics = step(init_state(params), batch)
    assert bool(metrics["finite"])
    # retrieval path
    cand = jnp.asarray(rng.standard_normal(
        (1000, cfg.embed_dim)).astype(np.float32))
    vals, idx = DL.retrieval_score(
        params, {"dense": batch["dense"][:1], "sparse": batch["sparse"][:1],
                 "candidates": cand}, cfg, top_k=10)
    assert vals.shape == (10,) and idx.shape == (10,)
    assert bool((vals[:-1] >= vals[1:]).all())


def test_all_archs_registered():
    assert len(REGISTRY) == 10
    kinds = {e.kind for e in REGISTRY.values()}
    assert kinds == {"lm", "gnn", "recsys"}
    # every entry exposes exactly 4 shapes (40 cells total)
    assert sum(len(e.shapes) for e in REGISTRY.values()) == 40


def test_param_counts_match_assignment():
    """Full configs match the assigned scale (coarse bands)."""
    from repro.models.transformer import build_specs
    counts = {a: param_count(build_specs(REGISTRY[a].config))
              for a in LM_ARCHS}
    assert 4.0e11 < counts["arctic-480b"] < 5.5e11, counts["arctic-480b"]
    assert 0.8e9 < counts["granite-moe-1b-a400m"] < 1.6e9
    assert 2.0e9 < counts["gemma-2b"] < 3.3e9
    assert 1.0e10 < counts["stablelm-12b"] < 1.45e10
    assert 6.0e9 < counts["qwen2-7b"] < 8.5e9
