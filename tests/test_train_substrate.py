"""Training substrate: optimizers, checkpointing, fault tolerance,
gradient accumulation, data determinism."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as T
from repro.models.common import init_params
from repro.train import checkpoint, data
from repro.train.optimizer import OptConfig, clip_by_global_norm
from repro.train.trainer import TrainLoopConfig, make_train_step, run_loop

CFG = T.TransformerConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          head_dim=16, d_ff=128, vocab=256, remat=False)


def _params():
    return init_params(T.build_specs(CFG), jax.random.key(0))


def _mk(step):
    return {k: jnp.asarray(v) for k, v in
            data.lm_batch(step, 4, 32, 256).items()}


def test_loss_decreases_adamw():
    init_state, step = make_train_step(
        lambda p, b: T.loss_fn(p, b, CFG), OptConfig(lr=1e-3))
    state, hist = run_loop(init_state, step, _mk, _params(),
                           TrainLoopConfig(steps=25, log_every=5))
    assert hist["loss"][-1][1] < hist["loss"][0][1]


def test_loss_decreases_adafactor():
    init_state, step = make_train_step(
        lambda p, b: T.loss_fn(p, b, CFG),
        OptConfig(name="adafactor", lr=1e-2))
    state, hist = run_loop(init_state, step, _mk, _params(),
                           TrainLoopConfig(steps=25, log_every=5))
    assert hist["loss"][-1][1] < hist["loss"][0][1]


def test_grad_accumulation_matches_full_batch():
    toks = jax.random.randint(jax.random.key(1), (8, 16), 0, 256)
    cfg32 = T.TransformerConfig(**{**CFG.__dict__,
                                   "compute_dtype": jnp.float32})
    i1, s1 = make_train_step(lambda p, b: T.loss_fn(p, b, cfg32),
                             OptConfig(lr=1e-3), microbatches=1)
    i4, s4 = make_train_step(lambda p, b: T.loss_fn(p, b, cfg32),
                             OptConfig(lr=1e-3), microbatches=4)
    p = _params()
    st1, m1 = s1(i1(p), {"tokens": toks})
    st4, m4 = s4(i4(p), {"tokens": toks})
    for a, b in zip(jax.tree_util.tree_leaves(st1["params"]),
                    jax.tree_util.tree_leaves(st4["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-4)


def test_checkpoint_roundtrip_and_resume():
    with tempfile.TemporaryDirectory() as td:
        init_state, step = make_train_step(
            lambda p, b: T.loss_fn(p, b, CFG), OptConfig(lr=1e-3))
        state, _ = run_loop(init_state, step, _mk, _params(),
                            TrainLoopConfig(steps=10, ckpt_dir=td,
                                            ckpt_every=5, log_every=5))
        assert checkpoint.latest_step(td) == 10
        # resume continues from step 10 (no recompute of earlier steps)
        state2, hist2 = run_loop(init_state, step, _mk, _params(),
                                 TrainLoopConfig(steps=12, ckpt_dir=td,
                                                 ckpt_every=50,
                                                 log_every=1))
        assert hist2["loss"][0][0] == 10
        # prune keeps the newest
        checkpoint.prune(td, keep=1)
        steps = [d for d in os.listdir(td) if d.startswith("step_")]
        assert len(steps) == 1


def test_checkpoint_restore_with_shardings():
    """Elastic re-mesh path: restore with explicit device placement."""
    state = {"a": jnp.arange(16.0).reshape(4, 4),
             "b": jnp.zeros((3,), jnp.int32)}
    with tempfile.TemporaryDirectory() as td:
        checkpoint.save(td, 1, state)
        sh = jax.tree_util.tree_map(
            lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]),
            state)
        restored, _ = checkpoint.restore(td, state, shardings=sh)
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(state["a"]))


def test_nan_containment():
    """A poisoned batch must not corrupt params (update skipped)."""
    init_state, step = make_train_step(
        lambda p, b: T.loss_fn(p, b, CFG) +
        jnp.where(b["tokens"][0, 0] == 0, jnp.nan, 0.0),
        OptConfig(lr=1e-3))
    state = init_state(_params())
    bad = {"tokens": jnp.zeros((4, 32), jnp.int32)}
    before = jax.tree_util.tree_leaves(state["params"])[0].copy()
    state, metrics = step(state, bad)
    assert not bool(metrics["finite"])
    after = jax.tree_util.tree_leaves(state["params"])[0]
    np.testing.assert_array_equal(np.asarray(before), np.asarray(after))
    assert int(state["nan_skips"]) == 1


def test_grad_clip():
    grads = {"w": jnp.full((4,), 100.0)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(200.0)
    assert float(jnp.linalg.norm(clipped["w"])) == pytest.approx(1.0, rel=1e-5)


def test_data_determinism():
    a = data.lm_batch(7, 4, 16, 100, seed=3)
    b = data.lm_batch(7, 4, 16, 100, seed=3)
    c = data.lm_batch(8, 4, 16, 100, seed=3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
