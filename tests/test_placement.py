"""Placement engine: the paper's technique wired into the framework."""
import numpy as np

from repro.core.partitioner import PartitionerConfig
from repro.graphs import generators
from repro.placement import dlrm_placement, gnn_placement, moe_placement


def test_gnn_placement_cuts_halo():
    """Partitioner placement must beat the naive contiguous split on a
    geometry-free (shuffled-id) graph — the collective-term reduction
    that EXPERIMENTS.md §Perf quantifies."""
    g = generators.make("rgg2d", 3000, 8.0, seed=3)
    # shuffle vertex ids so the naive contiguous split has no locality
    rng = np.random.default_rng(0)
    from repro.graphs.format import permute
    g, _ = permute(g, rng.permutation(g.n))
    plan = gnn_placement.plan(
        g, 8, config=PartitionerConfig(contraction_limit=64,
                                       ip_repetitions=2, num_chunks=4))
    assert plan.halo_bytes < 0.7 * plan.baseline_halo_bytes, \
        (plan.halo_bytes, plan.baseline_halo_bytes)
    # the relabelled graph is a consistent permutation of the input
    assert plan.graph.m == g.m
    assert plan.offsets[-1] == g.n


def test_dlrm_placement_balanced():
    rng = np.random.default_rng(1)
    B, F = 512, 26
    # two clusters of co-firing features
    sparse = rng.integers(0, 1000, (B, F, 1))
    off = rng.random((B, 1)) < 0.5
    sparse[:, :13][np.broadcast_to(off[:, :, None], (B, 13, 1))] = -1
    sparse[:, 13:][np.broadcast_to(~off[:, :, None], (B, 13, 1))] = -1
    rows = rng.integers(10_000, 1_000_000, F)
    out = dlrm_placement.plan(sparse, rows, n_shards=4, epsilon=0.5)
    assert out["assignment"].shape == (F,)
    assert len(np.unique(out["assignment"])) == 4


def test_moe_placement_beats_naive():
    rng = np.random.default_rng(2)
    E, T = 32, 20000
    # block-structured co-activation: experts pair within groups of 8
    grp = rng.integers(0, 4, T)
    a = grp * 8 + rng.integers(0, 8, T)
    b = grp * 8 + rng.integers(0, 8, T)
    # shuffle expert ids so naive contiguous ranges straddle groups
    shuf = rng.permutation(E)
    samples = np.stack([shuf[a], shuf[b]], axis=1)
    out = moe_placement.plan(samples, E, n_pods=4)
    assert out["cross_pod_fraction"] <= out["naive_cross_pod_fraction"]
    assert out["cross_pod_fraction"] < 0.25, out
    assert sum(out["experts_per_pod"]) == E
