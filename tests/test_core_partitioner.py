"""Core partitioner behaviour: feasibility, quality, invariants."""
import numpy as np
import pytest

from repro.core import PartitionerConfig
from repro.core import baselines, metrics
from repro.core.deep_mgp import partition as driver_partition
from repro.core.coarsening import cluster, enforce_cluster_weights
from repro.core.contraction import contract
from repro.core.deep_mgp import ceil2, extract_block_subgraphs
from repro.graphs import generators
from repro.graphs.format import from_coo


SMALL_CFG = PartitionerConfig(contraction_limit=128, ip_repetitions=2,
                              num_chunks=4)


@pytest.fixture(scope="module")
def rgg():
    return generators.make("rgg2d", 4000, 8.0, seed=3)


@pytest.fixture(scope="module")
def rhg():
    return generators.make("rhg", 4000, 12.0, seed=4)


# ---------------------------------------------------------------------------
# feasibility — the paper's headline robustness claim
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ["rgg2d", "rhg", "ba", "grid2d"])
@pytest.mark.parametrize("k", [2, 7, 16, 64])
def test_always_feasible(family, k):
    g = generators.make(family, 2500, 8.0, seed=11)
    part = driver_partition(g, k, SMALL_CFG)
    assert part.shape == (g.n,)
    assert part.min() >= 0 and part.max() < k
    assert metrics.is_feasible(g, part, k, 0.03), \
        metrics.summarize(g, part, k, 0.03)


def test_feasible_weighted_instance():
    g = generators.weighted_variant(
        generators.make("rgg2d", 3000, 8.0, seed=5), seed=6)
    part = driver_partition(g, 16, SMALL_CFG)
    assert metrics.is_feasible(g, part, 16, 0.03)


def test_feasible_large_k_small_C():
    """Deep MGP handles k comparable to n/C (the paper's large-k regime)."""
    g = generators.make("rgg2d", 6000, 8.0, seed=7)
    cfg = PartitionerConfig(contraction_limit=32, ip_repetitions=1,
                            num_chunks=4)
    part = driver_partition(g, 256, cfg)
    s = metrics.summarize(g, part, 256, 0.03)
    assert s["feasible"], s
    assert s["nonempty_blocks"] == 256


# ---------------------------------------------------------------------------
# quality — deep MGP must beat single-level LP clearly (paper Fig 2 / §3)
# ---------------------------------------------------------------------------

def test_quality_beats_single_level(rgg):
    p_deep = driver_partition(rgg, 8, SMALL_CFG)
    p_flat = baselines.single_level_lp(rgg, 8, seed=1)
    cut_deep = metrics.edge_cut(rgg, p_deep)
    cut_flat = metrics.edge_cut(rgg, p_flat)
    assert cut_deep < 0.75 * cut_flat, (cut_deep, cut_flat)


def test_quality_comparable_to_plain_mgp(rhg):
    p_deep = driver_partition(rhg, 8, SMALL_CFG)
    p_plain = baselines.plain_mgp(rhg, 8, cfg=SMALL_CFG)
    cut_deep = metrics.edge_cut(rhg, p_deep)
    cut_plain = metrics.edge_cut(rhg, p_plain)
    # within 2x of plain MGP at small k (paper: within a few percent;
    # we allow slack for the reduced test configuration)
    assert cut_deep < 2.0 * max(cut_plain, 1), (cut_deep, cut_plain)


# ---------------------------------------------------------------------------
# coarsening invariants
# ---------------------------------------------------------------------------

def test_cluster_respects_max_weight(rgg):
    W = 50
    labels = cluster(rgg, W, seed=0)
    cw = np.zeros(rgg.n, dtype=np.int64)
    np.add.at(cw, labels, rgg.vweights)
    # multi-member clusters obey W (singletons may exceed, none here since
    # unit weights and W >= 1)
    assert cw.max() <= W


def test_cluster_shrinks(rgg):
    labels = cluster(rgg, 50, seed=0)
    assert np.unique(labels).size < rgg.n * 0.7


def test_contract_preserves_totals(rgg):
    labels = cluster(rgg, 50, seed=0)
    gc, mapping = contract(rgg, labels)
    gc.validate()
    assert gc.total_vweight == rgg.total_vweight
    # cut of any partition is preserved through contraction+projection
    part_c = np.arange(gc.n) % 4
    part_f = part_c[mapping]
    assert metrics.edge_cut(gc, part_c) == metrics.edge_cut(rgg, part_f)


def test_enforce_cluster_weights():
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 10, size=200)
    vw = rng.integers(1, 5, size=200)
    out = enforce_cluster_weights(labels, vw, 20)
    cw = np.zeros(200, dtype=np.int64)
    np.add.at(cw, out, vw)
    members = np.bincount(out, minlength=200)
    # multi-member clusters fit
    assert np.all(cw[members > 1] <= 20)


# ---------------------------------------------------------------------------
# subgraph extraction (extension machinery)
# ---------------------------------------------------------------------------

def test_extract_block_subgraphs(rgg):
    part = np.arange(rgg.n) % 5
    graphs, ids = extract_block_subgraphs(rgg, part, 5)
    assert sum(s.n for s in graphs) == rgg.n
    for b, (sub, old) in enumerate(zip(graphs, ids)):
        sub.validate()
        assert np.all(part[old] == b)
    # every intra-block edge is preserved
    src = rgg.arc_tails()
    intra = (part[src] == part[rgg.adjncy])
    assert sum(s.m for s in graphs) == int(intra.sum())


def test_ceil2():
    assert [ceil2(x) for x in [1, 2, 3, 4, 5, 127, 128, 129]] == \
        [1, 2, 4, 4, 8, 128, 128, 256]


# ---------------------------------------------------------------------------
# metrics self-checks
# ---------------------------------------------------------------------------

def test_edge_cut_manual():
    #  0 - 1 - 2 - 3 (path), split in the middle
    g = from_coo(4, np.array([0, 1, 2]), np.array([1, 2, 3]))
    part = np.array([0, 0, 1, 1])
    assert metrics.edge_cut(g, part) == 1
    assert metrics.imbalance(g, part, 2) == 0.0


def test_l_max_allows_heaviest_vertex():
    # L_max >= c(V)/k + max_c guarantees feasibility is always reachable
    assert metrics.l_max(100, 10, 0.0, 50) >= 60
