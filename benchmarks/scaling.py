"""Paper Figures 4-6 analog: weak/strong scaling of the distributed
partitioner over simulated PEs (forced host devices, subprocess per PE
count since jax locks the device count at first init).

On a 1-core host wall-clock "speedup" is meaningless; what this bench
establishes is (a) the SPMD program runs at every PE count, (b) the
*communication volume per PE* stays ~constant under weak scaling (the
scalability argument of the paper), (c) quality does not degrade with P.
Halo volume == the sparse-all-to-all payload of §5.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CHILD = r"""
import json, sys
P = int(sys.argv[1]); mode = sys.argv[2]; n = int(sys.argv[3])
k = int(sys.argv[4])
from repro.api import runtime
runtime.force_host_devices(P)
from repro.api import PartitionRequest, Partitioner
from repro.core import PartitionerConfig
from repro.graphs import generators
from repro.graphs.distribute import distribute_graph
cfg = PartitionerConfig(contraction_limit=128, ip_repetitions=1,
                        num_chunks=4)
g = generators.make("rgg2d", n, 8.0, seed=23)
shards = distribute_graph(g, P)
res = Partitioner().run(PartitionRequest(
    graph=g, k=k, config=cfg, backend="dist-grid", devices=P,
    collect_trace=False))
print(json.dumps({
    "P": P, "mode": mode, "n": g.n, "m": g.m, "k": k,
    "time_s": res.time_s, "cut": res.cut,
    "feasible": res.feasible,
    "backend": res.backend,
    "halo_bytes_total": shards.comm_bytes_per_halo(),
    "halo_bytes_per_pe": shards.comm_bytes_per_halo() / P,
    "edges_per_s": g.m / res.time_s,
}))
"""


def _run_child(P, mode, n, k) -> Dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD, str(P), mode, str(n), str(k)],
        capture_output=True, text=True, env=env, timeout=560)
    lines = [l for l in proc.stdout.splitlines() if l.startswith("{")]
    assert proc.returncode == 0 and lines, proc.stderr[-2000:]
    return json.loads(lines[-1])


def run(pes=(1, 2, 4, 8), n_per_pe=2000, n_strong=8000, k=16,
        out_json=None) -> Dict:
    from .common import emit
    weak, strong = [], []
    for P in pes:
        r = _run_child(P, "weak", n_per_pe * P, k)
        weak.append(r)
        emit(f"scaling/weak/P{P}", r["time_s"],
             f"n={r['n']};cut={r['cut']};feas={r['feasible']};"
             f"halo_per_pe={r['halo_bytes_per_pe']:.0f}")
    for P in pes:
        r = _run_child(P, "strong", n_strong, k)
        strong.append(r)
        emit(f"scaling/strong/P{P}", r["time_s"],
             f"cut={r['cut']};feas={r['feasible']};"
             f"halo_per_pe={r['halo_bytes_per_pe']:.0f}")
    result = {"weak": weak, "strong": strong}
    if out_json:
        with open(out_json, "w") as f:
            json.dump(result, f, indent=1)
    return result


if __name__ == "__main__":
    run(out_json="artifacts/scaling.json"
        if os.path.isdir("artifacts") else None)
