"""Balancer deep-dive (the paper's §4 Balancing innovation): start from
deliberately infeasible partitions, measure imbalance before/after,
rounds to feasibility, and cut damage."""
from __future__ import annotations

import json
import time
from typing import Dict

import numpy as np

from repro.core import metrics
from repro.core.balance import rebalance

from .common import emit, instance_set


def run(k: int = 16, eps: float = 0.03, out_json=None) -> Dict:
    rows = []
    for name, g in instance_set("small"):
        rng = np.random.default_rng(5)
        # adversarial start: 60% of vertices in block 0
        part = rng.integers(0, k, g.n)
        part[rng.random(g.n) < 0.6] = 0
        lmax = metrics.l_max(g.total_vweight, k, eps, int(g.vweights.max()))
        before = metrics.summarize(g, part, k, eps)
        t0 = time.perf_counter()
        fixed = rebalance(g, part, np.full(k, lmax, dtype=np.int64))
        dt = time.perf_counter() - t0
        after = metrics.summarize(g, fixed, k, eps)
        moved = int(np.sum(fixed != part))
        rows.append({"instance": name, "before": before, "after": after,
                     "moved": moved, "time_s": dt})
        emit(f"balancer/{name}", dt,
             f"imb {before['imbalance']:.2f}->{after['imbalance']:.3f};"
             f"feas={after['feasible']};moved={moved};"
             f"cut {before['cut']}->{after['cut']}")
        assert after["feasible"], (name, after)
    result = {"rows": rows}
    if out_json:
        with open(out_json, "w") as f:
            json.dump(result, f, indent=1, default=str)
    return result


if __name__ == "__main__":
    run()
