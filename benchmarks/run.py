"""Benchmark runner — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).

Sections:
  api            — repro.api facade: every backend on one request,
                   emits BENCH_api.json (cut/feasibility/time per backend)
  dist           — distributed memory models: host/replicated vs
                   sharded/owner on forced devices, emits BENCH_dist.json
                   (per-level coarsen/exchange timings, peak replicated
                   bytes per PE)
  balance        — host vs distributed balancer: rounds to feasibility,
                   per-round time, bytes exchanged (gather vs pooled
                   candidates), emits BENCH_balance.json
  serve          — multi-mesh serving tier: throughput, p50/p99 latency,
                   queue depth vs offered load at 1 vs 2 meshes, emits
                   BENCH_serve.json
  quality        — Fig 2a/b: deep vs plain vs single-level LP edge cuts
  large_k        — Table 2: feasibility at large k
  balancer       — §4 Balancing: repair of adversarial imbalance
  scaling        — Fig 4-6: weak/strong scaling over simulated PEs
  kernels        — fused vs composed hot-loop kernels (bit-identity,
                   steady-state times, VMEM + roofline accounting),
                   emits BENCH_kernels.json; plus the legacy
                   micro-kernel CSV rows
  roofline       — §Roofline table (needs artifacts/dryrun from
                   ``python -m repro.launch.dryrun --all --out ...``)

``python -m benchmarks.run [--fast] [--sections a,b,c]``
"""
import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smallest instances (CI mode)")
    ap.add_argument("--sections", default="api,dist,balance,serve,quality,"
                    "large_k,balancer,kernels,scaling")
    args = ap.parse_args()
    sections = args.sections.split(",")
    print("name,us_per_call,derived")

    if "api" in sections:
        from . import api_bench
        api_bench.run(fast=args.fast)
    if "dist" in sections:
        from . import dist_bench
        dist_bench.run(fast=args.fast)
    if "balance" in sections:
        from . import balance_bench
        balance_bench.run(fast=args.fast)
    if "serve" in sections:
        from . import serve_bench
        serve_bench.run(fast=args.fast)
    if "quality" in sections:
        from . import quality
        quality.run(scale="small", ks=(2, 8, 32),
                    seeds=(0,) if args.fast else (0, 1))
    if "large_k" in sections:
        from . import large_k
        large_k.run(ks=(64, 256) if args.fast else (64, 256, 1024))
    if "balancer" in sections:
        from . import balancer_stats
        balancer_stats.run()
    if "kernels" in sections:
        from . import kernels_bench
        kernels_bench.run(fast=args.fast)
    if "scaling" in sections:
        from . import scaling
        scaling.run(pes=(1, 2, 4) if args.fast else (1, 2, 4, 8))
    if "roofline" in sections:
        from . import roofline
        if os.path.isdir("artifacts/dryrun"):
            roofline.run("artifacts/dryrun")
        else:
            print("roofline,0,skipped (run repro.launch.dryrun --all "
                  "--out artifacts/dryrun first)")


if __name__ == "__main__":
    main()
