"""§Perf hillclimb artifacts.

1. Paper-representative cell (gat-cora x ogb-scale full graph): quantify
   the collective-term reduction bought by partitioner placement — halo
   bytes per layer exchange, naive contiguous split vs deep-MGP blocks,
   at P=256 (the single-pod device count). Run on a same-family proxy
   graph sized for this host; the halo term scales linearly.
2. Emits the measured numbers as CSV for EXPERIMENTS.md §Perf-hillclimb.
"""
from __future__ import annotations

import json
import sys
import time

import numpy as np

from repro.core.partitioner import PartitionerConfig
from repro.graphs import generators
from repro.graphs.format import permute
from repro.placement import gnn_placement

from .common import emit


def run(n: int = 100_000, P: int = 256, d_feat: int = 100,
        out_json: str | None = None):
    g = generators.make("rgg2d", n, 16.0, seed=31)
    rng = np.random.default_rng(0)
    g, _ = permute(g, rng.permutation(g.n))   # destroy free locality
    cfg = PartitionerConfig(contraction_limit=256, ip_repetitions=1,
                            num_chunks=4)
    t0 = time.time()
    plan = gnn_placement.plan(g, P, config=cfg)
    dt = time.time() - t0
    # collective term: per-layer halo exchange moves halo entries x
    # d_feat floats; term = bytes/(P * link_bw)
    link_bw = 50e9
    naive = plan.baseline_halo_bytes / 4 * d_feat * 4
    part = plan.halo_bytes / 4 * d_feat * 4
    t_naive = naive / (P * link_bw)
    t_part = part / (P * link_bw)
    res = {
        "n": g.n, "m": g.m, "P": P,
        "cut": plan.cut,
        "halo_entries_naive": plan.baseline_halo_bytes // 4,
        "halo_entries_partitioned": plan.halo_bytes // 4,
        "reduction_x": plan.baseline_halo_bytes / max(plan.halo_bytes, 1),
        "collective_term_naive_s": t_naive,
        "collective_term_partitioned_s": t_part,
        "partition_time_s": dt,
    }
    emit(f"perf/gnn_halo/P{P}", dt,
         f"halo_reduction={res['reduction_x']:.2f}x;"
         f"coll_term {t_naive:.4f}s->{t_part:.4f}s")
    if out_json:
        with open(out_json, "w") as f:
            json.dump(res, f, indent=1)
    print(json.dumps(res), flush=True)
    return res


if __name__ == "__main__":
    run(n=int(sys.argv[1]) if len(sys.argv) > 1 else 100_000,
        out_json="artifacts/perf_gnn_halo.json")
