"""Host vs distributed balancer benchmark: one artifact tracking both.

Runs the same adversarially imbalanced instance through
``core.balance.rebalance`` (host: one O(m) single-chunk gather, then
greedy rounds) and ``dist.dist_balance.dist_rebalance`` (no gather;
O(P·top_m) pooled candidate records per round, replicated and
owner-sharded block tables) in a forced-multi-device subprocess, and
writes ``BENCH_balance.json``: rounds to feasibility, per-round wall
time, and bytes exchanged per mode — the host's up-front gather volume
against the distributed pool + halo traffic. A full ``dist-grid``
pipeline pass per ``balance`` mode records the per-level balancer
rounds from the driver trace.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CHILD = r"""
import json, sys
import numpy as np
P = int(sys.argv[1]); n = int(sys.argv[2]); k = int(sys.argv[3])
from repro.api import runtime
runtime.force_host_devices(P)
from repro.api import PartitionRequest, Partitioner
from repro.core import PartitionerConfig, metrics
from repro.core.balance import rebalance
from repro.dist.dist_balance import dist_rebalance
from repro.graphs import generators
from repro.graphs.distribute import distribute_graph

g = generators.make("rgg2d", n, 8.0, seed=31)
rng = np.random.default_rng(5)
part = rng.integers(0, k, g.n)
part[rng.random(g.n) < 0.6] = 0           # adversarial: 60% in block 0
lmax = np.full(k, metrics.l_max(g.total_vweight, k, 0.03,
                                int(g.vweights.max())), dtype=np.int64)
before = metrics.summarize(g, part, k, 0.03)
shards = distribute_graph(g, P)
out = {"P": P, "n": g.n, "m": g.m, "k": k, "imbalance_before":
       before["imbalance"], "modes": {}}

host_stats = {}
fixed_h = rebalance(g, part.copy(), lmax, seed=7, stats=host_stats)
out["modes"]["host"] = {
    "rounds": host_stats["rounds"],
    "time_s": round(host_stats["time_s"], 4),
    "s_per_round": round(host_stats["time_s"] /
                         max(1, host_stats["rounds"]), 5),
    "bytes_exchanged": host_stats["gather_bytes"],
    "feasible": bool(metrics.is_feasible(g, fixed_h, k, 0.03)),
    "cut": metrics.edge_cut(g, fixed_h),
}
for wmode in ("replicated", "owner"):
    st = {}
    fixed_d = dist_rebalance(shards, part.copy(), lmax, seed=7,
                             use_grid=True, weights=wmode, stats=st)
    out["modes"][f"dist_{wmode}"] = {
        "rounds": st["rounds"],
        "time_s": round(st["time_s"], 4),
        "s_per_round": round(st["time_s"] / max(1, st["rounds"]), 5),
        "bytes_exchanged": st["pool_bytes"] + st["halo_bytes"],
        "feasible": bool(metrics.is_feasible(g, fixed_d, k, 0.03)),
        "cut": metrics.edge_cut(g, fixed_d),
    }

# full-pipeline pass per balance mode: per-level balancer rounds
cfgs = {"host": PartitionerConfig(contraction_limit=128, ip_repetitions=1,
                                  num_chunks=4),
        "dist": PartitionerConfig(contraction_limit=128, ip_repetitions=1,
                                  num_chunks=4, balance="dist")}
out["pipeline"] = {}
for name, cfg in cfgs.items():
    res = Partitioner().run(PartitionRequest(
        graph=g, k=k, config=cfg, backend="dist-grid", devices=P))
    unc = [t for t in res.trace if t["phase"] == "dist-uncoarsen"]
    out["pipeline"][name] = {
        "time_s": round(float(res.time_s), 4),
        "cut": res.cut, "feasible": res.feasible,
        "levels": [{"n": t["n"], "balance_rounds": t.get("balance_rounds"),
                    "time_s": t["time_s"]} for t in unc],
    }
print(json.dumps(out))
"""


def run(fast: bool = True, P: int = 4, out_json: str = "BENCH_balance.json"
        ) -> Dict:
    from .common import emit

    n = 3000 if fast else 20000
    k = 16
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD, str(P), str(n), str(k)],
        capture_output=True, text=True, env=env, timeout=820)
    lines = [l for l in proc.stdout.splitlines() if l.startswith("{")]
    assert proc.returncode == 0 and lines, proc.stderr[-2000:]
    result = json.loads(lines[-1])
    for name, rec in result["modes"].items():
        emit(f"balance/{name}", rec["time_s"],
             f"rounds={rec['rounds']};feas={rec['feasible']};"
             f"bytes={rec['bytes_exchanged']};cut={rec['cut']}")
    host_b = result["modes"]["host"]["bytes_exchanged"]
    dist_b = result["modes"]["dist_replicated"]["bytes_exchanged"]
    emit("balance/bytes_ratio_host_over_dist", 0.0,
         f"{host_b}/{dist_b}")
    if out_json:
        with open(out_json, "w") as f:
            json.dump(result, f, indent=1)
        emit("balance/artifact", 0.0, out_json)
    return result


if __name__ == "__main__":
    run(fast=True)
