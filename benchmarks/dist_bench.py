"""Distributed memory-model benchmark: one artifact tracking both paths.

Runs the same instance through the ``dist-grid`` backend under the two
memory models (``contraction="host"``/``weights="replicated"`` vs
``"sharded"``/``"owner"``) in a forced-multi-device subprocess and writes
``BENCH_dist.json``: per-level coarsen/uncoarsen wall times, the sharded
path's exchange timings and payload bytes, and the peak *persistent*
replicated bytes per PE each model carries (the replicated table is
O(n); the owner shard is O(n/P + k) — the scaling argument of ROADMAP's
larger-n scenarios, measured run-over-run).

Each mode runs twice and keeps the second trace: the discarded warmup
absorbs jit/Pallas compilation so the committed per-level numbers are
steady state. ``kernel`` selects the hot-loop implementation
(docs/KERNELS.md); the default commits the fused-kernel numbers.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CHILD = r"""
import json, sys
P = int(sys.argv[1]); n = int(sys.argv[2]); k = int(sys.argv[3])
kernel = sys.argv[4]
from repro.api import runtime
runtime.force_host_devices(P)
from repro.api import PartitionRequest, Partitioner
from repro.core import PartitionerConfig
from repro.graphs import generators

g = generators.make("rgg2d", n, 8.0, seed=29)
out = {"P": P, "n": g.n, "m": g.m, "k": k, "kernel": kernel, "modes": {}}
engine = Partitioner()
for name, contraction, weights in (
        ("host_replicated", "host", "replicated"),
        ("sharded_owner", "sharded", "owner")):
    cfg = PartitionerConfig(contraction_limit=128, ip_repetitions=1,
                            num_chunks=4, contraction=contraction,
                            weights=weights, kernel=kernel)
    req = PartitionRequest(graph=g, k=k, config=cfg, backend="dist-grid",
                           devices=P)
    engine.run(req)       # discarded warmup: absorbs jit/Pallas compiles
    res = engine.run(req)  # steady state (same shapes, warm caches)
    levels = [t for t in res.trace
              if t["phase"].startswith("dist-coarsen")]
    unc = [t for t in res.trace if t["phase"] == "dist-uncoarsen"]
    # peak persistent replicated state per PE: the cluster weight table
    # of the largest level plus the block weight table (4-byte entries)
    def table_bytes(nl):
        if weights == "owner":
            return 4 * (-(-(nl + 1) // P) + -(-(k + 1) // P))
        return 4 * ((nl + 1) + (k + 1))
    out["modes"][name] = {
        "time_s": round(float(res.time_s), 4),
        "cut": res.cut, "feasible": res.feasible,
        "levels": levels, "uncoarsen": unc,
        "coarsen_s_total": round(sum(t["time_s"] for t in levels), 4),
        "exchange_s_total": round(
            sum(t.get("exchange_s", 0.0) for t in levels), 4),
        "exchange_payload_bytes": int(
            sum(t.get("payload_bytes", 0) for t in levels)),
        "peak_replicated_bytes_per_pe": max(
            (table_bytes(t["n"]) for t in levels), default=table_bytes(0)),
    }
print(json.dumps(out))
"""


def run(fast: bool = True, P: int = 4, out_json: str = "BENCH_dist.json",
        kernel: str = "fused") -> Dict:
    from .common import emit

    n = 3000 if fast else 20000
    k = 8
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD, str(P), str(n), str(k), kernel],
        capture_output=True, text=True, env=env, timeout=820)
    lines = [l for l in proc.stdout.splitlines() if l.startswith("{")]
    assert proc.returncode == 0 and lines, proc.stderr[-2000:]
    result = json.loads(lines[-1])
    for name, rec in result["modes"].items():
        emit(f"dist/{name}", rec["time_s"],
             f"cut={rec['cut']};feas={rec['feasible']};"
             f"repl_bytes_per_pe={rec['peak_replicated_bytes_per_pe']};"
             f"exchange_s={rec['exchange_s_total']}")
    host = result["modes"]["host_replicated"]
    shard = result["modes"]["sharded_owner"]
    emit("dist/replicated_bytes_ratio", 0.0,
         f"{host['peak_replicated_bytes_per_pe']}/"
         f"{shard['peak_replicated_bytes_per_pe']}")
    if out_json:
        with open(out_json, "w") as f:
            json.dump(result, f, indent=1)
        emit("dist/artifact", 0.0, out_json)
    return result


if __name__ == "__main__":
    run(fast=True)
