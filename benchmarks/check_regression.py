"""CI bench regression gate — compare fresh artifacts to baselines.

CI regenerates ``BENCH_api.json`` / ``BENCH_dist.json`` /
``BENCH_balance.json`` / ``BENCH_serve.json`` / ``BENCH_kernels.json``
in the working tree; this
gate compares them against the *committed* baselines (``git show
HEAD:<file>`` by default, or ``--baseline-dir``) and fails the job —
instead of only uploading artifacts — when:

  * any fresh record is infeasible (``"feasible": false`` anywhere),
    reports failed serve requests, reports batched serve results that
    deviate bit-wise from solo runs (``"bit_identical": false``),
    reports a ``batch_speedup`` below the 2x floor, reports the
    unconstrained refinement tier losing to LP on aggregate cut
    (``"cut_leq_lp"`` false), or reports a fabric autoscaler that
    failed to grow under pressure or shrink back when idle
    (``"grew"``/``"shrank"`` false);
  * a ``cut`` regresses by more than ``--tolerance`` (cuts are
    deterministic for fixed seeds, so any growth is a code change);
  * a latency/time metric regresses by more than ``--time-tolerance``
    *beyond* ``--time-floor`` seconds of absolute slack. Wall clock is
    machine-dependent (the committed baselines and the CI runner are
    different hardware) so its default budget is deliberately loose —
    100%, enough to catch an accidental complexity blowup or a lost
    jit cache without flaking on runner variance; tighten it with
    ``--time-tolerance 0.25`` when comparing runs from one machine;
  * serve throughput drops beyond the equivalent slack.

Structure changes (a key or list entry present on only one side) are
reported but don't fail the gate — renaming a benchmark field is a
reviewed code change, not a perf regression.

  python -m benchmarks.check_regression
  python -m benchmarks.check_regression --files BENCH_api.json \
      --tolerance 0.25 --baseline-ref origin/main
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import List, Optional, Tuple

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DEFAULT_FILES = ["BENCH_api.json", "BENCH_dist.json",
                 "BENCH_balance.json", "BENCH_serve.json",
                 "BENCH_kernels.json"]

# keys gated as "lower is better" wall-clock seconds
TIME_KEYS = {"time_s", "wall_s", "s_per_round", "latency_p50_s",
             "latency_p99_s", "queue_wait_p50_s", "coarsen_s_total"}
# keys gated as "higher is better" rates
RATE_KEYS = {"throughput_rps", "batch_speedup"}

# the batched serve path must beat solo by this factor on the hot mix
# (it is a structural win — coalescing — not a machine-speed number)
MIN_BATCH_SPEEDUP = 2.0

# top-level sections each artifact must carry; a missing one means the
# producing bench crashed mid-run or its writer changed shape, and the
# gate must say *which* section and *which* producer instead of letting
# a downstream lookup die with a bare KeyError
EXPECTED_SECTIONS = {
    "BENCH_api.json": ("instance", "backends", "refine_pareto"),
    "BENCH_dist.json": ("modes",),
    "BENCH_balance.json": ("modes", "pipeline"),
    "BENCH_serve.json": ("meshes", "batched", "fabric"),
    "BENCH_kernels.json": ("kernels", "roofline"),
}

# artifact -> the command that regenerates it (for error messages)
PRODUCERS = {
    "BENCH_api.json": "python -m benchmarks.api_bench",
    "BENCH_dist.json": "python -m benchmarks.dist_bench",
    "BENCH_balance.json": "python -m benchmarks.balance_bench",
    "BENCH_serve.json": "python -m benchmarks.serve_bench",
    "BENCH_kernels.json": "python -m benchmarks.kernels_bench",
}


class MissingSectionError(KeyError):
    """A bench artifact lacks a section the gate relies on."""

    def __init__(self, artifact: str, section: str):
        self.artifact = artifact
        self.section = section
        producer = PRODUCERS.get(artifact, "the producing bench")
        super().__init__(
            f"{artifact}: missing expected section {section!r} — the "
            f"artifact is incomplete (producer crashed mid-run or its "
            f"writer changed shape); re-run `{producer}` or update "
            "EXPECTED_SECTIONS if the rename is intentional")

    def __str__(self) -> str:  # KeyError.__str__ would repr() the msg
        return self.args[0]


def check_sections(name: str, fresh: dict, failures: List[str]) -> None:
    """Fail with a named, actionable message on missing sections."""
    base = os.path.basename(name)
    for section in EXPECTED_SECTIONS.get(base, ()):
        if not isinstance(fresh, dict) or section not in fresh:
            failures.append(str(MissingSectionError(base, section)))


def load_baseline(name: str, ref: str,
                  baseline_dir: Optional[str]) -> Optional[dict]:
    if baseline_dir is not None:
        path = os.path.join(baseline_dir, name)
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return json.load(f)
    proc = subprocess.run(["git", "-C", ROOT, "show", f"{ref}:{name}"],
                          capture_output=True, text=True)
    if proc.returncode != 0:
        return None
    return json.loads(proc.stdout)


def walk(fresh, base, path: str, failures: List[str],
         notes: List[str], tol: float, time_tol: float,
         floor: float) -> None:
    """Recursively gate matching paths of the two artifacts."""
    if isinstance(fresh, dict):
        if not isinstance(base, dict):
            notes.append(f"{path}: structure changed (dict vs baseline "
                         f"{type(base).__name__})")
            return
        for key, fval in fresh.items():
            sub = f"{path}.{key}" if path else key
            if key not in base:
                notes.append(f"{sub}: new in fresh artifact")
                continue
            walk(fval, base[key], sub, failures, notes, tol, time_tol,
                 floor)
        return
    if isinstance(fresh, list):
        if not isinstance(base, list) or len(base) != len(fresh):
            notes.append(f"{path}: list shape changed "
                         f"({len(fresh)} entries)")
            return
        for i, (fv, bv) in enumerate(zip(fresh, base)):
            walk(fv, bv, f"{path}[{i}]", failures, notes, tol,
                 time_tol, floor)
        return
    key = path.rsplit(".", 1)[-1].split("[")[0]
    if key == "cut" and isinstance(fresh, (int, float)) \
            and isinstance(base, (int, float)):
        if fresh > base * (1 + tol):
            failures.append(f"{path}: cut regressed {base} -> {fresh} "
                            f"(>{tol:.0%})")
    elif key in TIME_KEYS and isinstance(fresh, (int, float)) \
            and isinstance(base, (int, float)):
        if fresh > base * (1 + time_tol) + floor:
            failures.append(f"{path}: time regressed {base:.4f}s -> "
                            f"{fresh:.4f}s (>{time_tol:.0%} + {floor}s)")
    elif key in RATE_KEYS and isinstance(fresh, (int, float)) \
            and isinstance(base, (int, float)):
        if fresh * (1 + time_tol) < base and base - fresh > floor:
            failures.append(f"{path}: throughput regressed {base} -> "
                            f"{fresh} (>{time_tol:.0%})")


def check_invariants(node, path: str, failures: List[str]) -> None:
    """Feasibility (and serve failure counters) must hold regardless of
    any baseline: an infeasible partition is a correctness bug."""
    if isinstance(node, dict):
        for key, val in node.items():
            sub = f"{path}.{key}" if path else key
            if key == "feasible" and val is False:
                failures.append(f"{sub}: infeasible partition")
            elif key == "failed" and isinstance(val, int) and val > 0:
                failures.append(f"{sub}: {val} failed request(s)")
            elif key == "bit_identical" and val is False:
                failures.append(f"{sub}: bit-identity invariant violated "
                                "(batched vs solo, or fused vs composed "
                                "kernels)")
            elif key == "batch_speedup" and isinstance(val, (int, float)) \
                    and val < MIN_BATCH_SPEEDUP:
                failures.append(
                    f"{sub}: batched dispatch only {val}x solo "
                    f"(< {MIN_BATCH_SPEEDUP}x floor)")
            elif key == "cut_leq_lp" and val is False:
                failures.append(
                    f"{sub}: unconstrained refinement lost to LP on "
                    "aggregate cut (the tier's extra wall time must buy "
                    "quality — docs/REFINEMENT.md)")
            elif key == "grew" and val is False:
                failures.append(f"{sub}: autoscaler never grew the "
                                "fleet under queue pressure")
            elif key == "shrank" and val is False:
                failures.append(f"{sub}: autoscaler never shrank the "
                                "idle fleet back down")
            else:
                check_invariants(val, sub, failures)
    elif isinstance(node, list):
        for i, val in enumerate(node):
            check_invariants(val, f"{path}[{i}]", failures)


def check_file(name: str, ref: str, baseline_dir: Optional[str],
               tol: float, time_tol: float,
               floor: float) -> Tuple[List[str], List[str]]:
    failures: List[str] = []
    notes: List[str] = []
    if not os.path.exists(name):
        return [f"{name}: fresh artifact missing (bench not run?)"], notes
    with open(name) as f:
        fresh = json.load(f)
    check_sections(name, fresh, failures)
    check_invariants(fresh, name, failures)
    base = load_baseline(name, ref, baseline_dir)
    if base is None:
        notes.append(f"{name}: no committed baseline (new artifact) — "
                     "feasibility checked only")
        return failures, notes
    walk(fresh, base, name, failures, notes, tol, time_tol, floor)
    return failures, notes


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--files", default=",".join(DEFAULT_FILES),
                    help="comma-separated artifact names (working dir)")
    ap.add_argument("--baseline-ref", default="HEAD",
                    help="git ref the committed baselines are read from")
    ap.add_argument("--baseline-dir", default=None,
                    help="read baselines from a directory instead of git")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="relative budget for deterministic metrics "
                         "(cuts; default 25%%)")
    ap.add_argument("--time-tolerance", type=float, default=1.0,
                    help="relative budget for wall-clock metrics "
                         "(default 100%% — runner speeds differ; "
                         "tighten for same-machine comparisons)")
    ap.add_argument("--time-floor", type=float, default=0.5,
                    help="absolute seconds of slack on time metrics "
                         "before the relative gate applies")
    args = ap.parse_args()

    all_failures: List[str] = []
    for name in args.files.split(","):
        name = name.strip()
        if not name:
            continue
        failures, notes = check_file(name, args.baseline_ref,
                                     args.baseline_dir, args.tolerance,
                                     args.time_tolerance,
                                     args.time_floor)
        for n in notes:
            print(f"[gate:note] {n}")
        for f in failures:
            print(f"[gate:FAIL] {f}")
        if not failures:
            print(f"[gate:ok] {name}")
        all_failures.extend(failures)

    if all_failures:
        print(f"[gate] {len(all_failures)} regression(s) — failing")
        return 1
    print("[gate] all artifacts within budget")
    return 0


if __name__ == "__main__":
    sys.exit(main())
