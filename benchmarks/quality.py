"""Paper Figure 2a/b analog: edge-cut quality of deep MGP vs plain MGP vs
single-level LP across instances x k, with performance profiles.

Claims validated (paper §6): deep MGP is feasible on 100% of instances;
single-level LP cuts are >= 2x worse on average; deep ~ plain at small k.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List

import numpy as np

from repro.core import baselines, metrics, partition
from repro.core.partitioner import strong_config

from .common import bench_config, emit, geomean, instance_set, timed


def run(scale: str = "small", ks=(2, 8, 32), seeds=(0, 1), out_json=None
        ) -> Dict:
    cfg = bench_config()
    algos = {
        "deep": lambda g, k, s: partition(
            g, k, config=_with_seed(bench_config(), s)),
        "plain": lambda g, k, s: baselines.plain_mgp(
            g, k, cfg=_with_seed(bench_config(), s)),
        "single_lp": lambda g, k, s: baselines.single_level_lp(
            g, k, seed=s),
    }
    rows = []
    for name, g in instance_set(scale):
        for k in ks:
            per_algo = {}
            for aname, fn in algos.items():
                cuts, times, feas = [], [], []
                for s in seeds:
                    t0 = time.perf_counter()
                    part = fn(g, k, s)
                    times.append(time.perf_counter() - t0)
                    cuts.append(metrics.edge_cut(g, part))
                    feas.append(metrics.is_feasible(g, part, k, 0.03))
                per_algo[aname] = {
                    "cut": float(np.mean(cuts)),
                    "time": float(np.mean(times)),
                    "feasible": all(feas)}
            rows.append({"instance": name, "k": k, "algos": per_algo})
            emit(f"quality/{name}/k{k}/deep",
                 per_algo["deep"]["time"],
                 f"cut={per_algo['deep']['cut']:.0f};"
                 f"feas={per_algo['deep']['feasible']}")

    # performance profile + aggregates
    profile = {}
    for a in algos:
        ratios = []
        for r in rows:
            best = min(v["cut"] for v in r["algos"].values() if v["cut"] >= 0)
            ratios.append(r["algos"][a]["cut"] / max(best, 1))
        profile[a] = {
            "best_fraction": float(np.mean([x <= 1.0 + 1e-9
                                            for x in ratios])),
            "gmean_ratio": geomean(ratios),
            "feasible_fraction": float(np.mean(
                [r["algos"][a]["feasible"] for r in rows])),
        }
    result = {"rows": rows, "profile": profile}
    for a, p in profile.items():
        emit(f"quality/profile/{a}", 0.0,
             f"gmean_cut_ratio={p['gmean_ratio']:.3f};"
             f"best_frac={p['best_fraction']:.2f};"
             f"feasible={p['feasible_fraction']:.2f}")
    if out_json:
        with open(out_json, "w") as f:
            json.dump(result, f, indent=1)
    return result


def _with_seed(cfg, seed):
    import dataclasses
    return dataclasses.replace(cfg, seed=seed)


if __name__ == "__main__":
    import sys
    run(scale=sys.argv[1] if len(sys.argv) > 1 else "small",
        out_json="artifacts/quality.json" if len(sys.argv) > 2 else None)
