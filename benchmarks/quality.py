"""Paper Figure 2a/b analog: edge-cut quality of deep MGP vs plain MGP vs
single-level LP across instances x k, with performance profiles.

Claims validated (paper §6): deep MGP is feasible on 100% of instances;
single-level LP cuts are >= 2x worse on average; deep ~ plain at small k.

Also home to the refinement-tier Pareto sweep (``refine_pareto``):
cut vs time of ``refine="lp"`` against ``refine="unconstrained"`` on
the quality mix, emitted into ``BENCH_api.json`` and gated by
``check_regression`` — the unconstrained tier must stay feasible and
beat (or match) LP's aggregate cut, or the extra wall time buys
nothing (docs/REFINEMENT.md).
"""
from __future__ import annotations

import json
from typing import Dict

import numpy as np

from .common import bench_config, emit, geomean, instance_set

# facade backend -> the paper's algorithm label
ALGOS = {"single": "deep", "plain_mgp": "plain",
         "single_level_lp": "single_lp"}


def run(scale: str = "small", ks=(2, 8, 32), seeds=(0, 1), out_json=None
        ) -> Dict:
    from repro.api import PartitionRequest, Partitioner
    engine = Partitioner()
    rows = []
    for name, g in instance_set(scale):
        for k in ks:
            per_algo = {a: {"cuts": [], "times": [], "feas": []}
                        for a in ALGOS.values()}
            for s in seeds:
                req = PartitionRequest(
                    graph=g, k=k, config=_with_seed(bench_config(), s),
                    seed=s, collect_trace=False)
                for res in engine.compare(req, list(ALGOS)):
                    acc = per_algo[ALGOS[res.backend]]
                    acc["cuts"].append(res.cut)
                    acc["times"].append(res.time_s)
                    acc["feas"].append(res.feasible)
            per_algo = {a: {"cut": float(np.mean(acc["cuts"])),
                            "time": float(np.mean(acc["times"])),
                            "feasible": all(acc["feas"])}
                        for a, acc in per_algo.items()}
            rows.append({"instance": name, "k": k, "algos": per_algo})
            emit(f"quality/{name}/k{k}/deep",
                 per_algo["deep"]["time"],
                 f"cut={per_algo['deep']['cut']:.0f};"
                 f"feas={per_algo['deep']['feasible']}")

    # performance profile + aggregates
    profile = {}
    for a in ALGOS.values():
        ratios = []
        for r in rows:
            best = min(v["cut"] for v in r["algos"].values() if v["cut"] >= 0)
            ratios.append(r["algos"][a]["cut"] / max(best, 1))
        profile[a] = {
            "best_fraction": float(np.mean([x <= 1.0 + 1e-9
                                            for x in ratios])),
            "gmean_ratio": geomean(ratios),
            "feasible_fraction": float(np.mean(
                [r["algos"][a]["feasible"] for r in rows])),
        }
    result = {"rows": rows, "profile": profile}
    for a, p in profile.items():
        emit(f"quality/profile/{a}", 0.0,
             f"gmean_cut_ratio={p['gmean_ratio']:.3f};"
             f"best_frac={p['best_fraction']:.2f};"
             f"feasible={p['feasible_fraction']:.2f}")
    if out_json:
        with open(out_json, "w") as f:
            json.dump(result, f, indent=1)
    return result


def refine_pareto(scale: str = "small", ks=(8, 32), seeds=(0,),
                  families=None) -> Dict:
    """Cut-vs-time Pareto of the two refinement tiers on the quality
    mix: the same deep-MGP request run with ``refine="lp"`` and
    ``refine="unconstrained"`` (docs/REFINEMENT.md).

    Returns per-instance rows plus a summary whose keyed booleans the
    regression gate enforces: ``feasible`` (both tiers, every
    instance — the afterburner guarantee) and ``cut_leq_lp`` (the
    unconstrained tier's geomean cut ratio vs LP stays <= 1, i.e. the
    extra search actually buys quality)."""
    from repro.api import PartitionRequest, Partitioner
    engine = Partitioner()
    rows = []
    instances = instance_set(scale)
    if families is not None:
        instances = [(nm, g) for nm, g in instances
                     if nm.split("_")[0] in families]
    for name, g in instances:
        for k in ks:
            for s in seeds:
                row = {"instance": name, "k": k, "seed": s, "modes": {}}
                for mode in ("lp", "unconstrained"):
                    req = PartitionRequest(
                        graph=g, k=k, config=_with_seed(bench_config(), s),
                        seed=s, backend="single", refine=mode,
                        collect_trace=False)
                    res = engine.run(req)
                    row["modes"][mode] = {
                        "cut": res.cut, "feasible": res.feasible,
                        "time_s": round(float(res.time_s), 4)}
                    emit(f"quality/refine/{name}/k{k}/{mode}",
                         res.time_s, f"cut={res.cut};feas={res.feasible}")
                rows.append(row)
    ratios = [r["modes"]["unconstrained"]["cut"] /
              max(r["modes"]["lp"]["cut"], 1) for r in rows]
    gm = geomean(ratios)
    time_ratio = geomean(
        [max(r["modes"]["unconstrained"]["time_s"], 1e-9) /
         max(r["modes"]["lp"]["time_s"], 1e-9) for r in rows])
    summary = {
        "gmean_cut_ratio": round(gm, 4),
        "gmean_time_ratio": round(time_ratio, 4),
        "cut_leq_lp": bool(gm <= 1.0 + 1e-9),
        "feasible": all(m["feasible"] for r in rows
                        for m in r["modes"].values()),
    }
    emit("quality/refine/summary", 0.0,
         f"gmean_cut_ratio={gm:.4f};cut_leq_lp={summary['cut_leq_lp']};"
         f"feasible={summary['feasible']}")
    return {"rows": rows, "summary": summary}


def _with_seed(cfg, seed):
    import dataclasses
    return dataclasses.replace(cfg, seed=seed)


if __name__ == "__main__":
    import sys
    run(scale=sys.argv[1] if len(sys.argv) > 1 else "small",
        out_json="artifacts/quality.json" if len(sys.argv) > 2 else None)
