"""Shared benchmark helpers: instance sets, timing, CSV emission."""
from __future__ import annotations

import time
from typing import Callable, Iterable, List, Tuple

import numpy as np

from repro.core import PartitionerConfig
from repro.graphs import generators


def bench_config(C: int = 256) -> PartitionerConfig:
    return PartitionerConfig(contraction_limit=C, ip_repetitions=2,
                             num_chunks=4)


def instance_set(scale: str = "small") -> List[Tuple[str, object]]:
    """(name, graph) pairs across the paper's three synthetic families
    (+ ba as the complex-network proxy)."""
    sizes = {"small": 4000, "medium": 20000, "large": 60000}[scale]
    out = []
    for fam, deg in [("rgg2d", 8), ("rgg3d", 8), ("rhg", 12), ("ba", 8)]:
        g = generators.make(fam, sizes, deg, seed=17)
        out.append((f"{fam}_{sizes}", g))
    return out


def timed(fn: Callable, repeats: int = 1, warmup: int = 1):
    """Best-of-``repeats`` wall time after ``warmup`` discarded runs.

    The warmup run absorbs jit/Pallas compilation so the recorded
    numbers (and every committed BENCH_*.json built on them) measure
    steady state even at ``repeats=1``; pass ``warmup=0`` to time a
    cold start deliberately."""
    for _ in range(warmup):
        fn()
    vals = []
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        vals.append(time.perf_counter() - t0)
    return out, min(vals)


def emit(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds * 1e6:.0f},{derived}", flush=True)


def geomean(xs: Iterable[float]) -> float:
    xs = [max(x, 1e-12) for x in xs]
    return float(np.exp(np.mean(np.log(xs))))
