"""Serving-tier benchmark: throughput/latency/queue depth, 1 vs 2 meshes.

Drives the same mixed request set through ``repro.serve.PartitionServer``
at two offered loads (burst admission and paced admission just above a
single mesh's service rate) for 1 and 2 worker meshes, in a
forced-2-device subprocess, and writes ``BENCH_serve.json``: wall time,
throughput, p50/p99 end-to-end latency, queue-wait and queue-depth
stats, and per-worker served counts — the scaling claim of the serving
tier (adding a mesh drains the same offered load with a shorter queue)
tracked run-over-run by ``benchmarks.check_regression``.

A ``batched`` section drives a duplicate-heavy hot mix through the
shape-bucketed batched dispatcher and the same requests solo back to
back: the gate fails if the batched throughput falls below 2x solo or
if any batched result deviates bit-wise from its solo run.

A ``fabric`` section exercises the cross-process tier
(``repro.fabric``): the same burst through a front door backed by 1
then 2 real worker *processes* (throughput/p99 per fleet size, every
request must resolve ok and both servers must serve), then an
autoscaled front door under queue pressure — the gate fails unless the
fleet demonstrably grows 1 -> 2 under load (``grew``) and shrinks back
when idle (``shrank``).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict

from .common import emit

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CHILD = r"""
import json, sys, time
R = int(sys.argv[1]); n = int(sys.argv[2]); k = int(sys.argv[3])
from repro.api import runtime
runtime.force_host_devices(2)
from repro.api import GraphSpec, PartitionRequest, Partitioner
from repro.core import PartitionerConfig
from repro.serve import PartitionServer

cfg = PartitionerConfig(contraction_limit=128, ip_repetitions=1,
                        num_chunks=4)
reqs = [PartitionRequest(
            graph=GraphSpec("rgg2d", n // 2 * (1 + i % 3), 8.0,
                            seed=41 + i % 4),
            k=k * (1 + i % 2), config=cfg, collect_trace=False)
        for i in range(R)]

# warm every request shape once (jit caches are process-global, so the
# first measured configuration would otherwise pay all compilations and
# skew the 1-vs-2-mesh comparison), then estimate the warm solo service
# time so the paced load lands just above one mesh's capacity
engine = Partitioner()
for r in reqs:
    engine.run(r)
t0 = time.perf_counter()
engine.run(reqs[0])
t_solo = max(time.perf_counter() - t0, 1e-3)
paced_rps = 1.5 / t_solo

out = {"requests": R, "n": n, "k": k,
       "solo_service_s": round(t_solo, 4), "meshes": {}}
for meshes in (1, 2):
    per = {}
    for load, rate in (("burst", 0.0), ("paced", paced_rps)):
        with PartitionServer(meshes=meshes) as srv:
            t0 = time.perf_counter()
            futs = []
            for r in reqs:
                futs.append(srv.submit(r))
                if rate > 0:
                    time.sleep(1.0 / rate)
            results = [f.result() for f in futs]
            wall = time.perf_counter() - t0
            st = srv.stats()
        ok = all(r.ok for r in results)
        feas = ok and all(r.result.feasible for r in results)
        per[load] = {
            "offered_rps": round(rate, 3) if rate else "burst",
            "wall_s": round(wall, 4),
            "throughput_rps": round(len(results) / wall, 4),
            "latency_p50_s": st["latency_p50_s"],
            "latency_p99_s": st["latency_p99_s"],
            "queue_wait_p50_s": st["queue_wait_p50_s"],
            "queue_depth_max": st["queue_depth_max"],
            "queue_depth_mean": st["queue_depth_mean"],
            "per_worker_served": st["per_worker_served"],
            "completed": st["completed"], "failed": st["failed"],
            "feasible": feas,
        }
    out["meshes"][str(meshes)] = per

# batched dispatch on a hot mix: a duplicate-heavy burst (the serving
# workload batching targets) against the same requests run solo back to
# back. Identical requests coalesce into one partition run per distinct
# request, bit-identically — the structural speedup the gate tracks.
import numpy as np
distinct = [PartitionRequest(
                graph=GraphSpec("rgg2d", n // 2, 8.0, seed=61 + i),
                k=k, config=cfg, backend="single", collect_trace=False)
            for i in range(4)]
mix = [distinct[i % 4] for i in range(24)]
engine2 = Partitioner()
solo_res = [engine2.run(r) for r in distinct]   # warm the shapes
t0 = time.perf_counter()
for r in mix:
    engine2.run(r)
solo_wall = time.perf_counter() - t0

with PartitionServer(meshes=1, batch_max=32, batch_window_ms=20.0) as srv:
    srv.workers[0].hold()           # let the burst pile up, then drain
    t0 = time.perf_counter()
    futs = [srv.submit(r) for r in mix]
    srv.workers[0].release()
    results = [f.result() for f in futs]
    wall = time.perf_counter() - t0
    st = srv.stats()
bit_identical = all(
    r.ok and np.array_equal(r.result.assignment,
                            solo_res[i % 4].assignment)
    for i, r in enumerate(results))
out["batched"] = {
    "tickets": len(mix), "distinct": len(distinct),
    "solo_wall_s": round(solo_wall, 4),
    "wall_s": round(wall, 4),
    "throughput_rps": round(len(mix) / wall, 4),
    "batch_speedup": round(solo_wall / wall, 4),
    "bit_identical": bit_identical,
    "latency_p50_s": st["latency_p50_s"],
    "latency_p99_s": st["latency_p99_s"],
    "batches": st["batches"], "coalesced": st["coalesced"],
    "batch_size_max": st["batch_size_max"],
    "completed": st["completed"], "failed": st["failed"],
}
print(json.dumps(out))
"""

# The fabric child owns only the front door and the client — workers
# are grandchild processes spawned through the CLI, each with its own
# jax runtime. The front door never initializes a backend, so this
# child stays light; all partition compute happens in the workers.
_FABRIC_CHILD = r"""
import json, signal, subprocess, sys, time
R = int(sys.argv[1]); n = int(sys.argv[2]); k = int(sys.argv[3])
from repro.api import GraphSpec, PartitionRequest
from repro.core import PartitionerConfig
from repro.fabric import AutoscaleConfig, FabricClient, FrontDoor

cfg = PartitionerConfig(contraction_limit=128, ip_repetitions=1,
                        num_chunks=4)
reqs = [PartitionRequest(
            graph=GraphSpec("rgg2d", n, 8.0, seed=71 + i % 4),
            k=k, config=cfg, backend="single", collect_trace=False)
        for i in range(R)]


def spawn_worker(fd, sid):
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.fabric", "worker",
         "--frontdoor", f"{fd.host}:{fd.port}", "--server-id", sid,
         "--heartbeat-s", "0.3"],
        stdout=subprocess.PIPE, text=True)
    json.loads(proc.stdout.readline())  # block on the ready line
    return proc


def wait_servers(fd, count, timeout=180.0):
    t_end = time.monotonic() + timeout
    while time.monotonic() < t_end:
        if len(fd.registry.alive()) >= count:
            return True
        time.sleep(0.05)
    return False


def measure(client, reqs):
    lat = {}
    t0 = time.perf_counter()
    futs = []
    for i, r in enumerate(reqs):
        ts = time.perf_counter()
        f = client.submit(r)
        f.add_done_callback(
            lambda f, i=i, ts=ts:
            lat.__setitem__(i, time.perf_counter() - ts))
        futs.append(f)
    results = [f.result() for f in futs]
    wall = time.perf_counter() - t0
    xs = sorted(lat.values())
    nn = len(xs)
    return results, {
        "wall_s": round(wall, 4),
        "throughput_rps": round(len(results) / wall, 4),
        "latency_p50_s": round(xs[(nn - 1) // 2], 4),
        "latency_p99_s": round(xs[min(nn - 1, (99 * nn + 99) // 100 - 1)],
                               4),
    }


out = {"requests": R, "n": n, "k": k, "workers": {}}

# -- throughput/p99 at 1 vs 2 worker processes ------------------------------
procs = []
with FrontDoor(port=0, lease_ttl_s=5.0) as fd:
    with FabricClient(fd.host, fd.port) as client:
        for fleet in (1, 2):
            procs.append(spawn_worker(fd, f"bench-w{fleet - 1}"))
            assert wait_servers(fd, fleet), "worker never registered"
            client.serve(reqs)  # warm every worker's jit caches
            results, rec = measure(client, reqs)
            rec.update({
                "ok": sum(1 for r in results if r.ok),
                "failed": sum(1 for r in results if not r.ok),
                "servers_used": len({r.server for r in results}),
                "attempts_max": max(r.attempts for r in results),
            })
            out["workers"][str(fleet)] = rec
    for p in procs:
        p.send_signal(signal.SIGTERM)
    for p in procs:
        p.wait(timeout=120)

# -- autoscaler: grow 1 -> 2 under pressure, shrink back when idle ----------
auto = AutoscaleConfig(min_workers=1, max_workers=2,
                       grow_queue_depth=2.0, grow_windows=2,
                       shrink_windows=4, eval_period_s=0.3)
with FrontDoor(port=0, lease_ttl_s=5.0, autoscale=auto) as fd:
    assert wait_servers(fd, 1), "autoscaler never spawned min_workers"
    with FabricClient(fd.host, fd.port) as client:
        client.serve(reqs[:2])  # warm the first worker
        t0 = time.monotonic()
        futs = [client.submit(r) for r in reqs * 3]  # queue pressure
        grew = wait_servers(fd, 2)
        grow_s = time.monotonic() - t0
        results = [f.result() for f in futs]
        ok = sum(1 for r in results if r.ok)
        failed = len(results) - ok
    # idle now: the policy needs shrink_windows quiet evaluations, then
    # the youngest worker drains and exits
    t0 = time.monotonic()
    shrank = False
    t_end = time.monotonic() + 120.0
    while time.monotonic() < t_end:
        if fd._scaler.count() <= 1:
            shrank = True
            break
        time.sleep(0.1)
    out["autoscaler"] = {
        "grew": grew, "grow_s": round(grow_s, 2),
        "shrank": shrank, "shrink_s": round(time.monotonic() - t0, 2),
        "ok": ok, "failed": failed,
        "config": {"grow_windows": auto.grow_windows,
                   "shrink_windows": auto.shrink_windows,
                   "eval_period_s": auto.eval_period_s},
    }

print(json.dumps(out))
"""


def _run_child(code: str, argv, env) -> Dict:
    proc = subprocess.run(
        [sys.executable, "-c", code] + [str(a) for a in argv],
        capture_output=True, text=True, env=env, timeout=3000)
    if proc.returncode != 0:
        emit("serve/error", 0.0, proc.stderr[-300:].replace(",", ";"))
        raise RuntimeError(
            f"serve bench child failed:\n{proc.stderr[-2000:]}")
    return json.loads(proc.stdout.splitlines()[-1])


def run(fast: bool = True, out_json: str = "BENCH_serve.json") -> Dict:
    R, n, k = (8, 1500, 4) if fast else (16, 4000, 8)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    result = _run_child(_CHILD, [R, n, k], env)
    # the fabric child spawns worker processes that size their own jax
    # runtimes — an inherited device-count flag would skew them
    fabric_env = dict(env)
    fabric_env.pop("XLA_FLAGS", None)
    result["fabric"] = _run_child(_FABRIC_CHILD, [R, n // 2, k],
                                  fabric_env)
    for meshes, loads in result["meshes"].items():
        for load, rec in loads.items():
            emit(f"serve/{meshes}mesh/{load}", rec["wall_s"],
                 f"rps={rec['throughput_rps']};p99={rec['latency_p99_s']};"
                 f"depth={rec['queue_depth_max']};feas={rec['feasible']}")
    b = result["batched"]
    emit("serve/batched/hot_mix", b["wall_s"],
         f"rps={b['throughput_rps']};speedup={b['batch_speedup']};"
         f"coalesced={b['coalesced']};bit_identical={b['bit_identical']}")
    for fleet, rec in result["fabric"]["workers"].items():
        emit(f"serve/fabric/{fleet}proc", rec["wall_s"],
             f"rps={rec['throughput_rps']};p99={rec['latency_p99_s']};"
             f"servers={rec['servers_used']};failed={rec['failed']}")
    a = result["fabric"]["autoscaler"]
    emit("serve/fabric/autoscale", a["grow_s"],
         f"grew={a['grew']};shrank={a['shrank']};"
         f"shrink_s={a['shrink_s']};failed={a['failed']}")
    if out_json:
        with open(out_json, "w") as f:
            json.dump(result, f, indent=1)
        emit("serve/artifact", 0.0, out_json)
    return result


if __name__ == "__main__":
    run(fast=True)
