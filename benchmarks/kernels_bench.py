"""Kernel micro-benchmarks (interpret-mode wall time is NOT TPU perf —
reported for regression tracking; roofline numbers come from the dry-run).
Also prints the analytic VMEM footprint per tile, the quantity that
matters for the TPU BlockSpec choice."""
from __future__ import annotations

import numpy as np

from repro.graphs import generators
from repro.kernels.bsr_spmm.ops import graph_to_bsr, spmm
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.lp_gain.ops import lp_gain

from .common import emit, timed


def run() -> None:
    g = generators.make("rgg2d", 2000, 8.0, seed=3)
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 16, g.n)
    cw = np.zeros(16, dtype=np.int64)
    np.add.at(cw, labels, g.vweights)

    _, dt = timed(lambda: lp_gain(g, labels, cw, float(cw.max() + 10),
                                  row_tile=128), repeats=2)
    # VMEM per tile: lab/w/tgt_w tiles (R, D) f32 + eq (R, D, D) f32
    d_pad = 128
    vmem = (3 * 128 * d_pad * 4 + 128 * d_pad * d_pad * 4) / 2**20
    emit("kernels/lp_gain/rgg2d_2k", dt, f"vmem_tile_mb={vmem:.1f}")

    x = rng.standard_normal((g.n, 128)).astype(np.float32)
    _, dt = timed(lambda: spmm(g, x, bs=128), repeats=2)
    col, vals, rb, nnz = graph_to_bsr(g, 128)
    emit("kernels/bsr_spmm/rgg2d_2k", dt,
         f"blocks={vals.shape[0]};density={g.m / max(1, vals.size):.4f};"
         f"vmem_tile_mb={(2 * 128 * 128 * 4) / 2**20:.2f}")

    idx = rng.integers(0, 10000, (256, 2)).astype(np.int32)
    table = rng.standard_normal((10000, 64)).astype(np.float32)
    _, dt = timed(lambda: embedding_bag(idx, table), repeats=2)
    emit("kernels/embedding_bag/256x2", dt, "vmem_tile_mb=0.06")


if __name__ == "__main__":
    run()
