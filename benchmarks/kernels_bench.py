"""Kernel benchmarks: fused Pallas hot loops vs their composed twins.

Two tiers:

* the three fused hot-loop kernels (lp_move, seg_merge, bal_round) are
  timed through their *wired* entry points (``cluster`` / ``contract`` /
  ``rebalance`` with ``kernel="fused"`` vs ``"composed"``) and written to
  ``BENCH_kernels.json`` — per-kernel steady-state wall time (the
  ``timed`` warmup absorbs compilation), the analytic VMEM working set,
  a ``bit_identical`` flag (fused output must equal composed bit for
  bit; ``check_regression`` fails the gate on False), and achieved-vs-
  peak roofline terms via ``roofline.kernel_rows``;
* the legacy micro-kernels (lp_gain / bsr_spmm / embedding_bag) keep
  their CSV ``emit`` rows for continuity.

Interpret-mode wall time is NOT TPU perf — it is reported for
regression tracking on CPU runners; the roofline terms use analytic
bytes/FLOP counts so the achieved fraction is honest about that.
"""
from __future__ import annotations

import json
from typing import Dict, Optional

import numpy as np

from repro.core import metrics
from repro.graphs import generators

from .common import emit, timed


def _bench_lp_move(g, k: int) -> Dict:
    """LP move kernel through ``coarsening.cluster`` (both modes)."""
    from repro.core.coarsening import cluster
    from repro.kernels.lp_move.lp_move import lp_move_vmem_bytes
    from repro.kernels.lp_move.ops import (ROW_TILE, build_move_chunks,
                                           move_chunks_fit_vmem)
    W = max(1, int(0.10 * g.total_vweight / k))
    rec: Dict = {}
    labs = {}
    for mode in ("composed", "fused"):
        labs[mode], dt = timed(lambda m=mode: cluster(
            g, W, num_iterations=2, num_chunks=4, seed=1, kernel=m))
        rec[mode] = {"time_s": round(dt, 4)}
    chunks = build_move_chunks(g, 4)
    _, R, D = chunks.shape
    rec.update(
        bit_identical=bool(np.array_equal(labs["fused"],
                                          labs["composed"])),
        clusters=int(np.unique(labs["fused"]).size),
        ell_rows=R, ell_lanes=D,
        vmem_bytes=lp_move_vmem_bytes(R, D, ROW_TILE),
        vmem_fits=bool(move_chunks_fit_vmem(chunks)),
        # analytic per-iteration work: the (R, D, D) equality cube is
        # walked three times (conn, d_in/d_out, revert) across 4 chunks
        flops=3 * 4 * R * D * D,
        bytes=4 * (2 * R * D * 4 + 8 * R * 4))
    return rec


def _bench_seg_merge(g, k: int) -> Dict:
    """Contraction merge kernel through ``contraction.contract``."""
    from repro.core.coarsening import cluster
    from repro.core.contraction import contract
    from repro.kernels.seg_merge.seg_merge import (_next_pow2,
                                                   seg_merge_vmem_bytes)
    W = max(1, int(0.10 * g.total_vweight / k))
    labels = cluster(g, W, num_iterations=2, num_chunks=4, seed=1,
                     kernel="composed")
    rec: Dict = {}
    res = {}
    for mode in ("composed", "fused"):
        res[mode], dt = timed(lambda m=mode: contract(g, labels, kernel=m))
        rec[mode] = {"time_s": round(dt, 4)}
    (gc_f, map_f), (gc_c, map_c) = res["fused"], res["composed"]
    arcs = int(g.indptr[-1])
    L = _next_pow2(arcs)
    lg = max(1, L.bit_length() - 1)
    rec.update(
        bit_identical=bool(
            np.array_equal(map_f, map_c) and
            np.array_equal(gc_f.indptr, gc_c.indptr) and
            np.array_equal(gc_f.adjncy, gc_c.adjncy) and
            np.array_equal(gc_f.eweights, gc_c.eweights) and
            np.array_equal(gc_f.vweights, gc_c.vweights)),
        coarse_n=gc_f.n, coarse_m=gc_f.m, arcs=arcs,
        vmem_bytes=seg_merge_vmem_bytes(arcs),
        vmem_fits=bool(seg_merge_vmem_bytes(arcs) <= 8 * 2**20),
        # bitonic sort: L/2 compare-exchanges per stage, lg*(lg+1)/2
        # stages; plus 2*lg shifted passes for each of the two scans
        flops=L * lg * (lg + 1) // 4 + 4 * L * lg,
        bytes=seg_merge_vmem_bytes(arcs))
    return rec


def _bench_bal_round(g, k: int) -> Dict:
    """Balance round kernels through ``balance.rebalance`` on a skewed
    (infeasible) start so the round loop actually runs."""
    from repro.core.balance import rebalance
    from repro.kernels.bal_round.bal_round import bal_scores_vmem_bytes
    from repro.kernels.bal_round.ops import balance_ell_fits
    from repro.kernels.lp_move.ops import LANE, ROW_TILE, _round_up
    lmax = np.full(k, metrics.l_max(g.total_vweight, k, 0.03,
                                    int(g.vweights.max())), dtype=np.int64)
    rng = np.random.default_rng(5)
    part0 = np.where(rng.random(g.n) < 0.7, 0,
                     rng.integers(0, k, g.n)).astype(np.int64)
    rec: Dict = {}
    res = {}
    for mode in ("composed", "fused"):
        stats: Dict = {}
        res[mode], dt = timed(lambda m=mode, s=stats: rebalance(
            g, part0.copy(), lmax, seed=7, kernel=m, stats=s))
        rec[mode] = {"time_s": round(dt, 4), "rounds": stats.get("rounds")}
    deg = np.diff(g.indptr)
    R = _round_up(g.n + 2, ROW_TILE)
    D = _round_up(int(deg.max()) if g.n else 1, LANE)
    rec.update(
        bit_identical=bool(np.array_equal(res["fused"], res["composed"])),
        feasible=bool(metrics.is_feasible(g, res["fused"], k, 0.03)),
        ell_rows=R, ell_lanes=D,
        vmem_bytes=bal_scores_vmem_bytes(R, D, ROW_TILE),
        vmem_fits=bool(balance_ell_fits(R, D)),
        flops=R * D * D,
        bytes=4 * R * D * 4 + 8 * R * 4)
    return rec


def _legacy_micro() -> None:
    """The pre-existing micro-kernel CSV rows (emit-only, no JSON)."""
    from repro.kernels.bsr_spmm.ops import graph_to_bsr, spmm
    from repro.kernels.embedding_bag.ops import embedding_bag
    from repro.kernels.lp_gain.ops import lp_gain

    g = generators.make("rgg2d", 2000, 8.0, seed=3)
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 16, g.n)
    cw = np.zeros(16, dtype=np.int64)
    np.add.at(cw, labels, g.vweights)

    _, dt = timed(lambda: lp_gain(g, labels, cw, float(cw.max() + 10),
                                  row_tile=128), repeats=2)
    # VMEM per tile: lab/w/tgt_w tiles (R, D) f32 + eq (R, D, D) f32
    d_pad = 128
    vmem = (3 * 128 * d_pad * 4 + 128 * d_pad * d_pad * 4) / 2**20
    emit("kernels/lp_gain/rgg2d_2k", dt, f"vmem_tile_mb={vmem:.1f}")

    x = rng.standard_normal((g.n, 128)).astype(np.float32)
    _, dt = timed(lambda: spmm(g, x, bs=128), repeats=2)
    col, vals, rb, nnz = graph_to_bsr(g, 128)
    emit("kernels/bsr_spmm/rgg2d_2k", dt,
         f"blocks={vals.shape[0]};density={g.m / max(1, vals.size):.4f};"
         f"vmem_tile_mb={(2 * 128 * 128 * 4) / 2**20:.2f}")

    idx = rng.integers(0, 10000, (256, 2)).astype(np.int32)
    table = rng.standard_normal((10000, 64)).astype(np.float32)
    _, dt = timed(lambda: embedding_bag(idx, table), repeats=2)
    emit("kernels/embedding_bag/256x2", dt, "vmem_tile_mb=0.06")


def run(fast: bool = True,
        out_json: Optional[str] = "BENCH_kernels.json") -> Dict:
    import jax

    from . import roofline

    n = 1200 if fast else 4000
    k = 8
    g = generators.make("rgg2d", n, 8.0, seed=3)
    result: Dict = {
        "n": g.n, "m": g.m, "k": k,
        "backend": jax.default_backend(),
        # off-TPU the fused kernels run Pallas interpret mode: wall
        # times are regression signals, not accelerator performance
        "interpret": jax.default_backend() != "tpu",
        "kernels": {
            "lp_move": _bench_lp_move(g, k),
            "seg_merge": _bench_seg_merge(g, k),
            "bal_round": _bench_bal_round(g, k),
        },
    }
    result["roofline"] = roofline.kernel_rows(result["kernels"])
    for name, rec in result["kernels"].items():
        emit(f"kernels/{name}/fused", rec["fused"]["time_s"],
             f"composed_s={rec['composed']['time_s']};"
             f"bit_identical={rec['bit_identical']};"
             f"vmem_kb={rec['vmem_bytes'] // 1024}")
    _legacy_micro()
    if out_json:
        with open(out_json, "w") as f:
            json.dump(result, f, indent=1)
        emit("kernels/artifact", 0.0, out_json)
    return result


if __name__ == "__main__":
    run()
