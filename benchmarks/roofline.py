"""§Roofline: derive the three roofline terms per (arch x shape x mesh)
from the dry-run artifacts (launch/dryrun.py --out artifacts/dryrun).

TPU v5e constants (per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.

  compute_term    = HLO_FLOPs_per_device / peak_FLOPs
  memory_term     = HLO_bytes_per_device / HBM_bw
  collective_term = collective_bytes_per_device / link_bw

cost_analysis() reports the per-device SPMD program, so no further
division by chip count is needed. For LM cells the scan-corrected flops
(1/2-layer unrolled probes) are used — lax.scan hides the per-layer body
from cost_analysis. MODEL_FLOPS is the analytic 6·N·D (total, all chips).
"""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
LINK_BW = 50e9             # B/s / link


def load_cells(art_dir: str) -> List[Dict]:
    cells = []
    for fn in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(fn) as f:
            cells.append(json.load(f))
    return cells


def cell_name(cell: Dict) -> str:
    mesh = cell.get("mesh")
    mesh_s = "x".join(map(str, mesh)) if mesh else "?"
    return (f"{cell.get('arch', '?')}/{cell.get('shape', '?')}/"
            f"mesh={mesh_s}")


def roofline_row(cell: Dict) -> Optional[Dict]:
    cost = cell.get("cost_analysis", {})
    if "flops" not in cost:
        # a silent drop here would make a dry-run misconfiguration read
        # as "no kernels regressed" — name the cell and the reason
        reason = ("cost_analysis missing entirely (dry-run artifact "
                  "predates cost capture?)" if not cost else
                  "cost_analysis has no 'flops' key (backend did not "
                  "report HLO cost)")
        print(f"[roofline:skip] {cell_name(cell)}: {reason}",
              file=sys.stderr)
        return None
    n_dev = cell["n_devices"]
    flops_dev = cell.get("hlo_flops_per_device_corrected") or cost["flops"]
    bytes_dev = cost.get("bytes accessed", 0.0)
    coll_dev = sum(v["bytes"] for v in cell.get("collectives", {}).values())
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    dominant = max((t_compute, "compute"), (t_memory, "memory"),
                   (t_coll, "collective"))[1]
    model_flops = cell.get("model_flops") or 0.0
    hlo_total = flops_dev * n_dev
    bound = max(t_compute, t_memory, t_coll)
    # roofline fraction: useful model flops vs what the dominant resource
    # could deliver in the time the program occupies it
    frac = (model_flops / n_dev / PEAK_FLOPS) / bound if bound else 0.0
    return {
        "arch": cell["arch"], "shape": cell["shape"],
        "mesh": "x".join(map(str, cell["mesh"])),
        "compute_s": t_compute, "memory_s": t_memory,
        "collective_s": t_coll, "dominant": dominant,
        "model_flops": model_flops, "hlo_flops_total": hlo_total,
        "useful_ratio": model_flops / hlo_total if hlo_total else 0.0,
        "roofline_fraction": frac,
        "temp_gb_per_dev":
            cell["memory_analysis"].get("temp_size_bytes", 0) / 1e9,
    }


def kernel_rows(kernels: Dict) -> List[Dict]:
    """Achieved-vs-peak roofline terms for the fused hot-loop kernels.

    ``kernels`` is the ``BENCH_kernels.json`` ``"kernels"`` mapping:
    each record carries analytic per-invocation ``flops`` / ``bytes``
    and a measured fused ``time_s``. Returns one row per kernel with
    achieved FLOP/s and B/s, their fractions of the v5e peaks, the
    arithmetic intensity, and which roofline ceiling (compute vs HBM)
    binds at that intensity. On CPU runners the fused path is Pallas
    interpret mode, so achieved fractions are tiny by construction —
    they are tracked for run-over-run regressions, not as TPU truth.
    """
    ridge = PEAK_FLOPS / HBM_BW        # FLOP/B where the ceilings cross
    rows = []
    for name, rec in sorted(kernels.items()):
        t = float(rec.get("fused", {}).get("time_s") or 0.0)
        flops = float(rec.get("flops", 0))
        bts = float(rec.get("bytes", 0))
        if not t or not bts:
            print(f"[roofline:skip] kernel {name}: no fused time_s or "
                  "byte count in the bench record", file=sys.stderr)
            continue
        intensity = flops / bts
        rows.append({
            "kernel": name,
            "intensity_flop_per_byte": round(intensity, 3),
            "bound": "compute" if intensity >= ridge else "memory",
            "achieved_flops": flops / t,
            "achieved_bytes_s": bts / t,
            "peak_flops_fraction": flops / t / PEAK_FLOPS,
            "peak_hbm_fraction": bts / t / HBM_BW,
            "vmem_bytes": rec.get("vmem_bytes"),
        })
    return rows


def run(art_dir: str = "artifacts/dryrun", out_md: Optional[str] = None
        ) -> List[Dict]:
    rows = [r for r in (roofline_row(c) for c in load_cells(art_dir)) if r]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    hdr = ("arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
           "useful_ratio,roofline_fraction,temp_gb")
    print(hdr)
    lines = [hdr]
    for r in rows:
        line = (f"{r['arch']},{r['shape']},{r['mesh']},"
                f"{r['compute_s']:.4g},{r['memory_s']:.4g},"
                f"{r['collective_s']:.4g},{r['dominant']},"
                f"{r['useful_ratio']:.3f},{r['roofline_fraction']:.3f},"
                f"{r['temp_gb_per_dev']:.1f}")
        print(line)
        lines.append(line)
    if out_md:
        with open(out_md, "w") as f:
            f.write("\n".join(lines) + "\n")
    return rows


if __name__ == "__main__":
    run(sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun",
        out_md=sys.argv[2] if len(sys.argv) > 2 else None)
