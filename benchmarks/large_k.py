"""Paper Table 2 analog (large k): feasibility and relative cut/time for
k in {2^6, 2^8, 2^10} (scaled to laptop n; the paper uses 2^10..2^20 at
cluster n). Deep MGP must stay 100% feasible; plain MGP degrades because
the coarsest graph (C*k vertices) stops being small."""
from __future__ import annotations

import dataclasses
import json
from typing import Dict

import numpy as np

from repro.core.deep_mgp import PartitionerConfig

from .common import emit, geomean, instance_set


def run(scale: str = "small", ks=(64, 256, 1024), out_json=None) -> Dict:
    from repro.api import PartitionRequest, Partitioner
    # small C so that n/C supports large k (paper: C=2000 at n=2^26+)
    cfg = PartitionerConfig(contraction_limit=32, ip_repetitions=1,
                            num_chunks=4)
    engine = Partitioner()
    rows = []
    for name, g in instance_set(scale):
        for k in ks:
            if k * 4 > g.n:
                continue
            rec = {"instance": name, "k": k, "algos": {}}
            base = PartitionRequest(graph=g, k=k, config=cfg,
                                    collect_trace=False)
            for aname, req in {
                "deep": dataclasses.replace(base, backend="single"),
                # plain MGP's coarsest graph is C*k vertices — shrink C
                # further so the baseline stays runnable at large k
                "plain": dataclasses.replace(
                    base, backend="plain_mgp",
                    config=dataclasses.replace(cfg, contraction_limit=8)),
                "single_lp": dataclasses.replace(
                    base, backend="single_level_lp"),
            }.items():
                res = engine.run(req)
                s = res.metrics
                rec["algos"][aname] = {"cut": s["cut"],
                                       "time": float(res.time_s),
                                       "feasible": s["feasible"],
                                       "imbalance": s["imbalance"],
                                       "nonempty": s["nonempty_blocks"]}
            rows.append(rec)
            d = rec["algos"]["deep"]
            emit(f"large_k/{name}/k{k}/deep", d["time"],
                 f"cut={d['cut']};feas={d['feasible']};"
                 f"nonempty={d['nonempty']}")
    summary = {}
    for a in ("deep", "plain", "single_lp"):
        feas = [r["algos"][a]["feasible"] for r in rows]
        rel = [r["algos"][a]["cut"] /
               max(r["algos"]["deep"]["cut"], 1) for r in rows]
        summary[a] = {"n_feasible": int(np.sum(feas)), "n_total": len(feas),
                      "gmean_rel_cut": geomean(rel)}
        emit(f"large_k/summary/{a}", 0.0,
             f"feasible={summary[a]['n_feasible']}/{summary[a]['n_total']};"
             f"rel_cut={summary[a]['gmean_rel_cut']:.3f}")
    result = {"rows": rows, "summary": summary}
    if out_json:
        with open(out_json, "w") as f:
            json.dump(result, f, indent=1)
    return result


if __name__ == "__main__":
    run()
