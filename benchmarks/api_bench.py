"""Facade benchmark: one request, every backend, one JSON artifact.

Runs the same ``PartitionRequest`` against each registered backend via
``repro.api.Partitioner.compare`` and writes ``BENCH_api.json`` —
{backend: {cut, feasible, time_s}} plus instance metadata — so the perf
trajectory of the public API is tracked run-over-run. The distributed
backends run at P=1 in-process (a sharding smoke; multi-device numbers
come from the scaling section's subprocesses). A ``refine_pareto``
section (``benchmarks.quality.refine_pareto``) tracks the cut-vs-time
trade of ``refine="lp"`` vs ``refine="unconstrained"`` on the quality
mix; the regression gate requires the unconstrained tier to stay
feasible with aggregate cut <= LP (docs/REFINEMENT.md).
"""
from __future__ import annotations

import json
from typing import Dict

from .common import bench_config, emit
from .quality import refine_pareto

BACKENDS = ["single", "dist", "dist-grid", "plain_mgp", "single_level_lp"]


def run(fast: bool = True, out_json: str = "BENCH_api.json") -> Dict:
    from repro.api import GraphSpec, PartitionRequest, Partitioner

    n = 4000 if fast else 20000
    spec = GraphSpec("rgg2d", n, 8.0, seed=17)
    req = PartitionRequest(graph=spec, k=16, epsilon=0.03,
                           config=bench_config(), devices=1,
                           collect_trace=False)
    result = {"instance": {"family": spec.family, "n": spec.n,
                           "avg_deg": spec.avg_deg, "seed": spec.seed,
                           "k": req.k, "epsilon": req.epsilon},
              "backends": {}}
    for res in Partitioner().compare(req, BACKENDS):
        rec = {"cut": res.cut, "feasible": res.feasible,
               "time_s": round(float(res.time_s), 4)}
        result["backends"][res.backend] = rec
        emit(f"api/{res.backend}", res.time_s,
             f"cut={res.cut};feas={res.feasible}")
    result["refine_pareto"] = refine_pareto(
        scale="small" if fast else "medium", ks=(16,), seeds=(0,))
    if out_json:
        with open(out_json, "w") as f:
            json.dump(result, f, indent=1)
        emit("api/artifact", 0.0, out_json)
    return result


if __name__ == "__main__":
    run(fast=True)
